"""L1 kernel correctness: Pallas smm_conv / fc_matmul vs the pure-jnp
oracles, including hypothesis sweeps over layer geometry (the CORE
build-time correctness signal — the same kernels are AOT-compiled into
the artifacts the Rust golden check runs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import conv2d_ref, fc_ref
from compile.kernels.smm_conv import fc_matmul, smm_conv

jax.config.update("jax_platform_name", "cpu")


def int_conv_case(rng, n, m, r_i, r_k):
    """Integer-valued f32 tensors (the golden-path value domain)."""
    x = rng.integers(0, 256, size=(n, r_i, r_i)).astype(np.float32)
    w = rng.integers(-127, 128, size=(m, n, r_k, r_k)).astype(np.float32)
    b = rng.integers(-1000, 1000, size=(m,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)


@pytest.mark.parametrize(
    "n,m,r_i,r_k,stride,pad",
    [
        (4, 8, 16, 3, 1, 1),
        (8, 16, 8, 3, 1, 1),
        (8, 8, 10, 1, 1, 0),
        (3, 6, 14, 5, 1, 2),
        (3, 8, 23, 11, 4, 0),
        (3, 8, 21, 7, 2, 3),
        (5, 7, 9, 3, 1, 1),
        (1, 1, 5, 3, 2, 0),
    ],
)
def test_smm_conv_matches_ref(n, m, r_i, r_k, stride, pad):
    rng = np.random.default_rng(42 + n * 100 + m)
    x, w, b = int_conv_case(rng, n, m, r_i, r_k)
    got = smm_conv(x, w, b, stride=stride, pad=pad)
    want = conv2d_ref(x, w, b, stride=stride, pad=pad)
    assert got.shape == want.shape
    # Integer-valued inputs ⇒ exact equality (f32 is exact below 2^24).
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_smm_conv_zero_weights_is_bias():
    x = jnp.ones((2, 6, 6), jnp.float32) * 9
    w = jnp.zeros((3, 2, 3, 3), jnp.float32)
    b = jnp.asarray([1.0, -2.0, 5.0])
    out = smm_conv(x, w, b, stride=1, pad=1)
    assert out.shape == (3, 6, 6)
    np.testing.assert_array_equal(np.asarray(out[0]), 1.0)
    np.testing.assert_array_equal(np.asarray(out[1]), -2.0)
    np.testing.assert_array_equal(np.asarray(out[2]), 5.0)


def test_smm_conv_identity_kernel():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 256, size=(1, 5, 5)).astype(np.float32))
    w = jnp.zeros((1, 1, 1, 1), jnp.float32).at[0, 0, 0, 0].set(1.0)
    out = smm_conv(x, w, jnp.zeros((1,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 4),
    m=st.integers(1, 6),
    r_k=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 2),
    extra=st.integers(0, 5),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_smm_conv_hypothesis_geometry(n, m, r_k, stride, extra, pad, seed):
    """Property sweep: arbitrary small geometry, integer data, exactness."""
    r_i = r_k + stride + extra  # always ≥ kernel
    rng = np.random.default_rng(seed)
    x, w, b = int_conv_case(rng, n, m, r_i, r_k)
    got = smm_conv(x, w, b, stride=stride, pad=pad)
    want = conv2d_ref(x, w, b, stride=stride, pad=pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    i=st.integers(1, 64),
    o=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_fc_matmul_hypothesis(i, o, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 256, size=(i,)).astype(np.float32))
    w = jnp.asarray(rng.integers(-127, 128, size=(o, i)).astype(np.float32))
    b = jnp.asarray(rng.integers(-1000, 1000, size=(o,)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(fc_matmul(x, w, b)), np.asarray(fc_ref(x, w, b))
    )


def test_smm_conv_linearity_in_weights():
    """conv(w1 + w2) == conv(w1) + conv(w2) for zero bias."""
    rng = np.random.default_rng(3)
    x, w1, _ = int_conv_case(rng, 3, 4, 8, 3)
    _, w2, _ = int_conv_case(rng, 3, 4, 8, 3)
    b0 = jnp.zeros((4,), jnp.float32)
    lhs = smm_conv(x, w1 + w2, b0, pad=1)
    rhs = smm_conv(x, w1, b0, pad=1) + smm_conv(x, w2, b0, pad=1)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
