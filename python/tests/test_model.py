"""L2 model tests: the tiny CNN forward (shape/value sanity) and the AOT
lowering path (HLO text is produced and references no Python at runtime)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import lower_conv_case, lower_tiny_cnn, CONV_CASES, TINY
from compile.kernels.ref import conv2d_ref, maxpool2d_ref, relu_ref
from compile.model import requant_ref, tiny_cnn_forward, TINY_SHIFTS

jax.config.update("jax_platform_name", "cpu")


def tiny_args(seed=0):
    t = TINY
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, size=(t["n"], t["r_i"], t["r_i"])).astype(np.float32)
    w1 = rng.integers(-20, 21, size=(t["c1"], t["n"], 3, 3)).astype(np.float32)
    b1 = rng.integers(-100, 100, size=(t["c1"],)).astype(np.float32)
    w2 = rng.integers(-20, 21, size=(t["c2"], t["c1"], 3, 3)).astype(np.float32)
    b2 = rng.integers(-100, 100, size=(t["c2"],)).astype(np.float32)
    flat = t["c2"] * (t["r_i"] // 4) ** 2
    wf = rng.integers(-5, 6, size=(t["classes"], flat)).astype(np.float32)
    bf = rng.integers(-100, 100, size=(t["classes"],)).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (x, w1, b1, w2, b2, wf, bf))


def test_tiny_cnn_shapes_and_reference():
    args = tiny_args(1)
    logits = tiny_cnn_forward(*args)
    assert logits.shape == (TINY["classes"],)
    # Independent reference built from the oracles only.
    x, w1, b1, w2, b2, wf, bf = args
    h = requant_ref(relu_ref(conv2d_ref(x, w1, b1, stride=1, pad=1)), TINY_SHIFTS[0])
    h = maxpool2d_ref(h, 2, 2)
    h = requant_ref(relu_ref(conv2d_ref(h, w2, b2, stride=1, pad=1)), TINY_SHIFTS[1])
    h = maxpool2d_ref(h, 2, 2)
    want = wf @ jnp.reshape(h, (-1,)) + bf
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(want))


def test_tiny_cnn_deterministic():
    a = tiny_cnn_forward(*tiny_args(2))
    b = tiny_cnn_forward(*tiny_args(2))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_conv_case_lowers_to_hlo_text():
    name, n, m, r_i, r_k, stride, pad = CONV_CASES[0]
    text = lower_conv_case(name, n, m, r_i, r_k, stride, pad)
    assert "HloModule" in text
    # The whole point of AOT: no Python callbacks inside the artifact.
    assert "python" not in text.lower()


def test_all_conv_cases_lower():
    for case in CONV_CASES:
        text = lower_conv_case(*case)
        assert "HloModule" in text, f"case {case[0]}"


def test_tiny_cnn_lowers():
    text = lower_tiny_cnn()
    assert "HloModule" in text
    assert "custom-call" not in text.lower(), (
        "interpret=True must lower Pallas to plain HLO ops — a Mosaic "
        "custom-call cannot run on the CPU PJRT client"
    )
