"""L1 Pallas kernel: scalar-matrix-multiplication convolution (CoDR Fig 3b).

The paper's datapath computes a convolution as, for every (output-channel,
input-channel, kernel-offset) triple, a *scalar × input-window matrix*
product accumulated into the output tile — this is what breaks the
dependency between weight terms and enables Universal Computation Reuse.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): CoDR's Input-RF /
Output-RF stationarity maps onto Pallas VMEM blocks — the kernel's grid
iterates over output channels with the entire (padded) input resident in
VMEM, and each grid step accumulates the R_K·C_K scalar-matrix products
for its output channel. The MPE→APE index crossbar is control flow the
TPU cannot express cheaply, so the dense scatter is materialised as a sum
over kernel offsets (same arithmetic; the sparse routing stays in the L3
simulator).

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
that the CPU PJRT plugin cannot run (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _smm_conv_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, r_k, c_k, r_o, c_o):
    """One grid step: all scalar-matrix products for one output channel.

    x_ref: [N, R_P, C_P] padded input (VMEM-resident, f32)
    w_ref: [1, N, R_K, C_K] this output channel's filter
    b_ref: [1]             this output channel's bias
    o_ref: [1, R_O, C_O]   output tile (accumulated here — output stationary)
    """
    n = x_ref.shape[0]
    acc = jnp.full((r_o, c_o), b_ref[0], dtype=jnp.float32)
    # Scalar-matrix multiplication: each weight w[ic, kr, kc] (scalar)
    # multiplies the shifted input window (matrix) — the Fig 3b dataflow.
    for ic in range(n):
        for kr in range(r_k):
            for kc in range(c_k):
                window = jax.lax.slice(
                    x_ref[ic],
                    (kr, kc),
                    (kr + (r_o - 1) * stride + 1, kc + (c_o - 1) * stride + 1),
                    (stride, stride),
                )
                acc = acc + w_ref[0, ic, kr, kc] * window
    o_ref[...] = acc[None]


def smm_conv(x, w, b, *, stride=1, pad=0):
    """Convolution via the CoDR scalar-matrix dataflow, as a Pallas kernel.

    Args:
      x: [N, R_I, C_I] f32 input features
      w: [M, N, R_K, C_K] f32 weights
      b: [M] f32 bias
    Returns:
      [M, R_O, C_O] f32 pre-activations (exact integers for int-valued
      inputs — the golden-model contract with the Rust simulator).
    """
    n, r_i, c_i = x.shape
    m, n_w, r_k, c_k = w.shape
    assert n == n_w, f"input channels mismatch: {n} vs {n_w}"
    assert b.shape == (m,)
    r_o = (r_i + 2 * pad - r_k) // stride + 1
    c_o = (c_i + 2 * pad - c_k) // stride + 1

    x_padded = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    kernel = functools.partial(
        _smm_conv_kernel, stride=stride, r_k=r_k, c_k=c_k, r_o=r_o, c_o=c_o
    )
    # Grid over output channels (the T_M loop of a CoDR PU); the padded
    # input is broadcast to every step — input stationary in VMEM, exactly
    # the Input-RF sharing of Fig 5a.
    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec(x_padded.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((1, n, r_k, c_k), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, r_o, c_o), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r_o, c_o), jnp.float32),
        interpret=True,
    )(x_padded, w, b)


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref):
    """FC tile: o = x @ w^T + b (w stored [O, I] as in the paper models)."""
    o_ref[...] = x_ref[...] @ w_ref[...].T + b_ref[...]


def fc_matmul(x, w, b):
    """Fully-connected layer as a Pallas matmul kernel.

    Args:
      x: [I] f32 flattened activations
      w: [O, I] f32
      b: [O] f32
    Returns: [O] f32
    """
    (i,) = x.shape
    o, i_w = w.shape
    assert i == i_w
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((1, o), jnp.float32),
        interpret=True,
    )(x.reshape(1, i), w, b.reshape(1, o))
    return out.reshape(o)
