"""Pure-jnp correctness oracles for the Pallas kernels.

These are the build-time ground truth: pytest checks every kernel against
them (exactly — the golden path is integer-valued), and `aot.py` embeds
the *kernel* (not the oracle) into the artifacts the Rust runtime loads.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, b, *, stride=1, pad=0):
    """Reference conv via lax.conv_general_dilated.

    x: [N, R_I, C_I]; w: [M, N, R_K, C_K]; b: [M] → [M, R_O, C_O].
    """
    out = lax.conv_general_dilated(
        x[None],  # [1, N, H, W]
        w,  # [M, N, kh, kw] (OIHW)
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return out + b[:, None, None]


def fc_ref(x, w, b):
    """Reference FC: x [I], w [O, I], b [O] → [O]."""
    return w @ x + b


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def maxpool2d_ref(x, k=2, stride=2):
    """Max-pool per channel: x [C, R, Cc] → [C, R', C']."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, k, k),
        window_strides=(1, stride, stride),
        padding="VALID",
    )
