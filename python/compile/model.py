"""L2: the JAX golden model — conv layers and a small end-to-end CNN
built on the L1 Pallas kernels.

Everything here is f32 over integer-valued data (u8 activations, i8
weights, i32 bias): |accumulator| stays far below 2^24, so f32 arithmetic
is exact and the Rust simulator's integer outputs must match the compiled
artifacts bit for bit.

Only `aot.py` imports this module (build time); nothing here runs on the
simulation path.
"""

import jax.numpy as jnp

from .kernels.smm_conv import fc_matmul, smm_conv
from .kernels.ref import maxpool2d_ref, relu_ref

# Requantization shifts between the tiny CNN's layers (integer-only
# inference): accumulators are scaled back to u8 so every layer stays far
# below 2^24 and f32 remains exact. Mirrored by the Rust side
# (`tensor::requantize`, examples/e2e_tiny_cnn.rs).
TINY_SHIFTS = (6, 6)


def requant_ref(x, shift):
    """clip(⌊x / 2^shift⌋, 0, 255) — matches Rust `requantize` on the
    post-ReLU (non-negative) domain."""
    return jnp.clip(jnp.floor(x / (2.0**shift)), 0.0, 255.0)


def conv_layer(x, w, b, *, stride=1, pad=0):
    """One conv layer through the scalar-matrix-multiplication kernel —
    the unit artifact the Rust golden check loads per manifest entry."""
    return smm_conv(x, w, b, stride=stride, pad=pad)


def tiny_cnn_forward(x, w1, b1, w2, b2, wf, bf):
    """The `tiny` model of the Rust zoo (models::tiny_cnn), end to end:

    conv1(4→8, 3×3, pad 1) → ReLU → requant → maxpool2 →
    conv2(8→16, 3×3, pad 1) → ReLU → requant → maxpool2 →
    flatten → FC(→10)

    Shapes: x [4,16,16]; w1 [8,4,3,3]; w2 [16,8,3,3]; wf [10, 16*4*4].
    Returns logits [10].
    """
    h = smm_conv(x, w1, b1, stride=1, pad=1)
    h = requant_ref(relu_ref(h), TINY_SHIFTS[0])
    h = maxpool2d_ref(h, 2, 2)
    h = smm_conv(h, w2, b2, stride=1, pad=1)
    h = requant_ref(relu_ref(h), TINY_SHIFTS[1])
    h = maxpool2d_ref(h, 2, 2)
    flat = jnp.reshape(h, (-1,))
    return fc_matmul(flat, wf, bf)
