//! Numerical invariance of the optimized hot path.
//!
//! The zero-allocation / memoized / emission-free simulation pipeline
//! must produce **byte-for-byte identical** `LayerResult`s (mem, alu,
//! cycles, compression, energy) to the seed pipeline, which is kept
//! in-tree as `simulate_layer_reference` for each design. These tests
//! pin that equality on the tiny model across every sweep group, and
//! check the memo-driven `SweepStats` reporting.

use codr::baselines::{scnn, ucnn, Scnn, Ucnn};
use codr::codr::{dataflow, Codr};
use codr::coordinator::{run_sweep, Arch};
use codr::models::{
    alexnet, googlenet, synthesize_weights, tiny_cnn, vgg16, LayerSpec, SweepGroup, Workload,
};
use codr::sim::Accelerator;
use codr::util::rng::Rng;

/// One optimized-vs-oracle comparison for all three designs.
fn assert_all_archs_match(spec: &LayerSpec, w: &codr::tensor::Weights, ctx: &str) {
    let codr_design = Codr::default();
    let oracle = dataflow::simulate_layer_reference(&codr_design, spec, w);
    assert_eq!(codr_design.simulate_layer(spec, w), oracle, "CoDR {ctx}");

    let ucnn_design = Ucnn::default();
    let oracle = ucnn::simulate_layer_reference(&ucnn_design, spec, w);
    assert_eq!(ucnn_design.simulate_layer(spec, w), oracle, "UCNN {ctx}");

    let scnn_design = Scnn::default();
    let oracle = scnn::simulate_layer_reference(&scnn_design, spec, w);
    assert_eq!(scnn_design.simulate_layer(spec, w), oracle, "SCNN {ctx}");
}

/// Every design, every sweep group, every layer of the tiny model:
/// optimized == reference, both cold and memo-warm (each layer is
/// asserted twice via the helper's fresh calls plus the repeat below).
#[test]
fn optimized_layer_results_match_reference_on_tiny() {
    let model = tiny_cnn();
    for group in SweepGroup::all() {
        let (unique, density) = group.knobs();
        let wl = Workload::generate(&model, unique, density, 42);
        for (spec, w) in wl.conv_layers() {
            let ctx = format!("{} / {}", group.label(), spec.name);
            assert_all_archs_match(spec, w, &ctx);
            // And again, fully memo-warm.
            assert_all_archs_match(spec, w, &format!("warm {ctx}"));
        }
    }
}

/// Zoo geometry coverage: the tiny model never exercises 11×11-stride-4
/// tiling (alexnet conv1), 1×1 and 5×5 kernels (googlenet), or
/// VGG16-class channel counts. Pin one representative layer of each
/// kind per zoo model so a geometry-specific hot-path bug cannot hide
/// behind the tiny grid.
#[test]
fn optimized_layer_results_match_reference_across_zoo_geometries() {
    let mut rng = Rng::new(77);
    for model in [alexnet(), vgg16(), googlenet()] {
        let mut picked: Vec<&LayerSpec> = Vec::new();
        let convs: Vec<&LayerSpec> = model.conv_layers().collect();
        // First conv (largest kernel / stride of each net)…
        if let Some(&first) = convs.first() {
            picked.push(first);
        }
        // …plus the first layer of every distinct kernel size (1×1, 3×3,
        // 5×5, 7×7 across the zoo), bounded so the suite stays fast.
        for &spec in &convs {
            if picked.iter().all(|p| p.r_k != spec.r_k) && picked.len() < 4 {
                picked.push(spec);
            }
        }
        for spec in picked {
            let w = synthesize_weights(spec, &mut rng);
            assert_all_archs_match(spec, &w, &format!("{}/{}", model.name, spec.name));
        }
    }
}

/// The chunked tile-task fan-out (what `run_sweep` schedules) must be
/// bit-identical to direct unchunked per-layer simulation — including
/// on zoo layers big enough to actually split into several chunks.
#[test]
fn chunked_sweep_equals_direct_layer_simulation_on_big_layers() {
    use codr::coordinator::layer_chunks;
    use codr::sim::simulate_model;

    let models = [alexnet()];
    let group = SweepGroup::Original;
    let archs = Arch::all();
    // The premise: at least one alexnet conv fans out into >1 chunk.
    let widest = models[0]
        .conv_layers()
        .max_by_key(|l| l.num_weights())
        .expect("alexnet has conv layers");
    assert!(
        layer_chunks(Arch::Codr, widest) > 1,
        "{} should chunk",
        widest.name
    );

    let sweep = run_sweep(&models, &[group], &archs, 5);
    let wl = Workload::generate(&models[0], None, None, 5);
    for arch in archs {
        let direct = simulate_model(arch.build().as_ref(), &wl, &group.label());
        let chunked = sweep
            .get("alexnet", group, arch)
            .expect("sweep covers the point");
        assert_eq!(chunked, &direct, "{} chunked != direct", arch.name());
    }
}

/// Real weight vectors never collide in the 128-bit fingerprint space:
/// a whole sweep must complete with ZERO byte-verification fallbacks
/// (the acceptance pin that warm-path lookups do no byte comparisons),
/// and the two-level split must account for every reported hit.
#[test]
fn sweeps_never_byte_verify_on_collision_free_workloads() {
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original, SweepGroup::Density(25)];
    let r = run_sweep(&models, &groups, &Arch::all(), 77);
    assert_eq!(
        r.stats.collision_verifies, 0,
        "collision-free workload byte-verified: {:?}",
        r.stats
    );
    assert_eq!(r.stats.memo_hits, r.stats.l1_hits + r.stats.l2_hits);
    assert!(r.stats.memo_misses > 0, "cold sweep must transform");
    // Warm repeat: still collision-free, and hits dominate.
    let r2 = run_sweep(&models, &groups, &Arch::all(), 77);
    assert_eq!(r2.stats.collision_verifies, 0);
    assert!(r2.stats.memo_hits > 0);
    assert_eq!(r.results, r2.results);
}

/// Identical sweeps share the memo: the second run reports hits and
/// returns identical results.
#[test]
fn repeated_sweeps_hit_the_memo_and_stay_deterministic() {
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original, SweepGroup::Density(50)];
    let a = run_sweep(&models, &groups, &Arch::all(), 9);
    assert!(
        a.stats.memo_misses > 0,
        "a cold sweep must transform at least some vectors: {:?}",
        a.stats
    );
    let b = run_sweep(&models, &groups, &Arch::all(), 9);
    assert_eq!(a.results, b.results, "memo reuse must not change results");
    assert!(
        b.stats.memo_hits > 0,
        "an identical second sweep must hit the memo: {:?}",
        b.stats
    );
    assert!(b.stats.memo_hit_rate().unwrap() > 0.0);
}

/// The data-centric directive set equivalent to the fixed CoDR dataflow
/// must reproduce its SRAM-access and energy numbers **bit for bit**:
/// the mapping lowers to the same derived tile config and is priced by
/// the same walk. Pinned on every tiny-model conv plus the first conv of
/// each zoo net — notably alexnet conv1 (11×11 stride 4), the geometry
/// the `codr map` acceptance criterion names.
#[test]
fn baseline_directives_reproduce_fixed_dataflow_bit_for_bit() {
    use codr::mapping::{price_mapping, Mapping};

    let design = Codr::default();
    let mut rng = Rng::new(88);
    let mut specs: Vec<LayerSpec> = tiny_cnn().conv_layers().cloned().collect();
    for model in [alexnet(), vgg16(), googlenet()] {
        specs.push(model.conv_layers().next().expect("zoo model has convs").clone());
    }
    for spec in &specs {
        let w = synthesize_weights(spec, &mut rng);
        let mapping = Mapping::baseline(&design.cfg, spec);
        mapping
            .validate(spec, &design.cfg, &design.mem)
            .unwrap_or_else(|e| panic!("baseline mapping illegal on {}: {e}", spec.name));
        let direct = design.simulate_layer(spec, &w);
        let via_directives = price_mapping(&design, spec, &w, &mapping);
        // Headline axes called out explicitly, then the whole record.
        assert_eq!(via_directives.mem, direct.mem, "SRAM accesses {}", spec.name);
        assert_eq!(via_directives.energy, direct.energy, "energy {}", spec.name);
        assert_eq!(via_directives, direct, "full result {}", spec.name);
    }
}

/// Different seeds are different vectors — the memo must key strictly on
/// content, never collapse distinct weights.
#[test]
fn memo_never_aliases_different_seeds() {
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original];
    let a = run_sweep(&models, &groups, &Arch::all(), 101);
    let b = run_sweep(&models, &groups, &Arch::all(), 102);
    // Same grid shape, different weights: at least the compression of
    // some point must differ (the weights are random draws).
    let same = a
        .results
        .iter()
        .zip(&b.results)
        .all(|(x, y)| x.compression() == y.compression() && x.cycles() == y.cycles());
    assert!(!same, "distinct seeds produced identical sweeps");
    // And re-running seed 101 reproduces it exactly through the memo.
    let a2 = run_sweep(&models, &groups, &Arch::all(), 101);
    assert_eq!(a.results, a2.results);
}
