//! Numerical invariance of the optimized hot path.
//!
//! The zero-allocation / memoized / emission-free simulation pipeline
//! must produce **byte-for-byte identical** `LayerResult`s (mem, alu,
//! cycles, compression, energy) to the seed pipeline, which is kept
//! in-tree as `simulate_layer_reference` for each design. These tests
//! pin that equality on the tiny model across every sweep group, and
//! check the memo-driven `SweepStats` reporting.

use codr::baselines::{scnn, ucnn, Scnn, Ucnn};
use codr::codr::{dataflow, Codr};
use codr::coordinator::{run_sweep, Arch};
use codr::models::{
    alexnet, googlenet, synthesize_weights, tiny_cnn, vgg16, LayerSpec, SweepGroup, Workload,
};
use codr::sim::Accelerator;
use codr::util::rng::Rng;

/// One optimized-vs-oracle comparison for all three designs.
fn assert_all_archs_match(spec: &LayerSpec, w: &codr::tensor::Weights, ctx: &str) {
    let codr_design = Codr::default();
    let oracle = dataflow::simulate_layer_reference(&codr_design, spec, w);
    assert_eq!(codr_design.simulate_layer(spec, w), oracle, "CoDR {ctx}");

    let ucnn_design = Ucnn::default();
    let oracle = ucnn::simulate_layer_reference(&ucnn_design, spec, w);
    assert_eq!(ucnn_design.simulate_layer(spec, w), oracle, "UCNN {ctx}");

    let scnn_design = Scnn::default();
    let oracle = scnn::simulate_layer_reference(&scnn_design, spec, w);
    assert_eq!(scnn_design.simulate_layer(spec, w), oracle, "SCNN {ctx}");
}

/// Every design, every sweep group, every layer of the tiny model:
/// optimized == reference, both cold and memo-warm (each layer is
/// asserted twice via the helper's fresh calls plus the repeat below).
#[test]
fn optimized_layer_results_match_reference_on_tiny() {
    let model = tiny_cnn();
    for group in SweepGroup::all() {
        let (unique, density) = group.knobs();
        let wl = Workload::generate(&model, unique, density, 42);
        for (spec, w) in wl.conv_layers() {
            let ctx = format!("{} / {}", group.label(), spec.name);
            assert_all_archs_match(spec, w, &ctx);
            // And again, fully memo-warm.
            assert_all_archs_match(spec, w, &format!("warm {ctx}"));
        }
    }
}

/// Zoo geometry coverage: the tiny model never exercises 11×11-stride-4
/// tiling (alexnet conv1), 1×1 and 5×5 kernels (googlenet), or
/// VGG16-class channel counts. Pin one representative layer of each
/// kind per zoo model so a geometry-specific hot-path bug cannot hide
/// behind the tiny grid.
#[test]
fn optimized_layer_results_match_reference_across_zoo_geometries() {
    let mut rng = Rng::new(77);
    for model in [alexnet(), vgg16(), googlenet()] {
        let mut picked: Vec<&LayerSpec> = Vec::new();
        let convs: Vec<&LayerSpec> = model.conv_layers().collect();
        // First conv (largest kernel / stride of each net)…
        if let Some(&first) = convs.first() {
            picked.push(first);
        }
        // …plus the first layer of every distinct kernel size (1×1, 3×3,
        // 5×5, 7×7 across the zoo), bounded so the suite stays fast.
        for &spec in &convs {
            if picked.iter().all(|p| p.r_k != spec.r_k) && picked.len() < 4 {
                picked.push(spec);
            }
        }
        for spec in picked {
            let w = synthesize_weights(spec, &mut rng);
            assert_all_archs_match(spec, &w, &format!("{}/{}", model.name, spec.name));
        }
    }
}

/// Identical sweeps share the memo: the second run reports hits and
/// returns identical results.
#[test]
fn repeated_sweeps_hit_the_memo_and_stay_deterministic() {
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original, SweepGroup::Density(50)];
    let a = run_sweep(&models, &groups, &Arch::all(), 9);
    assert!(
        a.stats.memo_misses > 0,
        "a cold sweep must transform at least some vectors: {:?}",
        a.stats
    );
    let b = run_sweep(&models, &groups, &Arch::all(), 9);
    assert_eq!(a.results, b.results, "memo reuse must not change results");
    assert!(
        b.stats.memo_hits > 0,
        "an identical second sweep must hit the memo: {:?}",
        b.stats
    );
    assert!(b.stats.memo_hit_rate().unwrap() > 0.0);
}

/// Different seeds are different vectors — the memo must key strictly on
/// content, never collapse distinct weights.
#[test]
fn memo_never_aliases_different_seeds() {
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original];
    let a = run_sweep(&models, &groups, &Arch::all(), 101);
    let b = run_sweep(&models, &groups, &Arch::all(), 102);
    // Same grid shape, different weights: at least the compression of
    // some point must differ (the weights are random draws).
    let same = a
        .results
        .iter()
        .zip(&b.results)
        .all(|(x, y)| x.compression() == y.compression() && x.cycles() == y.cycles());
    assert!(!same, "distinct seeds produced identical sweeps");
    // And re-running seed 101 reproduces it exactly through the memo.
    let a2 = run_sweep(&models, &groups, &Arch::all(), 101);
    assert_eq!(a.results, a2.results);
}
