//! Tier-1 gate for `codr analyze`: the tree itself must be clean, and
//! every check must still fire on its known-bad fixture. The first half
//! is the contract the CI deny-findings step enforces; the second half
//! is the proof the analyzer has not gone quietly blind — a check that
//! stops firing on its fixture would otherwise look exactly like a
//! clean tree.

use codr::analysis::{analyze_source, analyze_tree, default_src_root, Finding};

fn checks(fs: &[Finding]) -> Vec<&'static str> {
    fs.iter().map(|f| f.check).collect()
}

// ------------------------------------------------------------- the tree

/// The repository's own source is clean: zero findings, and every waiver
/// in the tree is honored (an unused or malformed waiver is itself a
/// finding, so a clean report also means zero unexplained waivers).
#[test]
fn tree_is_clean() {
    let root = default_src_root();
    let report = analyze_tree(&root).expect("analyze_tree");
    assert!(
        report.files > 15,
        "suspiciously few files under {}: {}",
        root.display(),
        report.files
    );
    assert!(
        report.waivers_used >= 1,
        "the tree carries justified waivers; honoring none means waiver \
         matching broke"
    );
    assert!(
        report.is_clean(),
        "static analysis found violations:\n{}",
        report.render()
    );
}

// ------------------------------------------------- per-check known-bads

#[test]
fn lock_order_inversion_fires() {
    let bad = "\
impl S {
    fn f(&self) {
        let s = self.shard.lock();
        let j = self.jobs.lock();
    }
}
";
    let fs = analyze_source("reuse/memo.rs", bad);
    assert_eq!(checks(&fs), vec!["lock_order"], "{fs:?}");
    assert_eq!((fs[0].file.as_str(), fs[0].line), ("reuse/memo.rs", 4));
    assert!(fs[0].message.contains("inversion"), "{}", fs[0].message);

    // The same locks in hierarchy order are legal.
    let good = "\
impl S {
    fn f(&self) {
        let j = self.jobs.lock();
        let s = self.shard.lock();
    }
}
";
    assert!(analyze_source("reuse/memo.rs", good).is_empty());
}

#[test]
fn relaxed_on_control_flag_fires() {
    let bad = "fn f(s: &S) {\n    s.stop.store(true, Ordering::Relaxed);\n}\n";
    let fs = analyze_source("serve/server.rs", bad);
    assert_eq!(checks(&fs), vec!["atomics"], "{fs:?}");
    assert_eq!((fs[0].file.as_str(), fs[0].line), ("serve/server.rs", 2));

    // An allowlisted striped counter in its home file is silent…
    let counter = "fn f(s: &S) {\n    s.l2_hits.fetch_add(1, Ordering::Relaxed);\n}\n";
    assert!(analyze_source("reuse/memo.rs", counter).is_empty());
    // …but the same receiver name outside that file still fires: the
    // allowlist is (file, atomic) pairs, not bare names.
    assert_eq!(checks(&analyze_source("serve/server.rs", counter)), vec!["atomics"]);
}

#[test]
fn panics_in_no_panic_zones_fire() {
    let bad = "fn f() {\n    let v = x.parse().unwrap();\n    panic!(\"boom\");\n}\n";
    let fs = analyze_source("serve/scheduler.rs", bad);
    assert_eq!(checks(&fs), vec!["panic_policy", "panic_policy"], "{fs:?}");
    assert_eq!(fs[0].line, 2);
    assert_eq!(fs[1].line, 3);

    // The same source outside the no-panic zones is out of scope.
    assert!(analyze_source("sim/mod.rs", bad).is_empty());
    // #[cfg(test)] code inside the zone is exempt.
    let test_only = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
    assert!(analyze_source("serve/server.rs", test_only).is_empty());
}

#[test]
fn uncovered_durability_edge_fires() {
    let bad = "fn publish(a: &Path, b: &Path) {\n    std::fs::rename(a, b).ok();\n}\n";
    let fs = analyze_source("serve/newfile.rs", bad);
    assert_eq!(checks(&fs), vec!["fault_seams"], "{fs:?}");
    assert_eq!(fs[0].line, 2);
    assert!(fs[0].message.contains("fs::rename"), "{}", fs[0].message);

    // A faults:: seam anywhere in the same function covers the edge.
    let good = "\
fn publish(a: &Path, b: &Path) {
    crate::faults::sleep_point(\"publish.pre\");
    std::fs::rename(a, b).ok();
}
";
    assert!(analyze_source("serve/newfile.rs", good).is_empty());

    // create_new is the other durability edge shape.
    let create = "fn g(p: &Path) {\n    OpenOptions::new().create_new(true).open(p).ok();\n}\n";
    assert_eq!(checks(&analyze_source("serve/newfile.rs", create)), vec!["fault_seams"]);
}

#[test]
fn env_registry_checks_fire() {
    // An unregistered CODR_* literal plus a direct std::env read: the
    // name must be registered AND the read must route through
    // analysis::env_registry::var, so both findings fire on one line.
    let bad = "fn f() -> Option<String> {\n    std::env::var(\"CODR_UNREGISTERED_THING\").ok()\n}\n";
    let fs = analyze_source("serve/newfile.rs", bad);
    assert_eq!(checks(&fs), vec!["env_registry", "env_registry"], "{fs:?}");
    assert!(fs.iter().all(|f| f.line == 2));
    assert!(fs.iter().any(|f| f.message.contains("not in")), "{fs:?}");
    assert!(fs.iter().any(|f| f.message.contains("route through")), "{fs:?}");

    // A registered name read directly still fires the routing check.
    let direct = "fn f() { std::env::var(\"CODR_STORE\").ok(); }\n";
    let fs = analyze_source("serve/newfile.rs", direct);
    assert_eq!(checks(&fs), vec!["env_registry"]);
    assert!(fs[0].message.contains("route through"));

    // The sanctioned path is silent.
    let routed = "fn f() { crate::analysis::env_registry::var(\"CODR_STORE\"); }\n";
    assert!(analyze_source("serve/newfile.rs", routed).is_empty());
}

// --------------------------------------------------------------- waivers

#[test]
fn waivers_silence_and_stay_honest() {
    // A justified waiver on the line above silences exactly its check.
    let waived = "\
fn f() {
    // analyze: allow(panic_policy): fixture — fires without this line
    x.unwrap();
}
";
    assert!(analyze_source("serve/x.rs", waived).is_empty());

    // An unused waiver is a finding, not a no-op.
    let unused = "// analyze: allow(atomics): nothing here uses atomics\nfn f() {}\n";
    let fs = analyze_source("sim/x.rs", unused);
    assert_eq!(checks(&fs), vec!["waiver"], "{fs:?}");
    assert!(fs[0].message.contains("unused"));

    // A malformed waiver (no reason) is reported AND the violation it
    // meant to cover still fires — a typo never disables a check.
    let malformed = "fn f() {\n    // analyze: allow(panic_policy)\n    x.unwrap();\n}\n";
    let fs = analyze_source("serve/x.rs", malformed);
    assert!(fs.iter().any(|f| f.check == "waiver"), "{fs:?}");
    assert!(fs.iter().any(|f| f.check == "panic_policy"), "{fs:?}");
}
