//! Integration tests for the result store + cached sweep path: the PR's
//! acceptance loop — warm the store, re-run, observe zero simulation and
//! byte-identical figure text — plus corruption fallback end to end.

use codr::arch::MemConfig;
use codr::coordinator::{run_sweep, run_sweep_with, Arch};
use codr::models::{tiny_cnn, SweepGroup};
use codr::report::headline_report;
use codr::serve::{CacheKey, LoadOutcome, ResultStore};
use std::path::PathBuf;

/// Unique per-test store dir under the system temp dir (no `tempfile`
/// crate offline).
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("codr-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn warm_store_serves_figures_without_simulating() {
    let dir = temp_dir("warmfig");
    let store = ResultStore::open(&dir).unwrap();
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original, SweepGroup::Density(50)];

    // Cold run: everything simulates, everything persists.
    let cold = run_sweep_with(&models, &groups, &Arch::all(), 42, Some(&store));
    assert_eq!(cold.stats.requested, 6);
    assert_eq!(cold.stats.computed, 6);
    assert_eq!(cold.stats.cache_hits, 0);
    assert!(cold.stats.simulated_layers > 0);
    assert_eq!(store.len(), 6);

    // Packed layout: 6 points across 2 (model, group, seed) packs means
    // exactly 2 files on disk, not 6.
    let stats = store.stats();
    assert_eq!(stats.packed_files, 2, "{stats:?}");
    assert_eq!(stats.v1_files, 0, "{stats:?}");
    assert_eq!(stats.entries, 6, "{stats:?}");

    // Warm run: zero simulate_layer calls, per the sweep stats.
    let warm = run_sweep_with(&models, &groups, &Arch::all(), 42, Some(&store));
    assert_eq!(warm.stats.cache_hits, 6);
    assert_eq!(warm.stats.computed, 0);
    assert_eq!(
        warm.stats.simulated_layers, 0,
        "a fully warm store must not simulate any layer"
    );

    // The cached sweep is indistinguishable from a storeless one: same
    // results in the same order, and byte-identical figure text.
    let fresh = run_sweep(&models, &groups, &Arch::all(), 42);
    assert_eq!(fresh.results, warm.results);
    let fresh_text = headline_report(&fresh, &["tiny"]).unwrap();
    let warm_text = headline_report(&warm, &["tiny"]).unwrap();
    assert_eq!(fresh_text, warm_text);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_packs_and_entries_recompute_instead_of_crashing() {
    let dir = temp_dir("corrupt");
    let store = ResultStore::open(&dir).unwrap();
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original];

    let cold = run_sweep_with(&models, &groups, &Arch::all(), 7, Some(&store));
    assert_eq!(cold.stats.computed, 3);

    let key = CacheKey::for_point(
        "tiny",
        &SweepGroup::Original,
        Arch::Codr.name(),
        &Arch::Codr.build().tile_config(),
        &MemConfig::default(),
        7,
    );
    let path = store.pack_path_for(&key);
    assert!(path.exists(), "cold run must have persisted the pack");

    // File-level vandalism (truncation, garbage, an empty file) takes
    // the whole pack down: all three entries degrade to Corrupt and
    // recompute — and the recompute heals the pack.
    let original = std::fs::read_to_string(&path).unwrap();
    for vandalism in [&original[..original.len() / 3], "}{ not json", ""] {
        std::fs::write(&path, vandalism).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));
        let rerun = run_sweep_with(&models, &groups, &Arch::all(), 7, Some(&store));
        assert_eq!(rerun.stats.corrupt, 3, "the whole pack is one unit of damage");
        assert_eq!(rerun.stats.computed, 3);
        assert_eq!(rerun.stats.cache_hits, 0);
        assert_eq!(rerun.results, cold.results, "recompute restores the data");
        assert!(matches!(store.load(&key), LoadOutcome::Hit(_)));
    }

    // Entry-level vandalism (flip one entry's check hash; the file stays
    // valid JSON): only that entry recomputes, its siblings stay hits.
    let healed = std::fs::read_to_string(&path).unwrap();
    let pos = healed.find("\"check\":").unwrap() + "\"check\":".len();
    let mut bytes = healed.into_bytes();
    bytes[pos] = if bytes[pos] == b'9' { b'1' } else { b'9' };
    std::fs::write(&path, &bytes).unwrap();
    let rerun = run_sweep_with(&models, &groups, &Arch::all(), 7, Some(&store));
    assert_eq!(rerun.stats.corrupt, 1, "one damaged entry detected");
    assert_eq!(rerun.stats.computed, 1, "only the damaged entry recomputes");
    assert_eq!(rerun.stats.cache_hits, 2, "siblings in the pack survive");
    assert_eq!(rerun.results, cold.results);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn v1_store_migrates_to_packed_v2_with_hits_not_recomputes() {
    let dir = temp_dir("v1migrate");
    let store = ResultStore::open(&dir).unwrap();
    let models = [tiny_cnn()];
    let groups = [SweepGroup::Original];

    // Seed a legacy v1-format store: one single-point file per arch,
    // exactly what a pre-v2 binary (or CODR_STORE_WRITE_V1=1) leaves.
    let fresh = run_sweep(&models, &groups, &Arch::all(), 7);
    for arch in Arch::all() {
        let key = CacheKey::for_point(
            "tiny",
            &SweepGroup::Original,
            arch.name(),
            &arch.build().tile_config(),
            &MemConfig::default(),
            7,
        );
        let result = fresh.get("tiny", SweepGroup::Original, arch).unwrap();
        store.save_v1(&key, result).unwrap();
    }
    let before = store.stats();
    assert_eq!((before.v1_files, before.packed_files), (3, 0));

    // A warm run over the v1 store: every point HITS (no recompute — the
    // key fingerprints are unchanged across the format bump) and the
    // directory converges to packed v2 files.
    let warm = run_sweep_with(&models, &groups, &Arch::all(), 7, Some(&store));
    assert_eq!(warm.stats.cache_hits, 3, "{:?}", warm.stats);
    assert_eq!(warm.stats.computed, 0, "{:?}", warm.stats);
    assert_eq!(warm.stats.simulated_layers, 0);
    assert_eq!(warm.results, fresh.results, "migrated data is bit-identical");
    let after = store.stats();
    assert_eq!(
        (after.v1_files, after.packed_files, after.entries),
        (0, 1, 3),
        "read-through migration must converge the directory"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_round_trips_every_result_type_field() {
    // Round-trip through disk (not just the in-memory codec): pick the
    // arch with the richest stats (CoDR uses low-precision mults and the
    // crossbar) and demand full equality after a save/load cycle.
    let dir = temp_dir("roundtrip");
    let store = ResultStore::open(&dir).unwrap();
    let models = [tiny_cnn()];
    let cold = run_sweep_with(&models, &[SweepGroup::Unique(16)], &[Arch::Codr], 3, Some(&store));
    let key = CacheKey::for_point(
        "tiny",
        &SweepGroup::Unique(16),
        Arch::Codr.name(),
        &Arch::Codr.build().tile_config(),
        &MemConfig::default(),
        3,
    );
    match store.load(&key) {
        LoadOutcome::Hit(r) => {
            let orig = &cold.results[0];
            assert_eq!(*r, *orig);
            // Spot-check the derived metrics flow through unchanged.
            assert_eq!(r.cycles(), orig.cycles());
            assert_eq!(r.mem(), orig.mem());
            assert_eq!(r.alu(), orig.alu());
            assert_eq!(r.compression(), orig.compression());
            assert_eq!(r.energy().total_uj().to_bits(), orig.energy().total_uj().to_bits());
        }
        other => panic!("expected hit, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two independently-opened store handles over one directory — the
/// in-process model of two *processes* sharing a store (each handle has
/// its own save mutex, so nothing in-process serializes them) — save
/// interleaved entries into the same pack concurrently. The advisory
/// pack file lock must make the read-modify-writes merge: every entry
/// survives, none is lost to a last-writer-wins rewrite.
#[test]
fn two_store_handles_merge_concurrent_saves_into_one_pack() {
    let dir = temp_dir("multiwriter");
    let a = ResultStore::open(&dir).unwrap();
    let b = ResultStore::open(&dir).unwrap();

    // One real result reused for every entry; the identities differ by
    // fingerprint (same (model, group, seed) → same pack file).
    fn key_for(i: u64) -> CacheKey {
        CacheKey {
            model: "tiny".into(),
            group: "Orig".into(),
            arch: format!("W{i}"),
            seed: 3,
            fingerprint: 0xbeef_0000 + i,
        }
    }
    const N: u64 = 16;
    let fresh = run_sweep(&[tiny_cnn()], &[SweepGroup::Original], &[Arch::Codr], 3);
    let result = fresh.results[0].clone();

    let spawn_writer = |store: ResultStore, result: codr::sim::ModelResult, offset: u64| {
        std::thread::spawn(move || {
            for i in (offset..N).step_by(2) {
                store.save(&key_for(i), &result).unwrap();
            }
        })
    };
    let ta = spawn_writer(a.clone(), result.clone(), 0);
    let tb = spawn_writer(b.clone(), result.clone(), 1);
    ta.join().unwrap();
    tb.join().unwrap();

    // Merge, not clobber: all 16 entries are present and loadable.
    for i in 0..N {
        assert!(
            matches!(a.load(&key_for(i)), LoadOutcome::Hit(_)),
            "entry {i} lost to a concurrent rewrite"
        );
    }
    let stats = a.stats();
    assert_eq!(stats.entries, N as usize, "{stats:?}");
    assert_eq!(stats.packed_files, 1, "one shared pack: {stats:?}");
    // And no lock or temp files survive the writers.
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|f| f.contains(".tmp-") || f.contains(".lock"))
        .collect();
    assert!(leftovers.is_empty(), "{leftovers:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn seed_and_group_isolate_cache_entries() {
    let dir = temp_dir("isolate");
    let store = ResultStore::open(&dir).unwrap();
    let models = [tiny_cnn()];

    run_sweep_with(&models, &[SweepGroup::Original], &[Arch::Codr], 1, Some(&store));
    // Different seed: distinct point, no false hit.
    let other_seed =
        run_sweep_with(&models, &[SweepGroup::Original], &[Arch::Codr], 2, Some(&store));
    assert_eq!(other_seed.stats.cache_hits, 0);
    // Different group: likewise.
    let other_group =
        run_sweep_with(&models, &[SweepGroup::Density(25)], &[Arch::Codr], 1, Some(&store));
    assert_eq!(other_group.stats.cache_hits, 0);
    // Original point still hits.
    let again = run_sweep_with(&models, &[SweepGroup::Original], &[Arch::Codr], 1, Some(&store));
    assert_eq!(again.stats.cache_hits, 1);
    assert_eq!(store.len(), 3);

    let _ = std::fs::remove_dir_all(&dir);
}
