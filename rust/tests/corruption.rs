//! Property-style corruption sweeps over the two on-disk caches — packed
//! result files and the vector-memo snapshot. Seeded bit flips and
//! truncations at arbitrary offsets must *degrade* (the damaged entry
//! recomputes) — never panic, and never serve data that differs from a
//! clean computation. A broken cache can cost time, never correctness.

use codr::arch::MemConfig;
use codr::coordinator::{run_sweep_with, Arch};
use codr::models::{tiny_cnn, SweepGroup};
use codr::reuse::memo::{VectorCache, DEFAULT_SNAPSHOT_CAP_BYTES};
use codr::serve::{CacheKey, LoadOutcome, ResultStore};
use codr::sim::{Accelerator, ModelResult};
use codr::util::rng::Rng;
use std::path::PathBuf;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("codr-corrupt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The store keys of the tiny × Orig × all-archs grid.
fn grid_keys(seed: u64) -> Vec<CacheKey> {
    Arch::all()
        .iter()
        .map(|arch| {
            CacheKey::for_point(
                "tiny",
                &SweepGroup::Original,
                arch.name(),
                &arch.build().tile_config(),
                &MemConfig::default(),
                seed,
            )
        })
        .collect()
}

/// Populate a store with the grid, return its per-key baseline results.
fn warm_baseline(dir: &PathBuf, seed: u64) -> Vec<Box<ModelResult>> {
    let store = ResultStore::open(dir).expect("open store");
    run_sweep_with(
        &[tiny_cnn()],
        &[SweepGroup::Original],
        &Arch::all(),
        seed,
        Some(&store),
    );
    grid_keys(seed)
        .iter()
        .map(|k| match store.load(k) {
            LoadOutcome::Hit(r) => r,
            other => panic!("baseline must hit, got {other:?}"),
        })
        .collect()
}

fn pack_files(dir: &PathBuf) -> Vec<PathBuf> {
    let packs: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("read store dir")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.to_string_lossy().ends_with(".pack.json"))
        .collect();
    assert!(!packs.is_empty(), "warmed store must hold a pack file");
    packs
}

/// Damage the store with `mangle`, then check every key either hits with
/// the exact baseline result or degrades — counting the degrades.
fn check_loads(
    dir: &PathBuf,
    keys: &[CacheKey],
    baseline: &[Box<ModelResult>],
    degraded: &mut usize,
) {
    let store = ResultStore::open(dir).expect("reopen damaged store");
    for (k, base) in keys.iter().zip(baseline) {
        match store.load(k) {
            LoadOutcome::Hit(r) => {
                assert_eq!(&r, base, "damage must never alter a served result");
            }
            LoadOutcome::Miss | LoadOutcome::Corrupt => *degraded += 1,
        }
    }
}

/// After the damage trials, one sweep over the store must recompute the
/// casualties and restore every key to its baseline value.
fn check_heals(dir: &PathBuf, seed: u64, keys: &[CacheKey], baseline: &[Box<ModelResult>]) {
    let store = ResultStore::open(dir).expect("reopen for healing");
    let results = run_sweep_with(
        &[tiny_cnn()],
        &[SweepGroup::Original],
        &Arch::all(),
        seed,
        Some(&store),
    );
    assert_eq!(results.stats.requested, 3);
    assert_eq!(results.stats.failed, 0);
    assert_eq!(
        results.stats.cache_hits + results.stats.computed,
        3,
        "{:?}",
        results.stats
    );
    for (k, base) in keys.iter().zip(baseline) {
        match store.load(k) {
            LoadOutcome::Hit(r) => assert_eq!(&r, base, "healed entry must match baseline"),
            other => panic!("store must heal under a sweep, got {other:?}"),
        }
    }
}

#[test]
fn pack_bit_flips_never_panic_and_never_serve_wrong_data() {
    let dir = temp_dir("packflip");
    let seed = 13;
    let baseline = warm_baseline(&dir, seed);
    let keys = grid_keys(seed);
    let packs = pack_files(&dir);
    let clean: Vec<Vec<u8>> = packs.iter().map(|p| std::fs::read(p).unwrap()).collect();

    let mut rng = Rng::new(0xC0D2);
    let mut degraded = 0usize;
    for _trial in 0..64 {
        for (p, bytes) in packs.iter().zip(&clean) {
            let mut bent = bytes.clone();
            let bit = rng.below(bent.len() as u64 * 8);
            bent[(bit / 8) as usize] ^= 1 << (bit % 8);
            std::fs::write(p, &bent).unwrap();
        }
        check_loads(&dir, &keys, &baseline, &mut degraded);
    }
    // Structural chars, checksum digits, payload — wherever the flip
    // lands, at least some trials must detect damage; zero means the
    // verification chain is dead.
    assert!(degraded > 0, "no flip was ever detected");

    check_heals(&dir, seed, &keys, &baseline);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pack_truncations_never_panic_and_never_serve_wrong_data() {
    let dir = temp_dir("packtrunc");
    let seed = 17;
    let baseline = warm_baseline(&dir, seed);
    let keys = grid_keys(seed);
    let packs = pack_files(&dir);
    let clean: Vec<Vec<u8>> = packs.iter().map(|p| std::fs::read(p).unwrap()).collect();

    let mut rng = Rng::new(0x7A11);
    let mut degraded = 0usize;
    for _trial in 0..64 {
        for (p, bytes) in packs.iter().zip(&clean) {
            let mut bent = bytes.clone();
            // Keep a strict prefix, down to and including zero bytes —
            // what a crash mid-write (or mid-`ftruncate`) leaves behind.
            bent.truncate(rng.below(bent.len() as u64) as usize);
            std::fs::write(p, &bent).unwrap();
        }
        check_loads(&dir, &keys, &baseline, &mut degraded);
    }
    assert!(degraded > 0, "no truncation was ever detected");

    check_heals(&dir, seed, &keys, &baseline);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Memo snapshot damage: every entry is length-framed and checksummed,
/// so a flipped bit costs one entry (or the frame tail) on restore —
/// and a restored cache must transform every vector exactly like a
/// clean one (a corrupt entry may vanish, never alias to wrong bytes).
#[test]
fn snapshot_damage_degrades_to_cold_entries_never_wrong_transforms() {
    let dir = temp_dir("snapshot");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("memo.snapshot");

    // Populate a private cache with seeded weight vectors, snapshot it.
    let reference = VectorCache::with_capacity(4096);
    let mut gen = Rng::new(7);
    let mut vectors: Vec<Vec<i8>> = Vec::new();
    for _ in 0..40 {
        let n = 4 + gen.below(60) as usize;
        let v: Vec<i8> = (0..n).map(|_| (gen.below(17) as i64 - 8) as i8).collect();
        reference.get_or_insert(&v);
        vectors.push(v);
    }
    reference
        .save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES)
        .expect("save snapshot");
    let clean = std::fs::read(&path).unwrap();

    let mut rng = Rng::new(0xBADC0DE);
    let mut restored_total = 0usize;
    for trial in 0..64 {
        let mut bent = clean.clone();
        if trial % 2 == 0 {
            let bit = rng.below(bent.len() as u64 * 8);
            bent[(bit / 8) as usize] ^= 1 << (bit % 8);
        } else {
            bent.truncate(rng.below(bent.len() as u64) as usize);
        }
        std::fs::write(&path, &bent).unwrap();

        let restored = VectorCache::with_capacity(4096);
        // Damage degrades: fewer entries or a clean error — never a
        // panic, never more entries than were saved.
        let loaded = restored.load_snapshot(&path).unwrap_or(0);
        assert!(loaded <= vectors.len(), "{loaded} entries from 40 saved");
        restored_total += loaded;
        // Whatever survived must transform identically: each lookup is
        // either a restored hit or a fresh recompute, and both must
        // equal the clean reference.
        for v in &vectors {
            assert_eq!(
                restored.get_or_insert(v).ucr,
                reference.get_or_insert(v).ucr,
                "a damaged snapshot must never alias to a wrong transform"
            );
        }
    }
    // Per-entry framing: most single-bit flips cost one entry, not the
    // whole snapshot.
    assert!(restored_total > 0, "every damaged snapshot restored nothing");

    let _ = std::fs::remove_dir_all(&dir);
}
