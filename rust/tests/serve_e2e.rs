//! End-to-end test of the sweep service: a real `codr serve` server on an
//! ephemeral localhost port, driven through the line-delimited JSON
//! protocol exactly as the `codr submit` / `codr warm` clients drive it.

use codr::arch::MemConfig;
use codr::coordinator::Arch;
use codr::models::SweepGroup;
use codr::serve::{proto, CacheKey, LoadOutcome, ResultStore, Server};
use codr::util::json::Json;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("codr-serve-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

#[test]
fn serve_submit_status_result_warm_shutdown() {
    let dir = temp_dir("full");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    // ping
    let pong = proto::request(&addr, &obj(&[("verb", Json::str("ping"))])).unwrap();
    assert!(ok(&pong), "{pong}");

    // warm a tiny grid synchronously: 1 model × 1 group × 3 archs.
    let warm_req = obj(&[
        ("verb", Json::str("warm")),
        ("models", Json::str("tiny")),
        ("groups", Json::str("Orig")),
        ("seed", Json::u64(5)),
    ]);
    let first = proto::request(&addr, &warm_req).unwrap();
    assert!(ok(&first), "{first}");
    let stats = first.get("stats").unwrap();
    assert_eq!(stats.get("requested").unwrap().as_u64().unwrap(), 3);
    assert_eq!(stats.get("computed").unwrap().as_u64().unwrap(), 3);
    assert_eq!(stats.get("cache_hits").unwrap().as_u64().unwrap(), 0);

    // Second warm of the same grid: all hits, zero simulated layers.
    let second = proto::request(&addr, &warm_req).unwrap();
    assert!(ok(&second), "{second}");
    let stats = second.get("stats").unwrap();
    assert_eq!(stats.get("cache_hits").unwrap().as_u64().unwrap(), 3);
    assert_eq!(stats.get("computed").unwrap().as_u64().unwrap(), 0);
    assert_eq!(stats.get("simulated_layers").unwrap().as_u64().unwrap(), 0);

    // result: a warmed point answers from the store.
    let res = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("result")),
            ("model", Json::str("tiny")),
            ("group", Json::str("Orig")),
            ("arch", Json::str("CoDR")),
            ("seed", Json::u64(5)),
        ]),
    )
    .unwrap();
    assert!(ok(&res), "{res}");
    assert!(res.get("cycles").unwrap().as_u64().unwrap() > 0);
    assert!(res.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);

    // result for a point never warmed: clean protocol error.
    let missing = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("result")),
            ("model", Json::str("tiny")),
            ("group", Json::str("D=25%")),
            ("arch", Json::str("SCNN")),
            ("seed", Json::u64(5)),
        ]),
    )
    .unwrap();
    assert!(!ok(&missing), "{missing}");
    assert!(missing.get("error").unwrap().as_str().unwrap().contains("not in store"));

    // submit: async job over a new group, polled to completion.
    let submitted = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str("D=50%")),
            ("seed", Json::u64(5)),
        ]),
    )
    .unwrap();
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").unwrap().as_u64().unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let final_stats = loop {
        assert!(Instant::now() < deadline, "job {job} never finished");
        let status = proto::request(
            &addr,
            &obj(&[("verb", Json::str("status")), ("job", Json::u64(job))]),
        )
        .unwrap();
        assert!(ok(&status), "{status}");
        match status.get("state").unwrap().as_str().unwrap() {
            "running" => std::thread::sleep(Duration::from_millis(50)),
            "done" => break status.get("stats").unwrap().clone(),
            other => panic!("job entered state {other}: {status}"),
        }
    };
    assert_eq!(final_stats.get("requested").unwrap().as_u64().unwrap(), 3);

    // Unknown verbs and malformed grids answer, not crash.
    let bad = proto::request(&addr, &obj(&[("verb", Json::str("frobnicate"))])).unwrap();
    assert!(!ok(&bad));
    let bad_model = proto::request(
        &addr,
        &obj(&[("verb", Json::str("warm")), ("models", Json::str("resnet"))]),
    )
    .unwrap();
    assert!(!ok(&bad_model));
    assert!(bad_model.get("error").unwrap().as_str().unwrap().contains("unknown model"));

    // Server-wide status sees the job table and the populated store —
    // both the legacy flat counter and the structured v2 objects.
    let status = proto::request(&addr, &obj(&[("verb", Json::str("status"))])).unwrap();
    assert!(ok(&status), "{status}");
    assert_eq!(status.get("jobs").unwrap().as_u64().unwrap(), 1);
    assert_eq!(status.get("store_entries").unwrap().as_u64().unwrap(), 6);
    let st = status.get("store").unwrap();
    assert_eq!(st.get("entries").unwrap().as_u64().unwrap(), 6);
    // 6 points over 2 (model, group, seed) packs → 2 packed files, no v1.
    assert_eq!(st.get("packed_files").unwrap().as_u64().unwrap(), 2);
    assert_eq!(st.get("v1_files").unwrap().as_u64().unwrap(), 0);
    assert!(st.get("bytes").unwrap().as_u64().unwrap() > 0);
    assert_eq!(st.get("cap_bytes").unwrap(), &Json::Null);
    let memo = status.get("memo").unwrap();
    for field in [
        "entries",
        "hits",
        "misses",
        "evictions",
        "lookups",
        "l1_hits",
        "l2_hits",
        "collision_verifies",
        "double_computes",
        "lock_waits",
    ] {
        assert!(memo.get(field).unwrap().as_u64().is_ok(), "{status}");
    }
    // Real workloads never collide in the 128-bit fingerprint space.
    assert_eq!(
        memo.get("collision_verifies").unwrap().as_u64().unwrap(),
        0,
        "{status}"
    );
    let arena = memo.get("arena").unwrap();
    assert!(arena.get("entries").unwrap().as_u64().is_ok(), "{status}");
    assert!(arena.get("bytes").unwrap().as_u64().is_ok(), "{status}");
    // The warmed grid interned vectors: the arena is populated.
    assert!(arena.get("entries").unwrap().as_u64().unwrap() > 0, "{status}");

    // shutdown stops the accept loop; run() returns cleanly.
    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye), "{bye}");
    handle.join().unwrap().unwrap();

    let _ = std::fs::remove_dir_all(&dir);
}

/// The `watch` verb end to end: one `point` event per completed sweep
/// point with a strictly increasing `done` counter, a terminal `end`
/// whose stats equal the job's final `status` stats, and a byte-for-byte
/// identical replay for a watcher that attaches after the job finished.
#[test]
fn watch_streams_ordered_events_and_end_stats_match_status() {
    let dir = temp_dir("watch");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    // 1 model × 2 groups × 3 archs = 6 points, all cold.
    let submitted = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str("Orig,D=50%")),
            ("seed", Json::u64(31)),
        ]),
    )
    .unwrap();
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").unwrap().as_u64().unwrap();

    let mut events = Vec::new();
    let end = proto::watch(&addr, job, |ev| events.push(ev.clone())).unwrap();
    let points: Vec<&Json> = events
        .iter()
        .filter(|e| matches!(e.get("event").map(|v| v.as_str()), Some(Ok("point"))))
        .collect();
    assert_eq!(points.len(), 6, "one event per sweep point: {events:?}");
    for (i, ev) in points.iter().enumerate() {
        assert_eq!(ev.get("job").unwrap().as_u64().unwrap(), job);
        assert_eq!(
            ev.get("done").unwrap().as_u64().unwrap(),
            i as u64 + 1,
            "done must increase strictly in stream order: {ev}"
        );
        assert_eq!(ev.get("total").unwrap().as_u64().unwrap(), 6);
        assert_eq!(ev.get("model").unwrap().as_str().unwrap(), "tiny");
        assert!(ev.get("group").is_some() && ev.get("arch").is_some());
        assert!(ev.get("cache_hit").unwrap().as_bool().is_ok());
    }
    // The last event of the stream is the end, and its stats equal what
    // `status` reports for the finished job.
    assert_eq!(events.last().unwrap(), &end);
    let end_stats = end.get("stats").expect("end carries stats").clone();
    assert_eq!(end_stats.get("requested").unwrap().as_u64().unwrap(), 6);
    let status = proto::request(
        &addr,
        &obj(&[("verb", Json::str("status")), ("job", Json::u64(job))]),
    )
    .unwrap();
    assert_eq!(status.get("state").unwrap().as_str().unwrap(), "done");
    assert_eq!(status.get("stats").unwrap(), &end_stats);

    // A late watcher replays the identical sequence.
    let mut replay = Vec::new();
    let end2 = proto::watch(&addr, job, |ev| replay.push(ev.clone())).unwrap();
    assert_eq!(replay, events, "late watch must replay the same history");
    assert_eq!(end2, end);

    // Watching a job that was never issued is a clean protocol error.
    assert!(proto::watch(&addr, 4242, |_| {}).is_err());

    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye));
    handle.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `shutdown` right after `submit`: the drain lets the job finish, its
/// results are persisted before `run()` returns, a watcher attached
/// across the shutdown still receives the real terminal `end` (with
/// stats, not an abort error), and no temp files leak.
#[test]
fn shutdown_drains_running_jobs_and_persists_results() {
    let dir = temp_dir("drain");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    let submitted = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str("D=25%")),
            ("seed", Json::u64(17)),
        ]),
    )
    .unwrap();
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").unwrap().as_u64().unwrap();

    // Attach a watcher on a raw stream (ack read before shutdown, so the
    // stream provably spans the drain window).
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).ok();
    let mut w = stream.try_clone().unwrap();
    let mut r = std::io::BufReader::new(stream);
    proto::write_message(
        &mut w,
        &obj(&[("verb", Json::str("watch")), ("job", Json::u64(job))]),
    )
    .unwrap();
    let ack = proto::read_message(&mut r).unwrap().unwrap();
    assert!(ok(&ack), "{ack}");

    // Shutdown immediately — almost certainly while the job still runs.
    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye), "{bye}");

    // The watcher stream still terminates with a real end event.
    let end = loop {
        let ev = proto::read_message(&mut r)
            .unwrap()
            .expect("stream must end with an end event, not EOF");
        if matches!(ev.get("event").map(|v| v.as_str()), Some(Ok("end"))) {
            break ev;
        }
    };
    assert!(
        end.get("stats").is_some(),
        "drained job must end with stats, not an abort: {end}"
    );

    // run() returned only after the drain: the job's points are on disk.
    handle.join().unwrap().unwrap();
    let store = ResultStore::open(&dir).unwrap();
    for arch in Arch::all() {
        let key = CacheKey::for_point(
            "tiny",
            &SweepGroup::Density(25),
            arch.name(),
            &arch.build().tile_config(),
            &MemConfig::default(),
            17,
        );
        assert!(
            matches!(store.load(&key), LoadOutcome::Hit(_)),
            "drain must persist {} before exit",
            arch.name()
        );
    }
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.contains(".tmp-") || n.contains(".lock"))
        .collect();
    assert!(leftovers.is_empty(), "{leftovers:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_clients_share_one_computation() {
    let dir = temp_dir("concurrent");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    // Four clients warm the identical grid at once; the in-flight dedup
    // must keep total computed points at exactly 3 (one per arch).
    let mut clients = Vec::new();
    for _ in 0..4 {
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || {
            let resp = proto::request(
                &addr,
                &obj(&[
                    ("verb", Json::str("warm")),
                    ("models", Json::str("tiny")),
                    ("groups", Json::str("Orig")),
                    ("seed", Json::u64(9)),
                ]),
            )
            .unwrap();
            assert!(ok(&resp), "{resp}");
            let stats = resp.get("stats").unwrap();
            stats.get("computed").unwrap().as_u64().unwrap()
        }));
    }
    let total: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert_eq!(total, 3, "each point must be simulated exactly once");

    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye));
    handle.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
