//! End-to-end test of the sweep service: a real `codr serve` server on an
//! ephemeral localhost port, driven through the line-delimited JSON
//! protocol exactly as the `codr submit` / `codr warm` clients drive it.

use codr::serve::{proto, Server};
use codr::util::json::Json;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("codr-serve-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

#[test]
fn serve_submit_status_result_warm_shutdown() {
    let dir = temp_dir("full");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    // ping
    let pong = proto::request(&addr, &obj(&[("verb", Json::str("ping"))])).unwrap();
    assert!(ok(&pong), "{pong}");

    // warm a tiny grid synchronously: 1 model × 1 group × 3 archs.
    let warm_req = obj(&[
        ("verb", Json::str("warm")),
        ("models", Json::str("tiny")),
        ("groups", Json::str("Orig")),
        ("seed", Json::u64(5)),
    ]);
    let first = proto::request(&addr, &warm_req).unwrap();
    assert!(ok(&first), "{first}");
    let stats = first.get("stats").unwrap();
    assert_eq!(stats.get("requested").unwrap().as_u64().unwrap(), 3);
    assert_eq!(stats.get("computed").unwrap().as_u64().unwrap(), 3);
    assert_eq!(stats.get("cache_hits").unwrap().as_u64().unwrap(), 0);

    // Second warm of the same grid: all hits, zero simulated layers.
    let second = proto::request(&addr, &warm_req).unwrap();
    assert!(ok(&second), "{second}");
    let stats = second.get("stats").unwrap();
    assert_eq!(stats.get("cache_hits").unwrap().as_u64().unwrap(), 3);
    assert_eq!(stats.get("computed").unwrap().as_u64().unwrap(), 0);
    assert_eq!(stats.get("simulated_layers").unwrap().as_u64().unwrap(), 0);

    // result: a warmed point answers from the store.
    let res = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("result")),
            ("model", Json::str("tiny")),
            ("group", Json::str("Orig")),
            ("arch", Json::str("CoDR")),
            ("seed", Json::u64(5)),
        ]),
    )
    .unwrap();
    assert!(ok(&res), "{res}");
    assert!(res.get("cycles").unwrap().as_u64().unwrap() > 0);
    assert!(res.get("energy_uj").unwrap().as_f64().unwrap() > 0.0);

    // result for a point never warmed: clean protocol error.
    let missing = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("result")),
            ("model", Json::str("tiny")),
            ("group", Json::str("D=25%")),
            ("arch", Json::str("SCNN")),
            ("seed", Json::u64(5)),
        ]),
    )
    .unwrap();
    assert!(!ok(&missing), "{missing}");
    assert!(missing.get("error").unwrap().as_str().unwrap().contains("not in store"));

    // submit: async job over a new group, polled to completion.
    let submitted = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str("D=50%")),
            ("seed", Json::u64(5)),
        ]),
    )
    .unwrap();
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").unwrap().as_u64().unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let final_stats = loop {
        assert!(Instant::now() < deadline, "job {job} never finished");
        let status = proto::request(
            &addr,
            &obj(&[("verb", Json::str("status")), ("job", Json::u64(job))]),
        )
        .unwrap();
        assert!(ok(&status), "{status}");
        match status.get("state").unwrap().as_str().unwrap() {
            "running" => std::thread::sleep(Duration::from_millis(50)),
            "done" => break status.get("stats").unwrap().clone(),
            other => panic!("job entered state {other}: {status}"),
        }
    };
    assert_eq!(final_stats.get("requested").unwrap().as_u64().unwrap(), 3);

    // Unknown verbs and malformed grids answer, not crash.
    let bad = proto::request(&addr, &obj(&[("verb", Json::str("frobnicate"))])).unwrap();
    assert!(!ok(&bad));
    let bad_model = proto::request(
        &addr,
        &obj(&[("verb", Json::str("warm")), ("models", Json::str("resnet"))]),
    )
    .unwrap();
    assert!(!ok(&bad_model));
    assert!(bad_model.get("error").unwrap().as_str().unwrap().contains("unknown model"));

    // Server-wide status sees the job table and the populated store —
    // both the legacy flat counter and the structured v2 objects.
    let status = proto::request(&addr, &obj(&[("verb", Json::str("status"))])).unwrap();
    assert!(ok(&status), "{status}");
    assert_eq!(status.get("jobs").unwrap().as_u64().unwrap(), 1);
    assert_eq!(status.get("store_entries").unwrap().as_u64().unwrap(), 6);
    let st = status.get("store").unwrap();
    assert_eq!(st.get("entries").unwrap().as_u64().unwrap(), 6);
    // 6 points over 2 (model, group, seed) packs → 2 packed files, no v1.
    assert_eq!(st.get("packed_files").unwrap().as_u64().unwrap(), 2);
    assert_eq!(st.get("v1_files").unwrap().as_u64().unwrap(), 0);
    assert!(st.get("bytes").unwrap().as_u64().unwrap() > 0);
    assert_eq!(st.get("cap_bytes").unwrap(), &Json::Null);
    let memo = status.get("memo").unwrap();
    for field in ["entries", "hits", "misses", "evictions"] {
        assert!(memo.get(field).unwrap().as_u64().is_ok(), "{status}");
    }

    // shutdown stops the accept loop; run() returns cleanly.
    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye), "{bye}");
    handle.join().unwrap().unwrap();

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_clients_share_one_computation() {
    let dir = temp_dir("concurrent");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    // Four clients warm the identical grid at once; the in-flight dedup
    // must keep total computed points at exactly 3 (one per arch).
    let mut clients = Vec::new();
    for _ in 0..4 {
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || {
            let resp = proto::request(
                &addr,
                &obj(&[
                    ("verb", Json::str("warm")),
                    ("models", Json::str("tiny")),
                    ("groups", Json::str("Orig")),
                    ("seed", Json::u64(9)),
                ]),
            )
            .unwrap();
            assert!(ok(&resp), "{resp}");
            let stats = resp.get("stats").unwrap();
            stats.get("computed").unwrap().as_u64().unwrap()
        }));
    }
    let total: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert_eq!(total, 3, "each point must be simulated exactly once");

    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye));
    handle.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
