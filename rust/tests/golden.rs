//! Integration tests over the PJRT runtime + artifacts: the simulator's
//! compressed datapath must equal the AOT-compiled JAX/Pallas golden
//! model bit for bit, per layer and end to end.
//!
//! Requires `make artifacts`; each test skips (with a notice) when the
//! artifacts are absent so `cargo test` stays green in a fresh checkout.

use codr::runtime::golden::{check_convs, run_tiny_cnn_e2e};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn all_conv_artifacts_match_simulator_exactly() {
    let Some(dir) = artifacts_dir() else { return };
    let checks = check_convs(dir, 42).expect("golden run failed");
    assert!(!checks.is_empty(), "manifest has no conv entries");
    for c in &checks {
        assert!(c.exact, "golden mismatch on {} ({} outputs)", c.name, c.outputs);
    }
    // The artifact set must cover strided, padded, 1×1 and clipped-tile
    // geometries (these exercise distinct simulator paths).
    let names: Vec<&str> = checks.iter().map(|c| c.name.as_str()).collect();
    assert!(names.iter().any(|n| n.contains("s4")), "strided case missing");
    assert!(names.iter().any(|n| n.contains("k1")), "1x1 case missing");
    assert!(names.iter().any(|n| n.contains("n5_m7")), "clipped-tile case missing");
}

#[test]
fn tiny_cnn_end_to_end_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let e2e = run_tiny_cnn_e2e(dir, 42).expect("e2e failed");
    assert_eq!(e2e.logits_sim.len(), 10);
    assert!(
        e2e.exact,
        "logits diverge: sim {:?} vs golden {:?}",
        e2e.logits_sim, e2e.logits_golden
    );
}

#[test]
fn golden_is_seed_sensitive() {
    // Different seeds give different logits (the comparison is not
    // trivially passing on constants).
    let Some(dir) = artifacts_dir() else { return };
    let a = run_tiny_cnn_e2e(dir, 1).unwrap();
    let b = run_tiny_cnn_e2e(dir, 2).unwrap();
    assert!(a.exact && b.exact);
    assert_ne!(a.logits_sim, b.logits_sim);
}
