//! Kill -9 `codr serve` mid-job, restart on the same store: the
//! journaled job must be re-queued under a fresh id, run to completion,
//! and leave a compacted journal behind. This is the pin for the
//! crash-restart contract — an acked submit survives the process.

use codr::serve::{proto, Journal};
use codr::util::json::Json;
use std::io::{BufRead, BufReader, Read};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_codr")
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

/// Spawn `codr serve` on an ephemeral port and parse the announce line.
fn spawn_serve(store: &PathBuf, faults: Option<&str>, capture_stderr: bool) -> (Child, String) {
    let mut cmd = Command::new(bin());
    cmd.args(["serve", "--addr", "127.0.0.1:0", "--store"])
        .arg(store)
        .stdout(Stdio::piped())
        .stderr(if capture_stderr {
            Stdio::piped()
        } else {
            Stdio::null()
        });
    if let Some(f) = faults {
        cmd.env("CODR_FAULTS", f);
    }
    let mut child = cmd.spawn().expect("spawn codr serve");
    let mut line = String::new();
    BufReader::new(child.stdout.take().expect("piped stdout"))
        .read_line(&mut line)
        .expect("read serve announce line");
    let addr = line
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("unparseable announce line {line:?}"))
        .to_string();
    (child, addr)
}

#[test]
fn kill_dash_nine_mid_job_requeues_from_the_journal_on_restart() {
    let dir = std::env::temp_dir().join(format!("codr-crash-recovery-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // Server 1: every sweep-point task is slowed by 250 ms, so the KILL
    // below provably lands while the job is still running — the journal
    // then holds a submit with no terminal record.
    let (mut first, addr1) = spawn_serve(&dir, Some("sched.point.slow:10000"), false);
    let submitted = proto::request(
        &addr1,
        &obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str("Orig")),
            ("seed", Json::u64(29)),
        ]),
    )
    .expect("submit");
    assert!(ok(&submitted), "{submitted}");
    let dead_job = submitted.get("job").unwrap().as_u64().unwrap();

    // The ack implies the submit record is journaled and fsynced: the
    // server answers only after the append. SIGKILL — no drain, no
    // atexit, exactly the crash the journal exists for.
    first.kill().expect("kill serve");
    let _ = first.wait();

    // Replay (in-process, same code the server runs) sees the open job.
    {
        let (_journal, recovered) = Journal::open(&dir).expect("open journal");
        assert_eq!(recovered.len(), 1, "{recovered:?}");
        assert_eq!(recovered[0].job, dead_job);
    }

    // Server 2, no faults: it must re-queue the journaled job before
    // accepting, announce the recovery on stderr, and finish the job.
    let (mut second, addr2) = spawn_serve(&dir, None, true);
    // The re-queued job runs under the fresh process's first id.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(
            Instant::now() < deadline,
            "re-queued job never reached a terminal state"
        );
        let status = proto::request(
            &addr2,
            &obj(&[("verb", Json::str("status")), ("job", Json::u64(1))]),
        )
        .expect("status");
        if !ok(&status) {
            // Recovery may still be registering the job; keep polling.
            std::thread::sleep(Duration::from_millis(20));
            continue;
        }
        match status.get("state").unwrap().as_str().unwrap() {
            "running" => std::thread::sleep(Duration::from_millis(50)),
            "done" => break,
            other => panic!("re-queued job entered state {other}: {status}"),
        }
    }

    // The recovered grid's results are in the store.
    let res = proto::request(
        &addr2,
        &obj(&[
            ("verb", Json::str("result")),
            ("model", Json::str("tiny")),
            ("group", Json::str("Orig")),
            ("arch", Json::str("CoDR")),
            ("seed", Json::u64(29)),
        ]),
    )
    .expect("result");
    assert!(ok(&res), "recovered job must persist its points: {res}");

    let bye = proto::request(&addr2, &obj(&[("verb", Json::str("shutdown"))])).expect("shutdown");
    assert!(ok(&bye), "{bye}");
    let status = second.wait().expect("serve exit status");
    assert!(status.success(), "serve exited {status}");
    let mut stderr = String::new();
    second
        .stderr
        .take()
        .expect("piped stderr")
        .read_to_string(&mut stderr)
        .expect("read serve stderr");
    assert!(
        stderr.contains(&format!("journal: recovered job {dead_job}")),
        "restart must announce the recovery: {stderr}"
    );

    // The old id was closed with `requeued` and the new one with `done`:
    // a third replay recovers nothing, and compaction keeps the file
    // from growing across restarts.
    let (journal, recovered) = Journal::open(&dir).expect("reopen journal");
    assert!(recovered.is_empty(), "{recovered:?}");
    let len = std::fs::metadata(journal.path()).expect("journal metadata").len();
    assert_eq!(len, 0, "a journal with no open jobs compacts to empty");

    let _ = std::fs::remove_dir_all(&dir);
}
