//! Integration tests for the mapping-space search engine: the serve
//! `map` verb end to end, grouped-layer searches through the scheduler,
//! and the `codr map` CLI surface (table + JSON, deterministic and
//! store-warmed across runs).

use codr::cli::{commands, Args};
use codr::mapping::search::SearchConfig;
use codr::models::{parse_model, SweepGroup};
use codr::serve::{proto, ResultStore, Scheduler, Server};
use codr::util::json::Json;
use std::path::PathBuf;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("codr-map-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

fn sv(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

/// The `map` verb end to end: submit returns the candidate count, the
/// watch stream carries one point per evaluated mapping (tile label in
/// the `group` field), the end event's `map` payload holds a non-empty
/// Pareto front, and an identical second job replays byte-identically
/// out of the warm store.
#[test]
fn serve_map_verb_streams_and_is_deterministic() {
    let dir = temp_dir("serve");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    let req = obj(&[
        ("verb", Json::str("map")),
        ("model", Json::str("tiny")),
        ("group", Json::str("Orig")),
        ("seed", Json::u64(5)),
        ("quick", Json::Bool(true)),
    ]);
    let run = |req: &Json| {
        let submitted = proto::request(&addr, req).unwrap();
        assert!(ok(&submitted), "{submitted}");
        let job = submitted.get("job").unwrap().as_u64().unwrap();
        let candidates = submitted.get("candidates").unwrap().as_u64().unwrap();
        assert!(candidates > 0, "{submitted}");
        let mut points = 0u64;
        let end = proto::watch(&addr, job, |ev| {
            if matches!(ev.get("event").map(|v| v.as_str()), Some(Ok("point"))) {
                points += 1;
                assert_eq!(ev.get("arch").unwrap().as_str().unwrap(), "CoDR");
                // The group field carries the candidate's tile label.
                assert!(ev.get("group").unwrap().as_str().unwrap().starts_with("PU"));
            }
        })
        .unwrap();
        assert_eq!(points, candidates, "one point per evaluated mapping");
        (submitted, end)
    };

    let (first_sub, first_end) = run(&req);
    assert_eq!(first_sub.get("layer").unwrap().as_str().unwrap(), "conv1");
    let map = first_end.get("map").expect("end event carries the report");
    let front = map.field("front").unwrap().as_arr().unwrap();
    assert!(!front.is_empty(), "{map}");
    let stats = first_end.get("stats").unwrap();
    assert_eq!(stats.get("cache_hits").unwrap().as_u64().unwrap(), 0);

    // Identical job again: all candidates answer from the store and the
    // report is byte-for-byte the same.
    let (_, second_end) = run(&req);
    let stats = second_end.get("stats").unwrap();
    assert_eq!(stats.get("computed").unwrap().as_u64().unwrap(), 0);
    assert!(stats.get("cache_hits").unwrap().as_u64().unwrap() > 0);
    assert_eq!(
        map.to_string(),
        second_end.get("map").unwrap().to_string(),
        "warm report must be byte-identical"
    );

    // Malformed map requests answer with clean errors.
    let bad = proto::request(
        &addr,
        &obj(&[("verb", Json::str("map")), ("model", Json::str("resnet"))]),
    )
    .unwrap();
    assert!(!ok(&bad), "{bad}");
    let bad_layer = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("map")),
            ("model", Json::str("tiny")),
            ("layer", Json::str("fc9")),
        ]),
    )
    .unwrap();
    assert!(!ok(&bad_layer), "{bad_layer}");

    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye));
    handle.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Grouped layers search legally through the scheduler path: every
/// front candidate of a depthwise layer respects the group boundary
/// (C tile of 1), and a named-layer miss is a clean error.
#[test]
fn run_map_respects_group_boundaries_on_grouped_layers() {
    let dir = temp_dir("sched");
    let sched = Scheduler::new(ResultStore::open(&dir).unwrap());
    let mobile = parse_model("mobile").unwrap();
    // The full grid (the quick one has no size-1 tiles, and a fully
    // depthwise layer only admits K=C=1), capped to keep the test fast.
    let cfg = SearchConfig {
        max_candidates: 64,
        quick: false,
    };

    for layer in ["dw2", "g3"] {
        let report = sched
            .run_map(&mobile, Some(layer), SweepGroup::Original, 7, &cfg, None)
            .unwrap();
        assert!(!report.front.is_empty(), "{layer}: empty front");
        assert!(report.illegal > 0, "{layer}: grid should trip group checks");
        let spec = mobile.conv_layers().find(|l| l.name == layer).unwrap();
        for c in &report.front {
            let n_tile = c.mapping.size_of(codr::mapping::Dim::C).unwrap();
            let m_tile = c.mapping.size_of(codr::mapping::Dim::K).unwrap();
            assert!(n_tile <= spec.n_per_group(), "{layer}: {}", c.mapping);
            assert!(m_tile <= spec.m_per_group(), "{layer}: {}", c.mapping);
        }
    }

    let err = sched
        .run_map(&mobile, Some("fc1"), SweepGroup::Original, 7, &cfg, None)
        .unwrap_err();
    assert!(err.to_string().contains("fc1"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `codr map` CLI: the table carries the summary lines the CI smoke
/// greps for, `--json` emits the report verbatim, and two identical
/// invocations produce byte-identical output (second one store-warm).
#[test]
fn cli_map_renders_table_and_json_deterministically() {
    let dir = temp_dir("cli");
    let store = dir.to_string_lossy().into_owned();
    let base = ["--model", "tiny", "--group", "Orig", "--seed", "11", "--store", &store, "--quick"];

    let table = commands::map(&Args::parse(&sv(&base)).unwrap()).unwrap();
    assert!(table.contains("mapping Pareto front"), "{table}");
    assert!(table.contains("front: "), "{table}");
    assert!(table.contains("baseline: "), "{table}");
    assert!(table.contains("best: "), "{table}");

    let mut json_args = sv(&base);
    json_args.push("--json".into());
    let a = commands::map(&Args::parse(&json_args).unwrap()).unwrap();
    let b = commands::map(&Args::parse(&json_args).unwrap()).unwrap();
    assert_eq!(a, b, "map report must be byte-stable across runs");
    let report = Json::parse(&a).unwrap();
    assert!(!report.field("front").unwrap().as_arr().unwrap().is_empty());
    // Second run answered from the store it populated in the first.
    assert!(report.field("evaluated").unwrap().as_u64().unwrap() > 0);
    let warm = Json::parse(&b).unwrap();
    assert_eq!(
        warm.field("cache_hits").unwrap().as_u64().unwrap(),
        warm.field("evaluated").unwrap().as_u64().unwrap(),
        "warm run must answer every candidate from the store"
    );

    // Missing model is a clean error, not a panic.
    assert!(commands::map(&Args::parse(&sv(&["--quick"])).unwrap()).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
