//! Chaos suite: real `codr` subprocesses with `CODR_FAULTS` armed at the
//! durability seams. Each scenario injects one failure class — a worker
//! panic, a dropped watch stream, a torn pack write — and pins the
//! degrade-then-heal contract: the process answers (never hangs, never
//! crashes the server), the damage is visible in the structured output,
//! and a clean follow-up run converges back to all-hits.
//!
//! The faults are armed in the *subprocess* only (via `.env()`), so the
//! test binary's own in-process registry stays cold and the tests can
//! run in parallel.

use codr::serve::proto;
use codr::util::json::Json;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_codr")
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("codr-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

/// A `codr serve` subprocess with a fault spec armed. Killed on drop so
/// a failing assertion cannot leak servers past the test run.
struct ServeProc {
    child: Child,
    addr: String,
}

impl ServeProc {
    fn spawn(store: &Path, faults: &str) -> ServeProc {
        ServeProc::spawn_opts(store, faults, &[], &[])
    }

    /// [`spawn`] with extra serve flags (`--max-queued`, …) and extra
    /// subprocess-only env vars (`CODR_SERVE_EXECUTORS`, …) — env is set
    /// on the child, never this process, so parallel tests stay isolated.
    fn spawn_opts(
        store: &Path,
        faults: &str,
        extra_args: &[&str],
        envs: &[(&str, &str)],
    ) -> ServeProc {
        let mut cmd = Command::new(bin());
        cmd.args(["serve", "--addr", "127.0.0.1:0", "--store"])
            .arg(store)
            .args(extra_args)
            .stdout(Stdio::piped())
            .stderr(Stdio::null());
        if !faults.is_empty() {
            cmd.env("CODR_FAULTS", faults);
        }
        for (k, v) in envs {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().expect("spawn codr serve");
        // The announce line carries the ephemeral port.
        let mut line = String::new();
        BufReader::new(child.stdout.take().expect("piped stdout"))
            .read_line(&mut line)
            .expect("read serve announce line");
        let addr = line
            .split("listening on ")
            .nth(1)
            .and_then(|rest| rest.split_whitespace().next())
            .unwrap_or_else(|| panic!("unparseable announce line {line:?}"))
            .to_string();
        ServeProc { child, addr }
    }

    fn request(&self, req: &Json) -> Json {
        proto::request(&self.addr, req).expect("request")
    }

    fn submit(&self, groups: &str, seed: u64) -> u64 {
        let resp = self.request(&obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str(groups)),
            ("seed", Json::u64(seed)),
        ]));
        assert!(ok(&resp), "{resp}");
        resp.get("job").unwrap().as_u64().unwrap()
    }

    fn shutdown(mut self) {
        let bye = self.request(&obj(&[("verb", Json::str("shutdown"))]));
        assert!(ok(&bye), "{bye}");
        let status = self.child.wait().expect("serve exit status");
        assert!(status.success(), "serve exited {status}");
    }
}

impl Drop for ServeProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn is_point(ev: &Json) -> bool {
    matches!(ev.get("event").map(|e| e.as_str()), Some(Ok("point")))
}

/// An injected worker panic fails exactly its own sweep point: the job
/// terminates `partial` (not a deadlocked `running`, not a whole-job
/// `failed`), the failed point is visible in the stream and the stats,
/// and resubmitting — the fault budget now spent — recomputes only the
/// failed point while the survivors answer from the store.
#[test]
fn injected_worker_panic_degrades_the_job_to_partial_and_resubmit_heals() {
    let dir = temp_dir("panic");
    let srv = ServeProc::spawn(&dir, "pool.worker.panic:1");

    let job = srv.submit("Orig", 11); // 1 model × 1 group × 3 archs
    let mut failed_events = 0usize;
    let end = proto::watch(&srv.addr, job, |ev| {
        if is_point(ev) {
            if let Some(err) = ev.get("error") {
                failed_events += 1;
                let msg = err.as_str().unwrap();
                assert!(msg.contains("fault injected"), "{msg}");
            }
        }
    })
    .expect("watch to end");
    assert_eq!(end.get("state").unwrap().as_str().unwrap(), "partial", "{end}");
    let stats = end.get("stats").unwrap();
    assert_eq!(stats.get("failed").unwrap().as_u64().unwrap(), 1, "{end}");
    assert_eq!(failed_events, 1, "exactly one point event carries the error");

    // Polling agrees with the stream.
    let status = srv.request(&obj(&[("verb", Json::str("status")), ("job", Json::u64(job))]));
    assert_eq!(status.get("state").unwrap().as_str().unwrap(), "partial", "{status}");

    // Heal: the two persisted points hit, only the casualty recomputes.
    let job2 = srv.submit("Orig", 11);
    let end2 = proto::watch(&srv.addr, job2, |_| {}).expect("second watch");
    assert_eq!(end2.get("state").unwrap().as_str().unwrap(), "done", "{end2}");
    let stats2 = end2.get("stats").unwrap();
    assert_eq!(stats2.get("failed").unwrap().as_u64().unwrap(), 0, "{end2}");
    assert_eq!(stats2.get("cache_hits").unwrap().as_u64().unwrap(), 2, "{end2}");
    assert_eq!(stats2.get("computed").unwrap().as_u64().unwrap(), 1, "{end2}");

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A server-side dropped watch stream: without retries the CLI fails
/// hard with "stream truncated" (EOF is never a silent success); with
/// `--retries` the reconnect replays the history, and the client-side
/// dedup keeps delivery exactly-once.
#[test]
fn dropped_watch_stream_truncates_without_retries_and_replays_with_them() {
    let dir = temp_dir("watchdrop");
    // Three drop shots: one per watcher below.
    let srv = ServeProc::spawn(&dir, "serve.watch.drop:3");

    // 1 model × 2 groups × 3 archs = 6 points. Poll to done over the
    // status verb (the drop fault only bites watch streams).
    let job = srv.submit("Orig,D=50%", 31);
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "job {job} never finished");
        let status =
            srv.request(&obj(&[("verb", Json::str("status")), ("job", Json::u64(job))]));
        assert!(ok(&status), "{status}");
        match status.get("state").unwrap().as_str().unwrap() {
            "running" => std::thread::sleep(Duration::from_millis(50)),
            "done" => break,
            other => panic!("job entered state {other}: {status}"),
        }
    }

    // Un-retried CLI watch: the injected drop is a hard error + nonzero
    // exit, naming the truncation.
    let out = Command::new(bin())
        .args(["watch", "--job", &job.to_string(), "--addr", &srv.addr])
        .output()
        .expect("run codr watch");
    assert!(!out.status.success(), "a truncated stream must fail the CLI");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("stream truncated"), "{stderr}");

    // With --retries the second attempt replays to the real end.
    let out = Command::new(bin())
        .args([
            "watch",
            "--job",
            &job.to_string(),
            "--addr",
            &srv.addr,
            "--retries",
            "3",
        ])
        .output()
        .expect("run codr watch --retries");
    assert!(
        out.status.success(),
        "retried watch must survive the drop: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains(&format!("job {job} done:")), "{stdout}");

    // Event-level exactly-once: the last drop shot hits this watcher's
    // first attempt; the replayed reconnect must not re-deliver.
    let mut events = Vec::new();
    let end = proto::watch_retry(&srv.addr, job, &proto::Retry::attempts(3), |ev| {
        events.push(ev.clone())
    })
    .expect("watch_retry to end");
    let points: Vec<&Json> = events.iter().filter(|e| is_point(e)).collect();
    assert_eq!(points.len(), 6, "{events:?}");
    for (i, ev) in points.iter().enumerate() {
        assert_eq!(
            ev.get("done").unwrap().as_u64().unwrap(),
            i as u64 + 1,
            "reconnect must dedup, not replay twice: {ev}"
        );
    }
    assert_eq!(end.get("state").unwrap().as_str().unwrap(), "done", "{end}");

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A torn pack write (crash between write and fsync) costs recompute
/// time, never correctness: the next warm run loads what survived,
/// recomputes the rest, and the run after that is all cache hits.
#[test]
fn torn_pack_write_recomputes_and_converges_to_all_hits() {
    let dir = temp_dir("torn");
    let run_warm = |faults: Option<&str>| {
        let mut cmd = Command::new(bin());
        cmd.args(["warm", "--models", "tiny", "--groups", "Orig", "--seed", "3", "--store"])
            .arg(&dir);
        if let Some(f) = faults {
            cmd.env("CODR_FAULTS", f);
        }
        cmd.output().expect("run codr warm")
    };

    let first = run_warm(Some("store.pack_write.torn:1"));
    assert!(first.status.success(), "{first:?}");
    let stderr = String::from_utf8_lossy(&first.stderr);
    // Guard against a silently-unarmed "chaos" run.
    assert!(stderr.contains("faults: armed from CODR_FAULTS"), "{stderr}");
    assert!(stderr.contains("store.pack_write.torn fired"), "{stderr}");

    // The damaged store degrades to recompute — exit 0, no panic.
    let second = run_warm(None);
    assert!(
        second.status.success(),
        "torn pack must degrade, not crash: {}",
        String::from_utf8_lossy(&second.stderr)
    );

    // And the store has healed: the third run computes nothing.
    let third = run_warm(None);
    assert!(third.status.success(), "{third:?}");
    let stdout = String::from_utf8_lossy(&third.stdout);
    assert!(
        stdout.contains("3 cache hits") && stdout.contains("0 computed"),
        "healed store must answer every point: {stdout}"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// Bounded admission under a held executor: with one worker, a queue
/// cap of 1, and the scheduler slowed by `sched.point.slow`, a third
/// concurrent submit is refused with the full `queued-full` contract —
/// and a `--retries` client backs off through the refusals and
/// converges to `done` once the backlog drains.
#[test]
fn full_admission_queue_refuses_submits_and_retries_converge() {
    let dir = temp_dir("backpressure");
    let srv = ServeProc::spawn_opts(
        &dir,
        "sched.point.slow:12",
        &["--max-queued", "1"],
        &[("CODR_SERVE_EXECUTORS", "1")],
    );

    // Job A occupies the single worker (each of its 3 points sleeps
    // 250 ms under the fault). Wait until the pool has dequeued it so
    // the queue slot below is deterministic.
    let job_a = srv.submit("Orig", 21);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let status = srv.request(&obj(&[("verb", Json::str("status"))]));
        if status.get("queued").unwrap().as_u64().unwrap() == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "job {job_a} never left the queue: {status}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Job B fills the one queue slot; submit C must be refused — never
    // silently queued, never a success.
    let job_b = srv.submit("Orig", 22);
    let refused = srv.request(&obj(&[
        ("verb", Json::str("submit")),
        ("models", Json::str("tiny")),
        ("groups", Json::str("Orig")),
        ("seed", Json::u64(23)),
    ]));
    assert!(!ok(&refused), "{refused}");
    assert!(proto::is_queued_full(&refused), "{refused}");
    assert_eq!(refused.get("max_queued").unwrap().as_u64().unwrap(), 1, "{refused}");
    assert!(
        refused.get("error").unwrap().as_str().unwrap().contains("admission queue full"),
        "{refused}"
    );

    // A retrying CLI submit backs off through the refusals and lands
    // once the backlog drains.
    let out = Command::new(bin())
        .args([
            "submit", "--addr", &srv.addr, "--models", "tiny", "--groups", "Orig", "--seed",
            "23", "--retries", "8", "--wait",
        ])
        .output()
        .expect("run codr submit --retries --wait");
    assert!(
        out.status.success(),
        "retried submit must converge: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("done:"), "{stdout}");

    // The queued job was admitted, not lost: it ran before the CLI job.
    let status = srv.request(&obj(&[("verb", Json::str("status")), ("job", Json::u64(job_b))]));
    assert_eq!(status.get("state").unwrap().as_str().unwrap(), "done", "{status}");

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `serve.conn.stall` re-seated at the reactor: a stalled dispatch
/// blocks the event loop for its 2 s injection, an idle connection's
/// `--conn-timeout-secs 1` deadline lapses meanwhile, and the reaper
/// closes it as soon as the loop resumes — the server stays healthy
/// and answers promptly once the stall budget is spent.
#[test]
fn stalled_dispatch_still_reaps_idle_connections() {
    let dir = temp_dir("stall");
    let srv =
        ServeProc::spawn_opts(&dir, "serve.conn.stall:1", &["--conn-timeout-secs", "1"], &[]);

    // An idle connection: never sends a byte, so its reap deadline is
    // one second after accept.
    let idle = std::net::TcpStream::connect(&srv.addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    let mut idle_reader = BufReader::new(idle);

    // This ping burns the single stall shot: dispatch sleeps 2 s on the
    // reactor thread, past the idle connection's deadline.
    let started = Instant::now();
    let pong = srv.request(&obj(&[("verb", Json::str("ping"))]));
    assert!(ok(&pong), "{pong}");
    assert!(
        started.elapsed() >= Duration::from_millis(1500),
        "the stall seam never fired ({:?})",
        started.elapsed()
    );

    // The loop resumed; the overdue idle connection must be reaped well
    // before the 15 s client read timeout.
    let waited = Instant::now();
    match proto::read_message(&mut idle_reader) {
        Ok(None) | Err(_) => {} // FIN or reset: both count as closed
        Ok(Some(m)) => panic!("unexpected message on the idle connection: {m}"),
    }
    assert!(
        waited.elapsed() < Duration::from_secs(10),
        "idle connection survived {:?} under --conn-timeout-secs 1",
        waited.elapsed()
    );

    // Stall budget spent: the server answers promptly again.
    let started = Instant::now();
    let pong = srv.request(&obj(&[("verb", Json::str("ping"))]));
    assert!(ok(&pong), "{pong}");
    assert!(started.elapsed() < Duration::from_secs(1), "second ping stalled: {pong}");

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------ ring chaos

/// Spawn one node of a fixed-address ring. Unlike [`ServeProc::spawn`]
/// the port is pinned (ring membership is static), so a restart of a
/// killed node may briefly collide with lingering sockets — the spawn
/// retries until the announce line confirms the bind.
fn ring_node(addr: &str, store: &Path, ring: &str, faults: &str) -> ServeProc {
    let deadline = Instant::now() + Duration::from_secs(90);
    loop {
        let mut cmd = Command::new(bin());
        cmd.args(["serve", "--addr", addr, "--ring", ring, "--store"])
            .arg(store)
            .env("CODR_PEER_TIMEOUT_MS", "200")
            .stdout(Stdio::piped())
            .stderr(Stdio::null());
        if !faults.is_empty() {
            cmd.env("CODR_FAULTS", faults);
        }
        let mut child = cmd.spawn().expect("spawn ring node");
        let mut line = String::new();
        let _ = BufReader::new(child.stdout.take().expect("piped stdout")).read_line(&mut line);
        if line.contains("listening on") {
            assert!(line.contains("ring"), "node must announce its ring: {line:?}");
            return ServeProc { child, addr: addr.to_string() };
        }
        let _ = child.kill();
        let _ = child.wait();
        assert!(Instant::now() < deadline, "ring node on {addr} never bound: {line:?}");
        std::thread::sleep(Duration::from_millis(500));
    }
}

fn submit_msg(seed: u64) -> Json {
    obj(&[
        ("verb", Json::str("submit")),
        ("models", Json::str("tiny")),
        ("groups", Json::str("Orig")),
        ("seed", Json::u64(seed)),
    ])
}

/// First `n` seeds whose `tiny`/`Orig` pack hashes to the *other* node,
/// resolved through the answering node's `ring` verb.
fn remote_owned_seeds(node: &ServeProc, n: usize) -> Vec<u64> {
    let mut seeds = Vec::new();
    for seed in 1..500u64 {
        let resp = node.request(&obj(&[
            ("verb", Json::str("ring")),
            ("model", Json::str("tiny")),
            ("group", Json::str("Orig")),
            ("seed", Json::u64(seed)),
        ]));
        assert!(ok(&resp), "{resp}");
        let pack = resp.get("pack").unwrap();
        if !pack.get("owned").unwrap().as_bool().unwrap() {
            seeds.push(seed);
            if seeds.len() == n {
                return seeds;
            }
        }
    }
    panic!("fewer than {n} of 500 seeds hashed to the remote node");
}

/// Poll `job` on `node` until it reaches `done`.
fn wait_done(node: &ServeProc, job: u64) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        assert!(Instant::now() < deadline, "job {job} never finished on {}", node.addr);
        let status = node.request(&obj(&[("verb", Json::str("status")), ("job", Json::u64(job))]));
        assert!(ok(&status), "{status}");
        match status.get("state").unwrap().as_str().unwrap() {
            "running" => std::thread::sleep(Duration::from_millis(50)),
            "done" => return,
            other => panic!("job {job} entered state {other}: {status}"),
        }
    }
}

/// Two-node ring, full degrade-then-heal arc: a submit for a pack the
/// other node owns is forwarded there (the pack lands in the owner's
/// store, never the forwarder's); after the owner is SIGKILLed the same
/// route answers `done-degraded` from local compute with the misplaced
/// pack origin-tagged; and once the owner restarts, the anti-entropy
/// pass pushes the pack home and trims the local copy — no entry lost,
/// no husk left behind.
#[test]
fn killed_ring_owner_degrades_then_anti_entropy_repairs() {
    let dir1 = temp_dir("ring-heal-1");
    let dir2 = temp_dir("ring-heal-2");
    let (a1, a2) = ("127.0.0.1:29411", "127.0.0.1:29412");
    let ring = format!("{a1},{a2}");
    let n1 = ring_node(a1, &dir1, &ring, "");
    let mut n2 = ring_node(a2, &dir2, &ring, "");

    let seeds = remote_owned_seeds(&n1, 2);
    let (fwd_seed, deg_seed) = (seeds[0], seeds[1]);

    // Healthy ring: the submit is forwarded and the pack lands on the
    // owner, not the node we dialed.
    let resp = n1.request(&submit_msg(fwd_seed));
    assert!(ok(&resp), "{resp}");
    assert_eq!(resp.get("owner").unwrap().as_str().unwrap(), a2, "{resp}");
    assert!(resp.get("forwarded").unwrap().as_bool().unwrap(), "{resp}");
    let job = resp.get("job").unwrap().as_u64().unwrap();
    wait_done(&n2, job);
    let fwd_pack = format!("tiny-Orig-s{fwd_seed}.pack.json");
    assert!(dir2.join(&fwd_pack).exists(), "pack must land in the owner's store");
    assert!(!dir1.join(&fwd_pack).exists(), "the forwarder must not keep a copy");

    // SIGKILL the owner: the same route degrades to local compute, and
    // the misplaced pack is origin-tagged for later repair.
    n2.child.kill().expect("kill owner");
    n2.child.wait().expect("reap owner");
    let resp = n1.request(&submit_msg(deg_seed));
    assert!(ok(&resp), "{resp}");
    assert_eq!(resp.get("state").unwrap().as_str().unwrap(), "done-degraded", "{resp}");
    assert_eq!(resp.get("owner").unwrap().as_str().unwrap(), a2, "{resp}");
    let stats = resp.get("stats").unwrap();
    assert_eq!(stats.get("computed").unwrap().as_u64().unwrap(), 3, "{resp}");
    let deg_pack = format!("tiny-Orig-s{deg_seed}.pack.json");
    let misplaced = std::fs::read_to_string(dir1.join(&deg_pack)).expect("misplaced pack");
    assert!(misplaced.contains("\"origin\""), "degraded entries must be origin-tagged");

    // Restart the owner on its fixed address: the maintenance pass
    // probes it back to Up and pushes the pack home.
    drop(n2);
    let n2 = ring_node(a2, &dir2, &ring, "");
    let deadline = Instant::now() + Duration::from_secs(30);
    while dir1.join(&deg_pack).exists() {
        assert!(
            Instant::now() < deadline,
            "misplaced pack was never repaired to the recovered owner"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    let repaired = std::fs::read_to_string(dir2.join(&deg_pack)).expect("repaired pack");
    let entries = Json::parse(&repaired)
        .expect("parse repaired pack")
        .field("entries")
        .expect("entries")
        .as_arr()
        .expect("entries array")
        .len();
    assert_eq!(entries, 3, "every degraded entry must survive the repair");
    let info = n1.request(&obj(&[("verb", Json::str("ring"))]));
    let gauges = info.get("ring").unwrap();
    assert!(
        gauges.get("repairs").unwrap().as_u64().unwrap() >= 1,
        "{info}"
    );

    n1.shutdown();
    n2.shutdown();
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir2);
}

/// The acceptance pin: with `peer.conn.fail` armed on the forwarding
/// node, a submit routed to a live remote owner is answered — the first
/// forward attempt burns the fault shot, the backoff retry lands, and
/// the client gets the owner's ack. Never a hang, never a silent drop.
#[test]
fn armed_peer_conn_fault_never_hangs_or_drops_a_forwarded_submit() {
    let dir1 = temp_dir("ring-fault-1");
    let dir2 = temp_dir("ring-fault-2");
    let (a1, a2) = ("127.0.0.1:29421", "127.0.0.1:29422");
    let ring = format!("{a1},{a2}");
    let n1 = ring_node(a1, &dir1, &ring, "peer.conn.fail:1");
    let n2 = ring_node(a2, &dir2, &ring, "");

    let seed = remote_owned_seeds(&n1, 1)[0];
    let started = Instant::now();
    let resp = n1.request(&submit_msg(seed));
    assert!(
        started.elapsed() < Duration::from_secs(30),
        "forwarded submit took {:?} under an armed connect fault",
        started.elapsed()
    );
    assert!(ok(&resp), "an armed connect fault must not drop the submit: {resp}");
    assert!(resp.get("forwarded").unwrap().as_bool().unwrap(), "{resp}");
    let job = resp.get("job").unwrap().as_u64().unwrap();
    wait_done(&n2, job);

    // The seam really fired: the retry that landed sits next to at
    // least one recorded forward error.
    let info = n1.request(&obj(&[("verb", Json::str("ring"))]));
    let peers = info.get("ring").unwrap().get("peers").unwrap();
    let errors: u64 = peers
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.get("forward_errors").unwrap().as_u64().unwrap())
        .sum();
    assert!(errors >= 1, "the peer.conn.fail seam never fired: {info}");

    n1.shutdown();
    n2.shutdown();
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir2);
}
