//! Pruned-job-id semantics: a terminal job pruned from the bounded job
//! table must answer `state:"expired"` — not `unknown job N` — so slow
//! pollers stop retrying, while a never-issued id stays a hard error.
//!
//! Lives in its own test binary because it shrinks the retention bound
//! via `CODR_SERVE_MAX_JOBS`, and env vars are process-wide: the other
//! serve tests must never observe it.

use codr::serve::{proto, Server};
use codr::util::json::Json;
use std::time::{Duration, Instant};

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

/// Submit a one-point grid and poll it to a terminal state.
fn submit_and_finish(addr: &str) -> u64 {
    let submitted = proto::request(
        addr,
        &obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str("Orig")),
            ("archs", Json::str("codr")),
            ("seed", Json::u64(23)),
        ]),
    )
    .unwrap();
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").unwrap().as_u64().unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "job {job} never finished");
        let status = proto::request(
            addr,
            &obj(&[("verb", Json::str("status")), ("job", Json::u64(job))]),
        )
        .unwrap();
        assert!(ok(&status), "{status}");
        match status.get("state").unwrap().as_str().unwrap() {
            "running" => std::thread::sleep(Duration::from_millis(10)),
            "done" => return job,
            other => panic!("job {job} entered state {other}: {status}"),
        }
    }
}

#[test]
fn pruned_job_ids_answer_expired_not_unknown() {
    // Must be set before the server handles any submit; this test binary
    // has exactly one test, so nothing else can race the env.
    std::env::set_var("CODR_SERVE_MAX_JOBS", "3");
    let dir = std::env::temp_dir().join(format!("codr-serve-expired-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    // Fill the table to its bound, then one more: the oldest terminal
    // job (the first) is pruned into the expired ring.
    let first = submit_and_finish(&addr);
    for _ in 0..3 {
        submit_and_finish(&addr);
    }

    // The pruned id answers ok with state "expired"...
    let s = proto::request(
        &addr,
        &obj(&[("verb", Json::str("status")), ("job", Json::u64(first))]),
    )
    .unwrap();
    assert!(ok(&s), "expired must be a normal answer, not an error: {s}");
    assert_eq!(s.get("state").unwrap().as_str().unwrap(), "expired");

    // ...a never-issued id stays a hard error...
    let s = proto::request(
        &addr,
        &obj(&[("verb", Json::str("status")), ("job", Json::u64(4242))]),
    )
    .unwrap();
    assert!(!ok(&s), "{s}");
    assert!(s.get("error").unwrap().as_str().unwrap().contains("unknown job"));

    // ...and watch distinguishes them the same way.
    let err = proto::watch(&addr, first, |_| {}).unwrap_err().to_string();
    assert!(err.contains("expired"), "{err}");
    let err = proto::watch(&addr, 4242, |_| {}).unwrap_err().to_string();
    assert!(err.contains("unknown job"), "{err}");

    let bye = proto::request(&addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&bye));
    handle.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
