//! Integration test: the paper's qualitative claims (the "shape" of
//! Figs 6–8 and the §V-C/§V-D anchors) hold on a real model sweep.
//! Heavier than unit tests — one GoogleNet sweep across three designs.

use codr::coordinator::{headline, run_sweep, Arch};
use codr::models::{googlenet, SweepGroup};

#[test]
fn googlenet_original_group_reproduces_paper_shape() {
    let model = googlenet();
    let groups = [SweepGroup::Unique(16), SweepGroup::Original, SweepGroup::Density(25)];
    let results = run_sweep(&[model.clone()], &groups, &Arch::all(), 42);

    // --- headline directions (abstract): CoDR wins on all three axes.
    let h = headline(&results, &["googlenet"]).expect("grid covers googlenet");
    assert!(h.compression_vs_ucnn > 1.0, "{h:?}");
    assert!(h.sram_vs_ucnn > 1.0 && h.sram_vs_scnn > 1.0, "{h:?}");
    assert!(h.energy_vs_ucnn > 1.0 && h.energy_vs_scnn > 1.0, "{h:?}");
    // Paper order: SCNN is the worst on SRAM and energy.
    assert!(h.sram_vs_scnn > h.sram_vs_ucnn, "{h:?}");
    assert!(h.energy_vs_scnn > h.energy_vs_ucnn, "{h:?}");

    // --- Fig 6 trend: limiting unique weights improves CoDR's rate more
    // than SCNN's (SCNN cannot exploit repetition).
    let rate = |g, a| {
        results
            .get("googlenet", g, a)
            .unwrap()
            .compression()
            .rate()
    };
    let codr_gain = rate(SweepGroup::Unique(16), Arch::Codr) / rate(SweepGroup::Original, Arch::Codr);
    let scnn_gain = rate(SweepGroup::Unique(16), Arch::Scnn) / rate(SweepGroup::Original, Arch::Scnn);
    assert!(
        codr_gain > scnn_gain,
        "U=16 compression gain: CoDR {codr_gain} vs SCNN {scnn_gain}"
    );

    // --- Fig 7: CoDR output-stationary; input ratios ≈ paper's 20×.
    let mem = |a| results.get("googlenet", SweepGroup::Original, a).unwrap().mem();
    let out_feats: u64 = model.conv_layers().map(|l| l.output_features() as u64).sum();
    assert_eq!(mem(Arch::Codr).output_sram.accesses, out_feats);
    let in_ratio = mem(Arch::Ucnn).input_sram.accesses as f64
        / mem(Arch::Codr).input_sram.accesses as f64;
    assert!((10.0..40.0).contains(&in_ratio), "input ratio {in_ratio}");

    // --- Fig 8: energy falls with density degradation for every design.
    for &a in &Arch::all() {
        let orig = results
            .get("googlenet", SweepGroup::Original, a)
            .unwrap()
            .energy()
            .total_uj();
        let sparse = results
            .get("googlenet", SweepGroup::Density(25), a)
            .unwrap()
            .energy()
            .total_uj();
        assert!(sparse < orig, "{}: {sparse} !< {orig}", a.name());
    }

    // --- §V-D: SCNN pays the most DRAM energy (worst compression).
    let dram = |a| {
        results
            .get("googlenet", SweepGroup::Original, a)
            .unwrap()
            .energy()
            .dram_uj
    };
    assert!(dram(Arch::Scnn) > dram(Arch::Ucnn));
    assert!(dram(Arch::Scnn) > dram(Arch::Codr));
}
