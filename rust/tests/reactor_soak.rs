//! Soak test for the event-driven serve core: many concurrent
//! connections over real sockets, watcher churn, and idle reaping.
//! Pins the reactor's headline invariants — every job completes, every
//! watch stream terminates with `event:"end"`, the per-verb counters
//! conserve (`requests == answers + errors` at quiescence), watcher and
//! connection gauges return to baseline, and `--conn-timeout-secs`
//! actually closes idle connections.

use codr::serve::{proto, Server};
use codr::util::json::Json;
use std::io::BufReader;
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("codr-soak-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

fn status_of(addr: &str) -> Json {
    proto::request(addr, &obj(&[("verb", Json::str("status"))])).expect("status request")
}

fn gauge(status: &Json, field: &str) -> u64 {
    status.get(field).unwrap().as_u64().unwrap()
}

/// Poll `status` until the server is quiescent: no lingering
/// connections beyond the one asking, and no parked watchers. Returns
/// the final status snapshot.
fn await_quiescent(addr: &str) -> Json {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let status = status_of(addr);
        if gauge(&status, "conns") == 1 && gauge(&status, "watchers") == 0 {
            return status;
        }
        assert!(
            Instant::now() < deadline,
            "server never quiesced: conns={} watchers={}",
            gauge(&status, "conns"),
            gauge(&status, "watchers"),
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn shutdown_and_join(addr: &str, handle: std::thread::JoinHandle<anyhow::Result<()>>) {
    let resp = proto::request(addr, &obj(&[("verb", Json::str("shutdown"))])).unwrap();
    assert!(ok(&resp), "{resp}");
    handle.join().unwrap().unwrap();
}

/// 64 concurrent client threads — submits watched to completion, warm
/// sweeps, status hammers, pings — then per-verb counter conservation
/// on the quiesced server.
#[test]
fn soak_sixty_four_connections_conserve_counters() {
    let dir = temp_dir("soak");
    let mut server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    server.set_max_queued(256);
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    let mut threads = Vec::new();
    // 16 submitters, each watching its job to the terminal `end` event.
    for i in 0..16u64 {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let submitted = proto::request(
                &addr,
                &obj(&[
                    ("verb", Json::str("submit")),
                    ("models", Json::str("tiny")),
                    ("groups", Json::str("Orig")),
                    ("seed", Json::u64(1 + i % 4)),
                ]),
            )
            .unwrap();
            assert!(ok(&submitted), "{submitted}");
            let job = submitted.get("job").unwrap().as_u64().unwrap();
            let end = proto::watch(&addr, job, |_| {}).unwrap();
            assert_eq!(end.get("event").unwrap().as_str().unwrap(), "end");
            assert_eq!(end.get("state").unwrap().as_str().unwrap(), "done", "{end}");
        }));
    }
    // 16 warm sweeps of one tiny grid (the store dedups repeats).
    for _ in 0..16 {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let resp = proto::request(
                &addr,
                &obj(&[
                    ("verb", Json::str("warm")),
                    ("models", Json::str("tiny")),
                    ("groups", Json::str("Orig")),
                    ("seed", Json::u64(9)),
                ]),
            )
            .unwrap();
            assert!(ok(&resp), "{resp}");
        }));
    }
    // 16 status hammers and 16 pings riding alongside the real work.
    for _ in 0..16 {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            for _ in 0..4 {
                let status = status_of(&addr);
                assert!(ok(&status), "{status}");
            }
        }));
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let pong = proto::request(&addr, &obj(&[("verb", Json::str("ping"))])).unwrap();
            assert!(ok(&pong), "{pong}");
        }));
    }
    for t in threads {
        t.join().expect("client thread panicked");
    }

    let status = await_quiescent(&addr);
    let verbs = status.get("verbs").expect("status carries per-verb counters");
    for name in ["ping", "warm", "submit", "map", "watch", "status", "result", "shutdown", "other"]
    {
        let v = verbs.get(name).unwrap_or_else(|| panic!("verb {name} missing"));
        let req = v.get("requests").unwrap().as_u64().unwrap();
        let ans = v.get("answers").unwrap().as_u64().unwrap();
        let err = v.get("errors").unwrap().as_u64().unwrap();
        // The snapshot is built while its own `status` request is still
        // in flight: counted as a request, not yet finished.
        let in_flight = u64::from(name == "status");
        assert_eq!(req, ans + err + in_flight, "verb {name}: {req} != {ans}+{err}+{in_flight}");
    }
    for (name, expected) in [("submit", 16), ("warm", 16), ("watch", 16), ("ping", 16)] {
        let v = verbs.get(name).unwrap();
        assert_eq!(v.get("requests").unwrap().as_u64().unwrap(), expected, "verb {name}");
        assert_eq!(v.get("errors").unwrap().as_u64().unwrap(), 0, "verb {name}");
    }
    // Latency quantiles are present and sane (bucketed, so >= 0.25 ms).
    let p50 = verbs.get("submit").unwrap().get("p50_ms").unwrap().as_f64().unwrap();
    let p99 = verbs.get("submit").unwrap().get("p99_ms").unwrap().as_f64().unwrap();
    assert!(p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}");

    shutdown_and_join(&addr, handle);
    let _ = std::fs::remove_dir_all(&dir);
}

/// 100 watchers that connect, attach, and immediately hang up: the
/// watcher and connection gauges must return to baseline — nothing
/// leaks, nothing double-decrements.
#[test]
fn watcher_churn_returns_to_baseline() {
    let dir = temp_dir("churn");
    let server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    let submitted = proto::request(
        &addr,
        &obj(&[
            ("verb", Json::str("submit")),
            ("models", Json::str("tiny")),
            ("groups", Json::str("Orig")),
            ("seed", Json::u64(3)),
        ]),
    )
    .unwrap();
    assert!(ok(&submitted), "{submitted}");
    let job = submitted.get("job").unwrap().as_u64().unwrap();
    let end = proto::watch(&addr, job, |_| {}).unwrap();
    assert_eq!(end.get("state").unwrap().as_str().unwrap(), "done", "{end}");

    for _ in 0..100 {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        proto::write_message(
            &mut writer,
            &obj(&[("verb", Json::str("watch")), ("job", Json::u64(job))]),
        )
        .unwrap();
        let ack = proto::read_message(&mut reader).unwrap().expect("watch ack");
        assert!(ok(&ack), "{ack}");
        // Drop both halves mid-stream: the reactor must deregister the
        // watcher and reclaim the connection.
    }

    let status = await_quiescent(&addr);
    assert_eq!(gauge(&status, "watchers"), 0);
    assert_eq!(gauge(&status, "conns"), 1);

    shutdown_and_join(&addr, handle);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--conn-timeout-secs` under the reactor: an idle connection is
/// reaped by the deadline heap while fresh connections keep working.
#[test]
fn idle_connections_are_reaped() {
    let dir = temp_dir("reap");
    let mut server = Server::bind("127.0.0.1:0", &dir).expect("bind ephemeral port");
    server.set_conn_timeout_secs(1);
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    proto::write_message(&mut writer, &obj(&[("verb", Json::str("ping"))])).unwrap();
    let pong = proto::read_message(&mut reader).unwrap().expect("pong");
    assert!(ok(&pong), "{pong}");

    // Go idle past the 1 s deadline: the reaper must close the socket
    // well before our 15 s read timeout would fire.
    let waited = Instant::now();
    match proto::read_message(&mut reader) {
        Ok(None) | Err(_) => {} // FIN or reset: both count as closed
        Ok(Some(m)) => panic!("unexpected message on an idle connection: {m}"),
    }
    assert!(
        waited.elapsed() < Duration::from_secs(10),
        "idle connection survived {:?} — the reaper never fired",
        waited.elapsed(),
    );

    // The server is still healthy for new connections.
    let pong = proto::request(&addr, &obj(&[("verb", Json::str("ping"))])).unwrap();
    assert!(ok(&pong), "{pong}");

    shutdown_and_join(&addr, handle);
    let _ = std::fs::remove_dir_all(&dir);
}
