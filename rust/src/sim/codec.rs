//! Versioned JSON (de)serialization of the simulation result types —
//! the schema of the `serve` result store (the offline registry has no
//! `serde`, so the mapping is spelled out by hand).
//!
//! [`CODEC_VERSION`] names the *schema* of a serialized [`ModelResult`].
//! Bump it whenever a field is added, removed, or changes meaning; the
//! store treats any version mismatch as a miss and recomputes, so old
//! cache files degrade to a cold start, never to a crash or a wrong
//! figure.

use crate::arch::{AccessCounter, MemoryStats};
use crate::energy::{AluStats, EnergyBreakdown};
use crate::rle::CompressionStats;
use crate::sim::{LayerResult, ModelResult};
use crate::util::json::Json;
use anyhow::{Context, Result};

/// Schema version of the serialized result types.
pub const CODEC_VERSION: u32 = 1;

fn counter_to_json(c: &AccessCounter) -> Json {
    Json::Obj(vec![
        ("accesses".into(), Json::u64(c.accesses)),
        ("bits".into(), Json::u64(c.bits)),
    ])
}

fn counter_from_json(j: &Json) -> Result<AccessCounter> {
    Ok(AccessCounter {
        accesses: j.field("accesses")?.as_u64()?,
        bits: j.field("bits")?.as_u64()?,
    })
}

fn mem_to_json(m: &MemoryStats) -> Json {
    Json::Obj(vec![
        ("input_sram".into(), counter_to_json(&m.input_sram)),
        ("output_sram".into(), counter_to_json(&m.output_sram)),
        ("weight_sram".into(), counter_to_json(&m.weight_sram)),
        ("dram".into(), counter_to_json(&m.dram)),
        ("input_rf".into(), counter_to_json(&m.input_rf)),
        ("weight_rf".into(), counter_to_json(&m.weight_rf)),
        ("output_rf".into(), counter_to_json(&m.output_rf)),
    ])
}

fn mem_from_json(j: &Json) -> Result<MemoryStats> {
    Ok(MemoryStats {
        input_sram: counter_from_json(j.field("input_sram")?)?,
        output_sram: counter_from_json(j.field("output_sram")?)?,
        weight_sram: counter_from_json(j.field("weight_sram")?)?,
        dram: counter_from_json(j.field("dram")?)?,
        input_rf: counter_from_json(j.field("input_rf")?)?,
        weight_rf: counter_from_json(j.field("weight_rf")?)?,
        output_rf: counter_from_json(j.field("output_rf")?)?,
    })
}

fn alu_to_json(a: &AluStats) -> Json {
    Json::Obj(vec![
        ("mults_full".into(), Json::u64(a.mults_full)),
        ("mults_low".into(), Json::u64(a.mults_low)),
        ("delta_bits".into(), Json::u64(a.delta_bits as u64)),
        ("adds".into(), Json::u64(a.adds)),
        ("xbar_transfers".into(), Json::u64(a.xbar_transfers)),
        ("xbar_bits".into(), Json::u64(a.xbar_bits as u64)),
    ])
}

fn alu_from_json(j: &Json) -> Result<AluStats> {
    Ok(AluStats {
        mults_full: j.field("mults_full")?.as_u64()?,
        mults_low: j.field("mults_low")?.as_u64()?,
        delta_bits: j.field("delta_bits")?.as_u32()?,
        adds: j.field("adds")?.as_u64()?,
        xbar_transfers: j.field("xbar_transfers")?.as_u64()?,
        xbar_bits: j.field("xbar_bits")?.as_u32()?,
    })
}

fn energy_to_json(e: &EnergyBreakdown) -> Json {
    Json::Obj(vec![
        ("dram_uj".into(), Json::f64(e.dram_uj)),
        ("sram_uj".into(), Json::f64(e.sram_uj)),
        ("rf_uj".into(), Json::f64(e.rf_uj)),
        ("alu_uj".into(), Json::f64(e.alu_uj)),
        ("xbar_uj".into(), Json::f64(e.xbar_uj)),
    ])
}

fn energy_from_json(j: &Json) -> Result<EnergyBreakdown> {
    Ok(EnergyBreakdown {
        dram_uj: j.field("dram_uj")?.as_f64()?,
        sram_uj: j.field("sram_uj")?.as_f64()?,
        rf_uj: j.field("rf_uj")?.as_f64()?,
        alu_uj: j.field("alu_uj")?.as_f64()?,
        xbar_uj: j.field("xbar_uj")?.as_f64()?,
    })
}

fn compression_to_json(c: &CompressionStats) -> Json {
    Json::Obj(vec![
        ("num_weights".into(), Json::usize(c.num_weights)),
        ("encoded_bits".into(), Json::usize(c.encoded_bits)),
        ("delta_bits".into(), Json::usize(c.delta_bits)),
        ("count_bits".into(), Json::usize(c.count_bits)),
        ("index_bits".into(), Json::usize(c.index_bits)),
        ("header_bits".into(), Json::usize(c.header_bits)),
    ])
}

fn compression_from_json(j: &Json) -> Result<CompressionStats> {
    Ok(CompressionStats {
        num_weights: j.field("num_weights")?.as_usize()?,
        encoded_bits: j.field("encoded_bits")?.as_usize()?,
        delta_bits: j.field("delta_bits")?.as_usize()?,
        count_bits: j.field("count_bits")?.as_usize()?,
        index_bits: j.field("index_bits")?.as_usize()?,
        header_bits: j.field("header_bits")?.as_usize()?,
    })
}

fn layer_to_json(l: &LayerResult) -> Json {
    Json::Obj(vec![
        ("layer".into(), Json::str(&l.layer)),
        ("mem".into(), mem_to_json(&l.mem)),
        ("alu".into(), alu_to_json(&l.alu)),
        ("cycles".into(), Json::u64(l.cycles)),
        ("compression".into(), compression_to_json(&l.compression)),
        ("energy".into(), energy_to_json(&l.energy)),
    ])
}

fn layer_from_json(j: &Json) -> Result<LayerResult> {
    Ok(LayerResult {
        layer: j.field("layer")?.as_str()?.to_string(),
        mem: mem_from_json(j.field("mem")?)?,
        alu: alu_from_json(j.field("alu")?)?,
        cycles: j.field("cycles")?.as_u64()?,
        compression: compression_from_json(j.field("compression")?)?,
        energy: energy_from_json(j.field("energy")?)?,
    })
}

/// Serialize a [`ModelResult`] (schema [`CODEC_VERSION`]).
pub fn model_result_to_json(r: &ModelResult) -> Json {
    Json::Obj(vec![
        ("codec".into(), Json::u64(CODEC_VERSION as u64)),
        ("arch".into(), Json::str(&r.arch)),
        ("model".into(), Json::str(&r.model)),
        ("group".into(), Json::str(&r.group)),
        (
            "layers".into(),
            Json::Arr(r.layers.iter().map(layer_to_json).collect()),
        ),
    ])
}

/// Integrity check of one serialized result subtree: FNV-1a over the
/// compact (wire) rendering. The packed store (format v2) saves this per
/// entry and re-verifies it on load, so a single bit-rotted or hand-edited
/// entry degrades to `Corrupt`/recompute without discarding its siblings.
/// `Json → text` is canonical (insertion-ordered objects, shortest-
/// roundtrip floats), so the hash is stable across encode/parse cycles —
/// `codec` round-trip tests pin that property.
pub fn result_check(result: &Json) -> u64 {
    crate::util::hash::fnv1a64(result.to_string().as_bytes())
}

/// Deserialize a [`ModelResult`]; errors on any schema or type mismatch
/// (callers treat the error as a cache miss).
pub fn model_result_from_json(j: &Json) -> Result<ModelResult> {
    let codec = j.field("codec")?.as_u32()?;
    if codec != CODEC_VERSION {
        anyhow::bail!("codec version {codec}, expected {CODEC_VERSION}");
    }
    let layers = j
        .field("layers")?
        .as_arr()?
        .iter()
        .enumerate()
        .map(|(i, l)| layer_from_json(l).with_context(|| format!("layer {i}")))
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelResult {
        arch: j.field("arch")?.as_str()?.to_string(),
        model: j.field("model")?.as_str()?.to_string(),
        group: j.field("group")?.as_str()?.to_string(),
        layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MemoryKind;

    fn sample_result() -> ModelResult {
        let mut l = LayerResult {
            layer: "conv1".into(),
            cycles: 123_456,
            ..Default::default()
        };
        l.mem.record(MemoryKind::InputSram, 17, 8);
        l.mem.record(MemoryKind::WeightSram, 5, 64);
        l.mem.record(MemoryKind::Dram, 2, 4096);
        l.alu = AluStats {
            mults_full: 9,
            mults_low: 1000,
            delta_bits: 3,
            adds: 1009,
            xbar_transfers: 40,
            xbar_bits: 32,
        };
        l.compression = CompressionStats {
            num_weights: 864,
            encoded_bits: 1460,
            delta_bits: 700,
            count_bits: 300,
            index_bits: 260,
            header_bits: 200,
        };
        l.energy = EnergyBreakdown {
            dram_uj: 0.1,
            sram_uj: 1.0 / 3.0,
            rf_uj: 2.5e-7,
            alu_uj: 42.0,
            xbar_uj: 0.0,
        };
        ModelResult {
            arch: "CoDR".into(),
            model: "tiny".into(),
            group: "Orig".into(),
            layers: vec![l.clone(), LayerResult { layer: "conv2".into(), ..l }],
        }
    }

    #[test]
    fn round_trip_is_identity() {
        let r = sample_result();
        let text = model_result_to_json(&r).to_string();
        let back = model_result_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        // And a second encode is byte-stable.
        assert_eq!(model_result_to_json(&back).to_string(), text);
    }

    #[test]
    fn result_check_is_stable_across_parse_cycles_and_content_sensitive() {
        let r = sample_result();
        let node = model_result_to_json(&r);
        let c0 = result_check(&node);
        // Parse → re-check: the canonical rendering makes this identical.
        let reparsed = Json::parse(&node.to_string()).unwrap();
        assert_eq!(result_check(&reparsed), c0);
        // Any value change moves the hash.
        let tweaked = Json::parse(&node.to_string().replacen("123456", "123457", 1)).unwrap();
        assert_ne!(result_check(&tweaked), c0);
    }

    #[test]
    fn missing_field_is_an_error_not_a_panic() {
        let r = sample_result();
        let text = model_result_to_json(&r).to_string();
        let truncated = text.replace("\"cycles\"", "\"cycle_\"");
        let j = Json::parse(&truncated).unwrap();
        assert!(model_result_from_json(&j).is_err());
    }

    #[test]
    fn future_codec_version_is_rejected() {
        let mut text = model_result_to_json(&sample_result()).to_string();
        text = text.replacen("\"codec\":1", "\"codec\":999", 1);
        let j = Json::parse(&text).unwrap();
        assert!(model_result_from_json(&j).is_err());
    }
}
