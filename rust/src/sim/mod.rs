//! Common simulation driver types shared by the CoDR, UCNN and SCNN
//! architecture models: per-layer results, per-model aggregation, and the
//! `Accelerator` abstraction the coordinator fans out over.

pub mod codec;

use crate::arch::{CactiLite, MemConfig, MemoryStats, TileConfig};
use crate::energy::{price_layer, AluStats, EnergyBreakdown};
use crate::models::{LayerSpec, Workload};
use crate::rle::CompressionStats;
use crate::tensor::{Tensor, Weights};

/// Everything measured while simulating one conv layer on one design.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerResult {
    pub layer: String,
    pub mem: MemoryStats,
    pub alu: AluStats,
    pub cycles: u64,
    pub compression: CompressionStats,
    pub energy: EnergyBreakdown,
}

impl LayerResult {
    /// Price this layer's activity and store the breakdown.
    pub fn finish(mut self, cacti: &CactiLite, mem_cfg: &MemConfig) -> Self {
        self.energy = price_layer(&self.mem, &self.alu, cacti, mem_cfg);
        self
    }
}

/// Aggregate over a whole model.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModelResult {
    pub arch: String,
    pub model: String,
    pub group: String,
    pub layers: Vec<LayerResult>,
}

impl ModelResult {
    pub fn mem(&self) -> MemoryStats {
        let mut m = MemoryStats::default();
        for l in &self.layers {
            m.add(&l.mem);
        }
        m
    }

    pub fn alu(&self) -> AluStats {
        let mut a = AluStats::default();
        for l in &self.layers {
            a.add(&l.alu);
        }
        a
    }

    pub fn energy(&self) -> EnergyBreakdown {
        let mut e = EnergyBreakdown::default();
        for l in &self.layers {
            e.add(&l.energy);
        }
        e
    }

    pub fn compression(&self) -> CompressionStats {
        let mut c = CompressionStats::default();
        for l in &self.layers {
            c.add(&l.compression);
        }
        c
    }

    pub fn cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }
}

/// An accelerator design that can simulate a conv layer.
///
/// `simulate_layer` is the *stats* path used by every figure: it encodes
/// the real weights, walks the design's dataflow loop nest, and returns
/// exact access/ALU/cycle counts — without executing MACs, so full
/// VGG16-scale models simulate in milliseconds. Functional execution
/// (computing actual outputs through the compressed datapath) lives in
/// `codr::functional` and is exercised by tests/examples on small layers.
pub trait Accelerator: Sync {
    fn name(&self) -> &'static str;
    fn tile_config(&self) -> TileConfig;
    fn simulate_layer(&self, spec: &LayerSpec, weights: &Weights) -> LayerResult;
}

/// Simulate one conv layer, decomposing grouped convolutions.
///
/// A grouped conv (`spec.groups = g > 1`) is `g` independent dense convs
/// of `n/g → m/g` channels; each group's filter bank is a contiguous
/// `m/g`-row slice of the `[m, n/g, r_k, r_k]` weight tensor. The dataflow
/// walks (which assume a dense `[m, n, ...]` tensor) simulate each group
/// separately and the counters sum — channels never mix across a group
/// boundary, matching the hardware semantics. Dense layers pass through
/// untouched.
pub fn simulate_layer_grouped(
    acc: &dyn Accelerator,
    spec: &LayerSpec,
    weights: &Weights,
) -> LayerResult {
    if spec.groups <= 1 {
        return acc.simulate_layer(spec, weights);
    }
    let g = spec.groups;
    let (mg, ng) = (spec.m_per_group(), spec.n_per_group());
    let per = mg * ng * spec.r_k * spec.r_k;
    assert_eq!(weights.len(), g * per, "grouped weight tensor size");
    let mut total = LayerResult {
        layer: spec.name.clone(),
        ..Default::default()
    };
    for gi in 0..g {
        let sub_spec = LayerSpec {
            name: format!("{}#g{gi}", spec.name),
            n: ng,
            m: mg,
            groups: 1,
            ..spec.clone()
        };
        let sub_w = Tensor::from_vec(
            &[mg, ng, spec.r_k, spec.r_k],
            weights.data()[gi * per..(gi + 1) * per].to_vec(),
        );
        let r = acc.simulate_layer(&sub_spec, &sub_w);
        total.mem.add(&r.mem);
        total.alu.add(&r.alu);
        total.cycles += r.cycles;
        total.compression.add(&r.compression);
        total.energy.add(&r.energy);
    }
    total
}

/// Simulate every conv layer of a workload on `acc`.
pub fn simulate_model(acc: &dyn Accelerator, workload: &Workload, group: &str) -> ModelResult {
    let layers = workload
        .conv_layers()
        .map(|(spec, w)| simulate_layer_grouped(acc, spec, w))
        .collect();
    ModelResult {
        arch: acc.name().to_string(),
        model: workload.model.name.to_string(),
        group: group.to_string(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MemoryKind;

    #[test]
    fn model_result_aggregates() {
        let mut l1 = LayerResult {
            layer: "a".into(),
            cycles: 10,
            ..Default::default()
        };
        l1.mem.record(MemoryKind::InputSram, 5, 8);
        l1.compression.num_weights = 100;
        l1.compression.encoded_bits = 200;
        let mut l2 = LayerResult {
            layer: "b".into(),
            cycles: 32,
            ..Default::default()
        };
        l2.mem.record(MemoryKind::InputSram, 3, 8);
        l2.compression.num_weights = 50;
        l2.compression.encoded_bits = 100;
        let mr = ModelResult {
            arch: "x".into(),
            model: "m".into(),
            group: "Orig".into(),
            layers: vec![l1, l2],
        };
        assert_eq!(mr.cycles(), 42);
        assert_eq!(mr.mem().input_sram.accesses, 8);
        let c = mr.compression();
        assert_eq!(c.num_weights, 150);
        assert!((c.bits_per_weight() - 2.0).abs() < 1e-12);
    }
}
