//! Reference layer operators (the functional ground truth).
//!
//! Everything is integer-exact: `u8` activations × `i8` weights accumulated
//! in `i32`. Padding is zero-padding. These operators define the outputs
//! that every accelerator simulation must reproduce exactly through its
//! compressed datapath, and they match the JAX/Pallas golden model compiled
//! into `artifacts/` (f32 there, exact for these magnitudes).

use super::{Accum, Activations, Tensor, Weights};

/// Output spatial size for one dimension.
#[inline]
pub(crate) fn out_dim(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    (input + 2 * pad - kernel) / stride + 1
}

/// Direct 2-D convolution (cross-correlation, as in every CNN framework).
///
/// * `input`  — `[N, R_I, C_I]` u8
/// * `weights`— `[M, N, R_K, C_K]` i8
/// * `bias`   — length `M` (i32), added to every output element
///
/// Returns `[M, R_O, C_O]` i32 pre-activations.
pub fn conv2d(
    input: &Activations,
    weights: &Weights,
    bias: &[i32],
    stride: usize,
    pad: usize,
) -> Accum {
    assert_eq!(input.ndim(), 3, "input must be [N, R_I, C_I]");
    assert_eq!(weights.ndim(), 4, "weights must be [M, N, R_K, C_K]");
    let (n_in, r_i, c_i) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let (m, n_w, r_k, c_k) = (
        weights.shape()[0],
        weights.shape()[1],
        weights.shape()[2],
        weights.shape()[3],
    );
    assert_eq!(n_in, n_w, "input channels mismatch");
    assert_eq!(bias.len(), m, "bias length mismatch");
    assert!(stride >= 1);
    let r_o = out_dim(r_i, r_k, stride, pad);
    let c_o = out_dim(c_i, c_k, stride, pad);

    let mut out = Accum::zeros(&[m, r_o, c_o]);
    for om in 0..m {
        for or in 0..r_o {
            for oc in 0..c_o {
                let mut acc = bias[om];
                for ic in 0..n_in {
                    for kr in 0..r_k {
                        // Signed arithmetic for the padded border.
                        let ir = (or * stride + kr) as isize - pad as isize;
                        if ir < 0 || ir >= r_i as isize {
                            continue;
                        }
                        for kc in 0..c_k {
                            let icol = (oc * stride + kc) as isize - pad as isize;
                            if icol < 0 || icol >= c_i as isize {
                                continue;
                            }
                            let x = input.at3(ic, ir as usize, icol as usize) as i32;
                            let w = weights.at4(om, ic, kr, kc) as i32;
                            acc += x * w;
                        }
                    }
                }
                out.set3(om, or, oc, acc);
            }
        }
    }
    out
}

/// Fully-connected layer: `out[j] = bias[j] + Σ_i in[i]·w[j][i]`.
///
/// * `input` — flattened u8 activations, length `I`
/// * `weights` — `[O, I]` i8
pub fn fc(input: &[u8], weights: &Tensor<i8>, bias: &[i32]) -> Vec<i32> {
    assert_eq!(weights.ndim(), 2);
    let (o, i) = (weights.shape()[0], weights.shape()[1]);
    assert_eq!(input.len(), i, "fc input length mismatch");
    assert_eq!(bias.len(), o);
    let w = weights.data();
    (0..o)
        .map(|j| {
            let row = &w[j * i..(j + 1) * i];
            let mut acc = bias[j];
            for (x, wv) in input.iter().zip(row) {
                acc += *x as i32 * *wv as i32;
            }
            acc
        })
        .collect()
}

/// ReLU on accumulators.
pub fn relu_i32(x: &Accum) -> Accum {
    x.map(|v| v.max(0))
}

/// 2-D max-pool over each channel. `input` is `[C, R, Cc]`.
pub fn maxpool2d(input: &Accum, k: usize, stride: usize) -> Accum {
    assert_eq!(input.ndim(), 3);
    let (c, r_i, c_i) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let r_o = out_dim(r_i, k, stride, 0);
    let c_o = out_dim(c_i, k, stride, 0);
    let mut out = Accum::zeros(&[c, r_o, c_o]);
    for ch in 0..c {
        for or in 0..r_o {
            for oc in 0..c_o {
                let mut best = i32::MIN;
                for kr in 0..k {
                    for kc in 0..k {
                        best = best.max(input.at3(ch, or * stride + kr, oc * stride + kc));
                    }
                }
                out.set3(ch, or, oc, best);
            }
        }
    }
    out
}

/// Requantize i32 accumulators back to u8 activations with a power-of-two
/// right shift (the usual integer-only CNN inference step), saturating.
pub fn requantize(x: &Accum, shift: u32) -> Activations {
    x.map(|v| {
        let v = v >> shift;
        v.clamp(0, 255) as u8
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;


    /// The paper's Fig 3 worked example: N=2 input channels, M=2 output
    /// channels, 4×4 inputs, 2×2 kernels, stride 1, no padding. The figure
    /// shows the first 3-D convolution output value is 21.
    #[test]
    fn paper_fig3_example() {
        // Input channel values chosen to reproduce the figure's partial
        // sums: first channel dot product 14, second 7, total 21.
        // Kernel ch0 = [[1,0],[1,1]], window [[2,4],[4,8]] → 2+4+8 = 14
        // Kernel ch1 = [[1,1],[0,1]], window [[1,2],[3,4]] → 1+2+4 = 7
        let mut input = Activations::zeros(&[2, 4, 4]);
        // channel 0 top-left window
        input.set3(0, 0, 0, 2);
        input.set3(0, 0, 1, 4);
        input.set3(0, 1, 0, 4);
        input.set3(0, 1, 1, 8);
        // channel 1 top-left window
        input.set3(1, 0, 0, 1);
        input.set3(1, 0, 1, 2);
        input.set3(1, 1, 0, 3);
        input.set3(1, 1, 1, 4);

        let mut w = Weights::zeros(&[2, 2, 2, 2]);
        // output channel 0, input channel 0: [[1,0],[1,1]]
        w.set4(0, 0, 0, 0, 1);
        w.set4(0, 0, 1, 0, 1);
        w.set4(0, 0, 1, 1, 1);
        // output channel 0, input channel 1: [[1,1],[0,1]]
        w.set4(0, 1, 0, 0, 1);
        w.set4(0, 1, 0, 1, 1);
        w.set4(0, 1, 1, 1, 1);
        // output channel 1 uses weights {2,3} (the paper's scalar-matrix demo)
        w.set4(1, 0, 0, 0, 2);
        w.set4(1, 1, 1, 1, 3);

        let out = conv2d(&input, &w, &[0, 0], 1, 0);
        assert_eq!(out.shape(), &[2, 3, 3]);
        assert_eq!(out.at3(0, 0, 0), 21);
        // Output ch1 at (0,0): 2·in0(0,0) + 3·in1(1,1) = 2·2 + 3·4 = 16
        assert_eq!(out.at3(1, 0, 0), 16);
    }

    #[test]
    fn identity_kernel_passthrough() {
        let input = Activations::from_fn(&[1, 3, 3], |i| i as u8 + 1);
        let mut w = Weights::zeros(&[1, 1, 1, 1]);
        w.set4(0, 0, 0, 0, 1);
        let out = conv2d(&input, &w, &[0], 1, 0);
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(out.at3(0, r, c), input.at3(0, r, c) as i32);
            }
        }
    }

    #[test]
    fn bias_is_added_once_per_output() {
        let input = Activations::zeros(&[1, 4, 4]);
        let w = Weights::zeros(&[2, 1, 3, 3]);
        let out = conv2d(&input, &w, &[5, -3], 1, 0);
        assert!(out.data()[..4].iter().all(|&v| v == 5));
        assert!(out.data()[4..].iter().all(|&v| v == -3));
    }

    #[test]
    fn stride_and_padding_shapes() {
        let input = Activations::zeros(&[1, 7, 7]);
        let w = Weights::zeros(&[1, 1, 3, 3]);
        assert_eq!(conv2d(&input, &w, &[0], 2, 0).shape(), &[1, 3, 3]);
        assert_eq!(conv2d(&input, &w, &[0], 1, 1).shape(), &[1, 7, 7]);
        assert_eq!(conv2d(&input, &w, &[0], 2, 1).shape(), &[1, 4, 4]);
    }

    #[test]
    fn padding_zeros_contribute_nothing() {
        // All-ones input and kernel: interior outputs see 9 taps, the
        // corner sees only 4.
        let input = Activations::from_fn(&[1, 5, 5], |_| 1);
        let w = Weights::from_fn(&[1, 1, 3, 3], |_| 1);
        let out = conv2d(&input, &w, &[0], 1, 1);
        assert_eq!(out.at3(0, 2, 2), 9);
        assert_eq!(out.at3(0, 0, 0), 4);
        assert_eq!(out.at3(0, 0, 2), 6);
    }

    #[test]
    fn fc_matches_manual() {
        let w = Tensor::from_vec(&[2, 3], vec![1i8, 2, 3, -1, 0, 1]);
        let out = fc(&[1, 2, 3], &w, &[10, 20]);
        assert_eq!(out, vec![10 + 1 + 4 + 9, 20 - 1 + 0 + 3]);
    }

    #[test]
    fn relu_clamps_negatives() {
        let x = Accum::from_vec(&[1, 1, 3], vec![-5, 0, 7]);
        assert_eq!(relu_i32(&x).data(), &[0, 0, 7]);
    }

    #[test]
    fn maxpool_picks_window_max() {
        let x = Accum::from_vec(&[1, 2, 4], vec![1, 5, 2, 0, 3, 4, 9, -1]);
        let out = maxpool2d(&x, 2, 2);
        assert_eq!(out.shape(), &[1, 1, 2]);
        assert_eq!(out.data(), &[5, 9]);
    }

    #[test]
    fn requantize_shifts_and_saturates() {
        let x = Accum::from_vec(&[1, 1, 4], vec![-100, 0, 512, 100_000]);
        let q = requantize(&x, 2);
        assert_eq!(q.data(), &[0, 0, 128, 255]);
    }

    /// Property: convolution is linear in the weights — conv(w1+w2) =
    /// conv(w1) + conv(w2) (exact in i32 for small magnitudes).
    #[test]
    fn prop_conv_linear_in_weights() {
        check(
            30,
            |r, size| {
                let n = 1 + r.index(3);
                let m = 1 + r.index(3);
                let k = 1 + r.index(2);
                let d = (k + 1 + r.index(4 + size / 25)).max(k);
                let input = Activations::from_fn(&[n, d, d], |_| r.below(16) as u8);
                let w1 = Weights::from_fn(&[m, n, k, k], |_| r.below(9) as i8 - 4);
                let w2 = Weights::from_fn(&[m, n, k, k], |_| r.below(9) as i8 - 4);
                (input, w1, w2, m)
            },
            |(input, w1, w2, m)| {
                let bias = vec![0; *m];
                let a = conv2d(input, w1, &bias, 1, 0);
                let b = conv2d(input, w2, &bias, 1, 0);
                let wsum = Weights::from_vec(
                    w1.shape(),
                    w1.data()
                        .iter()
                        .zip(w2.data())
                        .map(|(&x, &y)| x + y)
                        .collect(),
                );
                let s = conv2d(input, &wsum, &bias, 1, 0);
                s.data()
                    .iter()
                    .zip(a.data().iter().zip(b.data()))
                    .all(|(&sv, (&av, &bv))| sv == av + bv)
            },
        );
    }

    /// Property: stride-s conv equals stride-1 conv subsampled.
    #[test]
    fn prop_stride_is_subsampling() {
        check(
            20,
            |r, _| {
                let input = Activations::from_fn(&[2, 8, 8], |_| r.below(8) as u8);
                let w = Weights::from_fn(&[2, 2, 3, 3], |_| r.below(7) as i8 - 3);
                (input, w)
            },
            |(input, w)| {
                let bias = [1, -1];
                let full = conv2d(input, w, &bias, 1, 0);
                let strided = conv2d(input, w, &bias, 2, 0);
                let (ro, co) = (strided.shape()[1], strided.shape()[2]);
                (0..2).all(|m| {
                    (0..ro).all(|r2| {
                        (0..co).all(|c2| strided.at3(m, r2, c2) == full.at3(m, r2 * 2, c2 * 2))
                    })
                })
            },
        );
    }

    /// Property: conv with a kernel that is zero except one tap equals a
    /// shifted copy of the input scaled by that tap.
    #[test]
    fn prop_single_tap_is_shift() {
        check(
            20,
            |r, _| {
                let input = Activations::from_fn(&[1, 6, 6], |_| r.below(32) as u8);
                let kr = r.index(3);
                let kc = r.index(3);
                let wv = (r.below(11) as i8) - 5;
                (input, kr, kc, wv)
            },
            |(input, kr, kc, wv)| {
                let mut w = Weights::zeros(&[1, 1, 3, 3]);
                w.set4(0, 0, *kr, *kc, *wv);
                let out = conv2d(input, &w, &[0], 1, 0);
                (0..4).all(|r| {
                    (0..4).all(|c| {
                        out.at3(0, r, c) == input.at3(0, r + kr, c + kc) as i32 * *wv as i32
                    })
                })
            },
        );
    }

    /// Randomized agreement with a second, differently-ordered conv
    /// implementation (kernel-major accumulation).
    #[test]
    fn prop_conv_agrees_with_scalar_matrix_order() {
        check(
            20,
            |r, _| {
                let n = 1 + r.index(3);
                let m = 1 + r.index(3);
                let input = Activations::from_fn(&[n, 6, 6], |_| r.below(64) as u8);
                let w = Weights::from_fn(&[m, n, 3, 3], |_| (r.below(255) as i64 - 127) as i8);
                (input, w, m, n)
            },
            |(input, w, m, n)| {
                let bias: Vec<i32> = (0..*m as i32).collect();
                let direct = conv2d(input, w, &bias, 1, 0);
                // Scalar-matrix order: for each (m, n, kr, kc) accumulate the
                // shifted input region — CoDR's dataflow (Fig 3b).
                let (ro, co) = (direct.shape()[1], direct.shape()[2]);
                let mut out = Accum::zeros(&[*m, ro, co]);
                for om in 0..*m {
                    for orr in 0..ro {
                        for occ in 0..co {
                            out.set3(om, orr, occ, bias[om]);
                        }
                    }
                }
                for om in 0..*m {
                    for ic in 0..*n {
                        for kr in 0..3 {
                            for kc in 0..3 {
                                let wv = w.at4(om, ic, kr, kc) as i32;
                                if wv == 0 {
                                    continue;
                                }
                                for orr in 0..ro {
                                    for occ in 0..co {
                                        let x = input.at3(ic, orr + kr, occ + kc) as i32;
                                        let cur = out.at3(om, orr, occ);
                                        out.set3(om, orr, occ, cur + wv * x);
                                    }
                                }
                            }
                        }
                    }
                }
                out == direct
            },
        );
    }
}
