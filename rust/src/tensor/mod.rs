//! Minimal integer tensor substrate.
//!
//! The simulators operate on quantized CNN data: `u8` activations, `i8`
//! weights, `i32` accumulators. This module provides the dense containers
//! and the *reference* layer operators (direct convolution, FC, ReLU,
//! max-pool) that every accelerator simulation is checked against — the
//! simulators must reproduce these outputs bit-for-bit through their
//! compressed datapaths.

mod ops;

pub use ops::{conv2d, fc, maxpool2d, relu_i32, requantize};

/// Dense row-major N-d array.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled tensor with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let len = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![T::default(); len],
        }
    }

    /// Build from existing data; `data.len()` must equal the shape volume.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Tensor filled by `f(flat_index)`.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> T) -> Self {
        let len = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..len).map(&mut f).collect(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Flat offset of a multi-index (row-major).
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (d, (&i, &s)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(i < s, "index {i} out of bounds for dim {d} (size {s})");
            off = off * s + i;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: T) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// 3-d accessor (channels, rows, cols) — the activation layout.
    #[inline]
    pub fn at3(&self, c: usize, r: usize, col: usize) -> T {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(c * self.shape[1] + r) * self.shape[2] + col]
    }

    #[inline]
    pub fn set3(&mut self, c: usize, r: usize, col: usize, v: T) {
        debug_assert_eq!(self.shape.len(), 3);
        let o = (c * self.shape[1] + r) * self.shape[2] + col;
        self.data[o] = v;
    }

    /// 4-d accessor (out-ch, in-ch, krow, kcol) — the weight layout.
    #[inline]
    pub fn at4(&self, m: usize, n: usize, r: usize, c: usize) -> T {
        debug_assert_eq!(self.shape.len(), 4);
        let s = &self.shape;
        self.data[((m * s[1] + n) * s[2] + r) * s[3] + c]
    }

    #[inline]
    pub fn set4(&mut self, m: usize, n: usize, r: usize, c: usize, v: T) {
        debug_assert_eq!(self.shape.len(), 4);
        let o = {
            let s = &self.shape;
            ((m * s[1] + n) * s[2] + r) * s[3] + c
        };
        self.data[o] = v;
    }

    /// Map element-wise into a new tensor.
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

/// Activations: `[channels, rows, cols]` of `u8`.
pub type Activations = Tensor<u8>;
/// Weights: `[out_channels, in_channels, k_rows, k_cols]` of `i8`.
pub type Weights = Tensor<i8>;
/// Accumulators / pre-activation outputs: `[channels, rows, cols]` of `i32`.
pub type Accum = Tensor<i32>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_volume() {
        let t: Tensor<i32> = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert!(t.data().iter().all(|&x| x == 0));
    }

    #[test]
    fn offset_row_major() {
        let t: Tensor<u8> = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 0, 0]), 12);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn at3_matches_generic() {
        let t = Tensor::from_fn(&[3, 4, 5], |i| i as u8);
        for c in 0..3 {
            for r in 0..4 {
                for col in 0..5 {
                    assert_eq!(t.at3(c, r, col), t.at(&[c, r, col]));
                }
            }
        }
    }

    #[test]
    fn at4_matches_generic() {
        let t = Tensor::from_fn(&[2, 3, 2, 2], |i| i as i8);
        for m in 0..2 {
            for n in 0..3 {
                for r in 0..2 {
                    for c in 0..2 {
                        assert_eq!(t.at4(m, n, r, c), t.at(&[m, n, r, c]));
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_bad_volume() {
        let _ = Tensor::from_vec(&[2, 2], vec![1u8, 2, 3]);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut t: Tensor<i32> = Tensor::zeros(&[2, 2]);
        t.set(&[1, 0], -7);
        assert_eq!(t.at(&[1, 0]), -7);
        assert_eq!(t.at(&[0, 0]), 0);
    }

    #[test]
    fn map_preserves_shape() {
        let t = Tensor::from_fn(&[2, 5], |i| i as i8);
        let u = t.map(|x| x as i32 * 2);
        assert_eq!(u.shape(), t.shape());
        assert_eq!(u.at(&[1, 4]), 18);
    }
}
