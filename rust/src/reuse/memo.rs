//! Content-addressed weight-vector memo — cross-tile / cross-layer /
//! cross-sweep-point computation reuse for the simulator itself.
//!
//! The paper's thesis is that CNN weights repeat; the simulator should
//! exploit the same fact. Every UCR pipeline run
//! ([`UcrVector::from_weights`]), every per-vector size summary
//! ([`VectorSizeStats::collect`]) and every dataflow metadata derivation
//! ([`VectorMeta::new`]) is a pure function of the linearized weight
//! bytes (plus, for the metadata, the chosen encoding parameters and
//! tile geometry). So the transform of each **distinct** vector is done
//! exactly once per process and shared:
//!
//! * across tiles of one layer (sparse layers repeat vectors heavily —
//!   the all-zero vector alone can be a double-digit share at D=25%);
//! * across layers and models within a sweep;
//! * across sweep points and repeated requests (same seed ⇒ same base
//!   weights), including every connection of a long-running `codr serve`.
//!
//! Keys are the raw weight bytes — candidates are compared
//! byte-for-byte by the map's `Eq` on lookup, so a hash collision can
//! never alias two different vectors and cached results are exactly what
//! a fresh transform would produce. Hit/miss counters feed
//! `SweepStats::{memo_hits, memo_misses}`.
//!
//! Two long-running-service concerns live here too:
//!
//! * **Eviction** — at capacity the cache evicts with a second-chance
//!   (clock) policy inside the incoming key's shard instead of refusing
//!   inserts, so a `codr serve` whose grid overflows `CODR_MEMO_CAP`
//!   keeps a warm hit rate on the vectors that are hot *now*;
//! * **Persistence** — [`VectorCache::save_snapshot`] /
//!   [`VectorCache::load_snapshot`] write/restore the memo as a compact
//!   binary file (size-capped, per-entry checksummed), so a restarted
//!   `codr serve` starts with yesterday's transforms instead of a cold
//!   cache. Loaded entries enter the same byte-keyed map, so lookups
//!   stay byte-verified exactly like the in-memory path.

use super::UcrVector;
use crate::codr::dataflow::VectorMeta;
use crate::rle::VectorSizeStats;
use crate::util::hash::{fnv1a64, FxBuildHasher};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::hash::{BuildHasher, Hash, Hasher};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Lock striping: vectors hash uniformly, so 64 shards keep the memo
/// uncontended even with every pool worker hitting it.
const SHARDS: usize = 64;

/// Default soft cap on cached vectors (entries, not bytes). A 3×3 CoDR
/// vector entry is a few hundred bytes, so the default bounds the memo
/// around the low hundreds of MB in the worst case. Override with
/// `CODR_MEMO_CAP`.
const DEFAULT_CAPACITY: usize = 1 << 19;

/// `(delta_bits, count_bits, t_m, kernel)` — everything
/// [`VectorMeta::new`] depends on besides the vector itself.
type MetaKey = (u32, u32, usize, usize);

/// Everything derived from one distinct linearized weight vector.
pub struct CachedVector {
    /// The sorted/densified/unified form (UCR steps iv–v).
    pub ucr: UcrVector,
    /// Per-vector encoded-size summary for `LayerHistograms::merge_vector`.
    pub size: VectorSizeStats,
    /// Dataflow metadata per (encoding parameters, tile geometry) — a
    /// layer's parameter search picks the key, so the tiny linear map
    /// almost always holds one entry. Deliberately *not* persisted in
    /// snapshots: it is cheap to rederive and keyed by runtime tile
    /// geometry.
    metas: Mutex<Vec<(MetaKey, Arc<VectorMeta>)>>,
    /// Second-chance (clock) reference bit: set on every hit, cleared as
    /// the eviction scan passes over the entry.
    hot: AtomicBool,
}

impl CachedVector {
    fn new(weights: &[i8]) -> CachedVector {
        let ucr = UcrVector::from_weights(weights);
        let size = VectorSizeStats::collect(&ucr);
        Self::from_parts(ucr, size, true)
    }

    fn from_parts(ucr: UcrVector, size: VectorSizeStats, hot: bool) -> CachedVector {
        CachedVector {
            ucr,
            size,
            metas: Mutex::new(Vec::new()),
            // Fresh transforms start hot (one full clock revolution of
            // protection); snapshot-restored entries start cold so an
            // overflowing grid sheds unproven history first.
            hot: AtomicBool::new(hot),
        }
    }

    /// Dataflow metadata under the given encoding parameters and tile
    /// geometry, derived once per distinct key.
    pub fn meta_for(
        &self,
        delta_bits: u32,
        count_bits: u32,
        t_m: usize,
        kernel: usize,
    ) -> Arc<VectorMeta> {
        let key: MetaKey = (delta_bits, count_bits, t_m, kernel);
        let mut metas = self.metas.lock().unwrap();
        if let Some((_, m)) = metas.iter().find(|(k, _)| *k == key) {
            return Arc::clone(m);
        }
        let m = Arc::new(VectorMeta::new(&self.ucr, delta_bits, count_bits, t_m, kernel));
        metas.push((key, Arc::clone(&m)));
        m
    }
}

/// One stripe of the cache: weight bytes → transform, FxHash-indexed.
type Shard = HashMap<Box<[i8]>, Arc<CachedVector>, FxBuildHasher>;

/// Sharded, capacity-bounded map from weight bytes to [`CachedVector`].
pub struct VectorCache {
    shards: Vec<Mutex<Shard>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    entries: AtomicUsize,
    capacity: usize,
}

impl VectorCache {
    /// A cache holding at most ~`capacity` entries. At capacity a new
    /// distinct vector evicts a second-chance victim from its own shard
    /// (shard selection is hash-uniform, so this approximates global
    /// random-with-second-chance) instead of being dropped — a
    /// long-running `codr serve` keeps a warm hit rate on grids that
    /// overflow the cap. Only when the incoming shard is empty at
    /// capacity is the transform served uncached, which keeps the bound
    /// hard.
    pub fn with_capacity(capacity: usize) -> VectorCache {
        VectorCache {
            shards: (0..SHARDS)
                .map(|_| Mutex::new(HashMap::with_hasher(FxBuildHasher)))
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            entries: AtomicUsize::new(0),
            capacity: capacity.max(1),
        }
    }

    /// The shard a weight vector lives in. Shard on the HIGH bits: the
    /// shard's HashMap buckets on the low bits of this same hash, so
    /// selecting shards by the low bits would leave every table using
    /// 1/SHARDS of its buckets.
    fn shard_for(&self, weights: &[i8]) -> &Mutex<Shard> {
        let mut hasher = FxBuildHasher.build_hasher();
        weights.hash(&mut hasher);
        &self.shards[(hasher.finish() >> 32) as usize % SHARDS]
    }

    /// Look up (or transform and insert) one linearized weight vector.
    pub fn get_or_insert(&self, weights: &[i8]) -> Arc<CachedVector> {
        let shard = self.shard_for(weights);
        {
            let map = shard.lock().unwrap();
            if let Some(e) = map.get(weights) {
                e.hot.store(true, Ordering::Relaxed);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(e);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Transform outside the lock; if a racing worker inserted the
        // same vector meanwhile, its (identical) entry wins.
        let entry = Arc::new(CachedVector::new(weights));
        let mut map = shard.lock().unwrap();
        if let Some(e) = map.get(weights) {
            return Arc::clone(e);
        }
        if self.entries.load(Ordering::Relaxed) >= self.capacity {
            // Second-chance scan: clear reference bits until a cold
            // entry turns up; if every resident was hot, the first one
            // (now cleared) goes.
            let mut victim: Option<Box<[i8]>> = None;
            for (k, v) in map.iter() {
                if v.hot.swap(false, Ordering::Relaxed) {
                    continue;
                }
                victim = Some(k.clone());
                break;
            }
            let victim = victim.or_else(|| map.keys().next().cloned());
            match victim {
                Some(k) => {
                    map.remove(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => return entry, // empty shard at cap: serve uncached
            }
            map.insert(weights.to_vec().into_boxed_slice(), Arc::clone(&entry));
        } else {
            map.insert(weights.to_vec().into_boxed_slice(), Arc::clone(&entry));
            drop(map);
            self.entries.fetch_add(1, Ordering::Relaxed);
        }
        entry
    }

    /// Cumulative (hits, misses) since construction. Sweeps report the
    /// delta across their run; under concurrent sweeps the split between
    /// them is approximate (the totals are exact).
    pub fn counters(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Entries evicted by the second-chance policy since construction
    /// (zero until the cache first fills). Reported by the serve
    /// `status` verb.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Write the memo to `path` as a compact binary snapshot (atomic
    /// temp-file + rename; the temp file is removed on failure). At most
    /// `cap_bytes` are written — when the memo is larger, whatever fits
    /// is snapshotted and the rest simply recomputes next run. Returns
    /// the number of entries written.
    pub fn save_snapshot(&self, path: &Path, cap_bytes: u64) -> Result<usize> {
        let mut buf = Vec::with_capacity(1 << 16);
        buf.extend_from_slice(SNAPSHOT_MAGIC);
        let mut written = 0usize;
        'shards: for shard in &self.shards {
            let map = shard.lock().unwrap();
            for (weights, entry) in map.iter() {
                let payload = encode_snapshot_entry(weights, entry);
                if (buf.len() + payload.len() + 12) as u64 > cap_bytes {
                    break 'shards;
                }
                put_u32(&mut buf, payload.len() as u32);
                buf.extend_from_slice(&payload);
                put_u64(&mut buf, fnv1a64(&payload));
                written += 1;
            }
        }
        let dir = path.parent().unwrap_or_else(|| Path::new("."));
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        let tmp = dir.join(format!(".{name}.tmp-{}", std::process::id()));
        if let Err(e) = std::fs::write(&tmp, &buf) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("writing {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("renaming to {}", path.display()));
        }
        Ok(written)
    }

    /// [`Self::save_snapshot`] under the standard cap
    /// ([`snapshot_cap_bytes`]), unless the memo is empty — an empty
    /// save would clobber a possibly-warm on-disk snapshot with a cold
    /// one. The single policy point for every snapshot writer (serve
    /// shutdown, the periodic background writer, local `codr warm`).
    /// Returns the entries written; `Ok(0)` means skipped-or-nothing.
    pub fn save_snapshot_if_warm(&self, path: &Path) -> Result<usize> {
        if self.is_empty() {
            return Ok(0);
        }
        self.save_snapshot(path, snapshot_cap_bytes())
    }

    /// Restore entries from a snapshot written by [`Self::save_snapshot`].
    /// A missing file is an empty snapshot (`Ok(0)`). Damage degrades by
    /// the smallest recoverable unit: a check-mismatched or structurally
    /// invalid entry is skipped, a broken frame ends the restore —
    /// either way the affected vectors just recompute on first use.
    /// Restored entries live in the same byte-keyed map as fresh
    /// transforms, so every later lookup byte-verifies them exactly like
    /// the in-memory path. Loading stops at capacity and never evicts
    /// live entries; hit/miss counters are untouched.
    pub fn load_snapshot(&self, path: &Path) -> Result<usize> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        if bytes.len() < SNAPSHOT_MAGIC.len() || !bytes.starts_with(SNAPSHOT_MAGIC) {
            bail!("{} is not a codr memo snapshot", path.display());
        }
        let mut pos = SNAPSHOT_MAGIC.len();
        let mut loaded = 0usize;
        while pos < bytes.len() {
            if self.entries.load(Ordering::Relaxed) >= self.capacity {
                break;
            }
            let Some((payload, check)) = read_frame(&bytes, &mut pos) else {
                break; // framing lost: the rest is unreachable
            };
            if fnv1a64(payload) != check {
                continue; // damaged entry, framing still intact
            }
            let Ok((weights, entry)) = decode_snapshot_entry(payload) else {
                continue;
            };
            let mut map = self.shard_for(&weights).lock().unwrap();
            if map.contains_key(&weights[..]) {
                continue;
            }
            map.insert(weights, Arc::new(entry));
            drop(map);
            self.entries.fetch_add(1, Ordering::Relaxed);
            loaded += 1;
        }
        Ok(loaded)
    }

    /// Drop every cached vector (used by `codr bench` to measure the
    /// cold path). Counters are preserved.
    pub fn flush(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
        self.entries.store(0, Ordering::Relaxed);
    }

    /// Cached distinct vectors.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Snapshot file prefix: magic + format version byte. Bump the trailing
/// byte on any layout change — old snapshots then fail the magic check
/// and degrade to a cold cache, never to wrong transforms.
const SNAPSHOT_MAGIC: &[u8; 8] = b"CODRMEM\x01";

/// Default snapshot size cap (bytes). Override with
/// `CODR_MEMO_SNAPSHOT_CAP_MB`.
pub const DEFAULT_SNAPSHOT_CAP_BYTES: u64 = 64 << 20;

/// The snapshot size cap honoring `CODR_MEMO_SNAPSHOT_CAP_MB`.
pub fn snapshot_cap_bytes() -> u64 {
    std::env::var("CODR_MEMO_SNAPSHOT_CAP_MB")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(|mb| mb << 20)
        .unwrap_or(DEFAULT_SNAPSHOT_CAP_BYTES)
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// One length-prefixed, checksum-suffixed frame: `len u32 | payload |
/// fnv1a64(payload) u64`, all little-endian.
fn read_frame<'a>(bytes: &'a [u8], pos: &mut usize) -> Option<(&'a [u8], u64)> {
    let len = u32::from_le_bytes(bytes.get(*pos..*pos + 4)?.try_into().ok()?) as usize;
    *pos += 4;
    let payload = bytes.get(*pos..*pos + len)?;
    *pos += len;
    let check = u64::from_le_bytes(bytes.get(*pos..*pos + 8)?.try_into().ok()?);
    *pos += 8;
    Some((payload, check))
}

fn encode_snapshot_entry(weights: &[i8], e: &CachedVector) -> Vec<u8> {
    let mut p = Vec::with_capacity(weights.len() + e.ucr.indexes.len() * 2 + 64);
    put_u32(&mut p, weights.len() as u32);
    p.extend(weights.iter().map(|&w| w as u8));
    put_u32(&mut p, e.ucr.uniques.len() as u32);
    p.extend(e.ucr.uniques.iter().map(|&w| w as u8));
    for &c in &e.ucr.counts {
        put_u32(&mut p, c);
    }
    put_u32(&mut p, e.ucr.indexes.len() as u32);
    for &i in &e.ucr.indexes {
        p.extend_from_slice(&i.to_le_bytes());
    }
    put_u32(&mut p, e.ucr.len as u32);
    put_u32(&mut p, e.size.deltas.len() as u32);
    p.extend_from_slice(&e.size.deltas);
    put_u32(&mut p, e.size.idx_deltas.len() as u32);
    for &(d, n) in &e.size.idx_deltas {
        p.extend_from_slice(&d.to_le_bytes());
        put_u32(&mut p, n);
    }
    put_u64(&mut p, e.size.n_idx_abs);
    put_u64(&mut p, e.size.n_indexes);
    p
}

/// Little-endian cursor over one snapshot payload.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .b
            .get(self.pos..self.pos + n)
            .context("truncated snapshot entry")?;
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn decode_snapshot_entry(payload: &[u8]) -> Result<(Box<[i8]>, CachedVector)> {
    let mut r = Reader { b: payload, pos: 0 };
    let w_len = r.u32()? as usize;
    let weights: Box<[i8]> = r.take(w_len)?.iter().map(|&b| b as i8).collect();
    let n_uniques = r.u32()? as usize;
    let uniques: Vec<i8> = r.take(n_uniques)?.iter().map(|&b| b as i8).collect();
    let counts: Vec<u32> = (0..n_uniques).map(|_| r.u32()).collect::<Result<_>>()?;
    let n_indexes = r.u32()? as usize;
    let indexes: Vec<u16> = (0..n_indexes).map(|_| r.u16()).collect::<Result<_>>()?;
    let len = r.u32()? as usize;
    let n_deltas = r.u32()? as usize;
    let deltas = r.take(n_deltas)?.to_vec();
    let n_idx_deltas = r.u32()? as usize;
    let idx_deltas: Vec<(u16, u32)> = (0..n_idx_deltas)
        .map(|_| Ok((r.u16()?, r.u32()?)))
        .collect::<Result<_>>()?;
    let n_idx_abs = r.u64()?;
    let size_n_indexes = r.u64()?;
    if r.pos != payload.len() {
        bail!("trailing bytes in snapshot entry");
    }
    let ucr = UcrVector {
        uniques,
        counts,
        indexes,
        len,
    };
    let size = VectorSizeStats {
        deltas,
        idx_deltas,
        n_idx_abs,
        n_indexes: size_n_indexes,
    };
    validate_snapshot_parts(&weights, &ucr, &size)?;
    Ok((weights, CachedVector::from_parts(ucr, size, false)))
}

/// Structural invariants of a restored entry — everything a cheap check
/// can promise without rerunning the transform (the per-entry checksum
/// already rules out random corruption; this rules out well-formed
/// snapshots from a build with different semantics).
fn validate_snapshot_parts(weights: &[i8], ucr: &UcrVector, size: &VectorSizeStats) -> Result<()> {
    if ucr.len != weights.len() {
        bail!("snapshot entry: vector length mismatch");
    }
    if !ucr.uniques.windows(2).all(|w| w[0] < w[1]) || ucr.uniques.contains(&0) {
        bail!("snapshot entry: uniques not sorted/distinct/non-zero");
    }
    let nnz: usize = ucr.counts.iter().map(|&c| c as usize).sum();
    if nnz != ucr.indexes.len() {
        bail!("snapshot entry: counts do not cover the index buffer");
    }
    if ucr.indexes.iter().any(|&i| i as usize >= ucr.len) {
        bail!("snapshot entry: index out of range");
    }
    if size.n_indexes != ucr.indexes.len() as u64 {
        bail!("snapshot entry: size summary disagrees with the vector");
    }
    if size.deltas.len() != ucr.uniques.len().saturating_sub(1) {
        bail!("snapshot entry: delta count disagrees with the uniques");
    }
    Ok(())
}

/// The process-wide memo every simulator path shares.
pub fn global() -> &'static VectorCache {
    static CACHE: OnceLock<VectorCache> = OnceLock::new();
    CACHE.get_or_init(|| {
        let cap = std::env::var("CODR_MEMO_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAPACITY);
        VectorCache::with_capacity(cap)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_returns_identical_transform() {
        let cache = VectorCache::with_capacity(1024);
        let v = [3i8, 0, 1, 3, 0, 1, 1, 4];
        let a = cache.get_or_insert(&v);
        let b = cache.get_or_insert(&v);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the entry");
        assert_eq!(a.ucr, UcrVector::from_weights(&v));
        assert_eq!(a.size, VectorSizeStats::collect(&a.ucr));
        assert_eq!(cache.counters(), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_vectors_never_alias() {
        let cache = VectorCache::with_capacity(1024);
        let a = cache.get_or_insert(&[1i8, 2, 3]);
        let b = cache.get_or_insert(&[1i8, 2, 4]);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.ucr.reconstruct(), vec![1, 2, 3]);
        assert_eq!(b.ucr.reconstruct(), vec![1, 2, 4]);
        // Same bytes at a different length are a different vector.
        let c = cache.get_or_insert(&[1i8, 2, 3, 0]);
        assert_eq!(c.ucr.len, 4);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn meta_for_computes_once_per_key() {
        let cache = VectorCache::with_capacity(16);
        let e = cache.get_or_insert(&[5i8, 0, 5, -1, 0, 0, 2, 2, 2]);
        let m1 = e.meta_for(2, 3, 1, 9);
        let m2 = e.meta_for(2, 3, 1, 9);
        assert!(Arc::ptr_eq(&m1, &m2));
        let m3 = e.meta_for(3, 3, 1, 9);
        assert!(!Arc::ptr_eq(&m1, &m3));
        assert_eq!(m1.nnz, 6);
    }

    #[test]
    fn capacity_bounds_entries_without_breaking_lookups() {
        let cache = VectorCache::with_capacity(2);
        cache.get_or_insert(&[1i8]);
        cache.get_or_insert(&[2i8]);
        // Full: the next distinct vector is still transformed correctly,
        // and the hard bound holds whether it was admitted by eviction
        // or served uncached.
        let e = cache.get_or_insert(&[3i8]);
        assert_eq!(e.ucr.reconstruct(), vec![3]);
        assert!(cache.len() <= 2);
        // Flush resets occupancy.
        cache.flush();
        assert!(cache.is_empty());
        cache.get_or_insert(&[3i8]);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn at_capacity_eviction_keeps_admitting_new_vectors() {
        // Capacity 1: the single resident's shard is a moving target, so
        // a stream of distinct vectors must trigger second-chance
        // evictions (expected within ~SHARDS inserts; the generous bound
        // keeps the test deterministic-by-construction, not timing).
        let cache = VectorCache::with_capacity(1);
        cache.get_or_insert(&[42i8, 1]);
        let mut evicted_key: Option<Vec<i8>> = None;
        for i in 0..10_000u32 {
            let v = [i as i8, (i >> 8) as i8, 7];
            cache.get_or_insert(&v);
            if cache.evictions() > 0 {
                evicted_key = Some(v.to_vec());
                break;
            }
        }
        let newest = evicted_key.expect("an eviction must occur well before 10k inserts");
        assert_eq!(cache.len(), 1, "hard bound holds through evictions");
        // The entry admitted by the eviction is resident: looking it up
        // again is a hit, not a re-transform.
        let (h0, m0) = cache.counters();
        cache.get_or_insert(&newest);
        assert_eq!(cache.counters(), (h0 + 1, m0));
    }

    fn snapshot_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("codr-memo-snap-{tag}-{}", std::process::id()))
    }

    #[test]
    fn snapshot_round_trips_entries_without_retransforming() {
        let a = VectorCache::with_capacity(64);
        let vectors: Vec<Vec<i8>> = vec![
            vec![3, 0, 1, 3, 0, 1, 1, 4],
            vec![0; 16], // all-zero vector (empty UCR form)
            vec![-5, 7, -5, 0, 2], // negative weights
            vec![1],
        ];
        for v in &vectors {
            a.get_or_insert(v);
        }
        let path = snapshot_path("roundtrip");
        let written = a.save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES).unwrap();
        assert_eq!(written, vectors.len());

        let b = VectorCache::with_capacity(64);
        let loaded = b.load_snapshot(&path).unwrap();
        assert_eq!(loaded, vectors.len());
        assert_eq!(b.len(), vectors.len());
        // Restoring must not count as hits or misses.
        assert_eq!(b.counters(), (0, 0));
        // Every restored entry equals a fresh transform and serves as a
        // hit (no re-transform miss).
        for v in &vectors {
            let e = b.get_or_insert(v);
            assert_eq!(e.ucr, UcrVector::from_weights(v));
            assert_eq!(e.size, VectorSizeStats::collect(&e.ucr));
        }
        assert_eq!(b.counters(), (vectors.len() as u64, 0));
        // Metadata rederives on demand from restored entries.
        let e = b.get_or_insert(&vectors[0]);
        assert_eq!(e.meta_for(2, 3, 1, 8).nnz, 6);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn snapshot_damage_degrades_to_fewer_entries_never_wrong_ones() {
        let a = VectorCache::with_capacity(64);
        for i in 1..=6i8 {
            a.get_or_insert(&[i, i, 0, -i]);
        }
        let path = snapshot_path("damage");
        a.save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES).unwrap();
        let clean = std::fs::read(&path).unwrap();

        // Flip one byte in the middle: that entry fails its checksum and
        // is skipped; the snapshot still restores the rest (entries
        // before the flip at minimum — framing after the flipped byte is
        // intact because lengths were untouched).
        let mut bent = clean.clone();
        let mid = clean.len() / 2;
        bent[mid] ^= 0x40;
        std::fs::write(&path, &bent).unwrap();
        let b = VectorCache::with_capacity(64);
        let loaded = b.load_snapshot(&path).unwrap();
        assert!(loaded < 6, "the damaged entry must be dropped");
        // Whatever restored is byte-exact.
        for i in 1..=6i8 {
            let v = [i, i, 0, -i];
            let e = b.get_or_insert(&v);
            assert_eq!(e.ucr, UcrVector::from_weights(&v));
        }

        // Truncation: restore ends at the broken frame, no panic.
        std::fs::write(&path, &clean[..clean.len() - 5]).unwrap();
        let c = VectorCache::with_capacity(64);
        assert!(c.load_snapshot(&path).unwrap() < 6);

        // Not a snapshot at all: clean error, cache untouched.
        std::fs::write(&path, b"junk").unwrap();
        let d = VectorCache::with_capacity(64);
        assert!(d.load_snapshot(&path).is_err());
        assert!(d.is_empty());

        // Missing file: an empty snapshot.
        let _ = std::fs::remove_file(&path);
        assert_eq!(d.load_snapshot(&path).unwrap(), 0);
    }

    #[test]
    fn snapshot_respects_size_and_capacity_caps() {
        let a = VectorCache::with_capacity(64);
        for i in 1..=8i8 {
            a.get_or_insert(&[i; 32]);
        }
        let path = snapshot_path("caps");
        // Tiny byte cap: only what fits is written.
        let written = a.save_snapshot(&path, 200).unwrap();
        assert!(written < 8, "{written} entries in 200 bytes is implausible");
        // Loading respects the destination's entry capacity.
        a.save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES).unwrap();
        let b = VectorCache::with_capacity(3);
        let loaded = b.load_snapshot(&path).unwrap();
        assert!(loaded <= 3);
        assert!(b.len() <= 3);
        let _ = std::fs::remove_file(&path);
    }
}
