//! Content-addressed weight-vector memo — cross-tile / cross-layer /
//! cross-sweep-point computation reuse for the simulator itself.
//!
//! The paper's thesis is that CNN weights repeat; the simulator should
//! exploit the same fact. Every UCR pipeline run
//! ([`UcrVector::from_weights`]), every per-vector size summary
//! ([`VectorSizeStats::collect`]) and every dataflow metadata derivation
//! ([`VectorMeta::new`]) is a pure function of the linearized weight
//! bytes (plus, for the metadata, the chosen encoding parameters and
//! tile geometry). So the transform of each **distinct** vector is done
//! exactly once per process and shared across tiles, layers, models,
//! sweep points, and every connection of a long-running `codr serve`.
//!
//! **Lookup path (this PR's rework).** Keys are 128-bit content
//! fingerprints ([`Fp128`]: two independent FNV/Fx streams), computed
//! once when a vector is linearized and reused for everything — L1
//! indexing, shard selection, map bucketing, and equality. A lookup
//! goes through two levels:
//!
//! 1. **L1** — a small thread-local direct-mapped table of
//!    `(fingerprint → arena handle)`. Repeated vectors within a tile
//!    (the all-zero vector alone can be a double-digit share at D=25%)
//!    resolve here without touching any shared state or lock.
//! 2. **L2** — the sharded `fingerprint → handle` map. Shards are
//!    selected by the high bits of the Fx half, map buckets by the FNV
//!    half, so the two indexes stay uncorrelated. Shard mutexes are
//!    `try_lock`-first; contended acquisitions are counted
//!    (`lock_waits`).
//!
//! Equality is fingerprint equality plus a length guard. A 128-bit
//! match with a *different* length is a detected collision: the lookup
//! falls back to byte verification over the shard's same-fingerprint
//! side chain, and every such verification is counted
//! (`collision_verifies` — zero on any collision-free workload, which a
//! test pins). A same-length collision across both independent 64-bit
//! streams (~2⁻¹²⁸ per pair) is the accepted residual risk.
//!
//! Entries live in an **append-only arena** of [`CachedVector`]s keyed
//! by `u32` handles: lookups return `&CachedVector` borrows instead of
//! cloning an `Arc`, per-entry overhead drops to 20 map bytes (the old
//! map boxed the full weight bytes per key), and snapshot save is a
//! bulk arena walk that never holds a shard lock. Eviction (second
//! chance at `CODR_MEMO_CAP`, unchanged policy) unlinks entries from
//! the map and tombstones them in the arena; the storage is reclaimed
//! only at process exit, which keeps outstanding borrows and stale L1
//! handles valid forever — a stale L1 hit still returns the *correct*
//! transform for those bytes.
//!
//! Persistence ([`VectorCache::save_snapshot`] /
//! [`VectorCache::load_snapshot`]) keeps the PR 3 on-disk format:
//! entries serialize their weight bytes (reconstructed losslessly from
//! the UCR form), so snapshots written by older builds restore into the
//! fingerprint-keyed map and vice versa.

use super::UcrVector;
use crate::codr::dataflow::VectorMeta;
use crate::rle::VectorSizeStats;
use crate::util::bench;
use crate::util::hash::fnv1a64;
pub use crate::util::hash::Fp128;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::hash::{BuildHasher, Hasher};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Lock striping: fingerprints distribute uniformly, so 64 shards keep
/// the memo uncontended even with every pool worker hitting it.
const SHARDS: usize = 64;

/// Default soft cap on cached vectors (entries, not bytes). A 3×3 CoDR
/// vector entry is a few hundred bytes, so the default bounds the memo
/// around the low hundreds of MB in the worst case. Override with
/// `CODR_MEMO_CAP`.
const DEFAULT_CAPACITY: usize = 1 << 19;

/// Thread-local L1 slots (direct-mapped, indexed by the low bits of the
/// fingerprint's Fx half — disjoint from the shard index's high bits).
const L1_SLOTS: usize = 1 << 10;

/// Stripes for the per-lookup counters: each thread is pinned to one
/// stripe, so the hottest counters (`lookups`, `l1_hits`) are relaxed
/// adds on a mostly-thread-private cache line instead of a single
/// contended atomic.
const COUNTER_STRIPES: usize = 16;

/// `(delta_bits, count_bits, t_m, kernel)` — everything
/// [`VectorMeta::new`] depends on besides the vector itself.
type MetaKey = (u32, u32, usize, usize);

/// Everything derived from one distinct linearized weight vector.
pub struct CachedVector {
    /// The sorted/densified/unified form (UCR steps iv–v).
    pub ucr: UcrVector,
    /// Per-vector encoded-size summary for `LayerHistograms::merge_vector`.
    pub size: VectorSizeStats,
    /// Dataflow metadata per (encoding parameters, tile geometry) — a
    /// layer's parameter search picks the key, so the tiny linear map
    /// almost always holds one entry. Deliberately *not* persisted in
    /// snapshots: it is cheap to rederive and keyed by runtime tile
    /// geometry.
    metas: Mutex<Vec<(MetaKey, Arc<VectorMeta>)>>,
    /// Second-chance (clock) reference bit: set on every hit, cleared as
    /// the eviction scan passes over the entry.
    hot: AtomicBool,
    /// Tombstone: the entry was evicted from the map (or served
    /// uncached at capacity). Its arena slot stays valid — outstanding
    /// borrows and stale L1 handles keep working — but snapshots skip
    /// it.
    dead: AtomicBool,
}

impl CachedVector {
    fn new(weights: &[i8]) -> CachedVector {
        let ucr = UcrVector::from_weights(weights);
        let size = VectorSizeStats::collect(&ucr);
        Self::from_parts(ucr, size, true)
    }

    fn from_parts(ucr: UcrVector, size: VectorSizeStats, hot: bool) -> CachedVector {
        CachedVector {
            ucr,
            size,
            metas: Mutex::new(Vec::new()),
            // Fresh transforms start hot (one full clock revolution of
            // protection); snapshot-restored entries start cold so an
            // overflowing grid sheds unproven history first.
            hot: AtomicBool::new(hot),
            dead: AtomicBool::new(false),
        }
    }

    /// Approximate resident bytes (struct + heap buffers), for the
    /// arena accounting the serve `status` verb reports.
    fn approx_bytes(&self) -> usize {
        std::mem::size_of::<CachedVector>()
            + self.ucr.uniques.capacity()
            + self.ucr.counts.capacity() * 4
            + self.ucr.indexes.capacity() * 2
            + self.size.deltas.capacity()
            + self.size.idx_deltas.capacity() * 8
    }

    /// Dataflow metadata under the given encoding parameters and tile
    /// geometry, derived once per distinct key.
    pub fn meta_for(
        &self,
        delta_bits: u32,
        count_bits: u32,
        t_m: usize,
        kernel: usize,
    ) -> Arc<VectorMeta> {
        let key: MetaKey = (delta_bits, count_bits, t_m, kernel);
        let mut metas = self.metas.lock().unwrap();
        if let Some((_, m)) = metas.iter().find(|(k, _)| *k == key) {
            return Arc::clone(m);
        }
        let m = Arc::new(VectorMeta::new(&self.ucr, delta_bits, count_bits, t_m, kernel));
        metas.push((key, Arc::clone(&m)));
        m
    }
}

/// Does `weights` reconstruct exactly to this UCR form? The counted
/// byte-verification fallback behind a detected fingerprint collision.
/// Equivalent to `ucr.reconstruct() == weights` without allocating:
/// every listed position must carry its unique's value, and the
/// non-zero population must match (positions are distinct by
/// construction, so matching population ⇒ the unlisted rest is zero on
/// both sides).
fn entry_matches(weights: &[i8], ucr: &UcrVector) -> bool {
    if ucr.len != weights.len() {
        return false;
    }
    let nnz = weights.iter().filter(|&&w| w != 0).count();
    if nnz != ucr.indexes.len() {
        return false;
    }
    ucr.uniques
        .iter()
        .zip(ucr.index_groups())
        .all(|(&u, group)| group.iter().all(|&i| weights[i as usize] == u))
}

// ---------------------------------------------------------------------------
// Arena
// ---------------------------------------------------------------------------

/// First segment's capacity; segment `s` holds `ARENA_BASE << s`
/// entries, so capacity doubles per segment and the handle space covers
/// `ARENA_BASE · (2^SEGMENTS − 1)` entries with a fixed-size spine.
const ARENA_BASE: usize = 1 << 10;
const ARENA_SEGMENTS: usize = 22;
/// ≈ 4.29 G entries — the `u32` handle space is the real bound; memory
/// exhausts long before either.
const ARENA_MAX: usize = ARENA_BASE * ((1 << ARENA_SEGMENTS) - 1);

/// Segment + offset of a global arena index.
#[inline]
fn arena_locate(idx: usize) -> (usize, usize) {
    let q = idx / ARENA_BASE + 1;
    let s = (usize::BITS - 1 - q.leading_zeros()) as usize;
    (s, idx - ARENA_BASE * ((1 << s) - 1))
}

/// Append-only, lock-free-on-read entry storage. Segments are allocated
/// on demand (`OnceLock`), entries are published once (`OnceLock`) and
/// never move or drop until the arena does, which is what makes `&`
/// borrows and `u32` handles safe to hold across eviction.
struct Arena {
    segments: [OnceLock<Box<[OnceLock<CachedVector>]>>; ARENA_SEGMENTS],
    next: AtomicUsize,
    bytes: AtomicU64,
    /// Bytes held by tombstoned (dead, never-reclaimed) entries — the
    /// arena's reclaimable slack, surfaced by `status` and `codr bench`.
    tombstoned: AtomicU64,
}

impl Arena {
    fn new() -> Arena {
        Arena {
            segments: std::array::from_fn(|_| OnceLock::new()),
            next: AtomicUsize::new(0),
            bytes: AtomicU64::new(0),
            tombstoned: AtomicU64::new(0),
        }
    }

    /// Tombstone one entry, accounting its bytes as reclaimable slack.
    /// The swap makes double-tombstoning (a `flush` over an already
    /// evicted entry) a no-op, so the gauge never double-counts.
    fn tombstone(&self, handle: u32) {
        let entry = self.get(handle);
        if !entry.dead.swap(true, Ordering::Relaxed) {
            self.tombstoned
                .fetch_add(entry.approx_bytes() as u64, Ordering::Relaxed);
        }
    }

    fn tombstoned_bytes(&self) -> u64 {
        self.tombstoned.load(Ordering::Relaxed)
    }

    /// Publish one entry; returns its handle.
    fn push(&self, entry: CachedVector) -> u32 {
        let idx = self.next.fetch_add(1, Ordering::Relaxed);
        assert!(idx < ARENA_MAX, "vector arena exhausted");
        let (s, off) = arena_locate(idx);
        let segment = self.segments[s]
            .get_or_init(|| (0..(ARENA_BASE << s)).map(|_| OnceLock::new()).collect());
        self.bytes
            .fetch_add(entry.approx_bytes() as u64, Ordering::Relaxed);
        if segment[off].set(entry).is_err() {
            unreachable!("arena slot {idx} double-published");
        }
        idx as u32
    }

    /// The entry behind a published handle.
    #[inline]
    fn get(&self, handle: u32) -> &CachedVector {
        let (s, off) = arena_locate(handle as usize);
        self.segments[s].get().expect("arena segment")[off]
            .get()
            .expect("arena entry")
    }

    /// Like [`Self::get`] but tolerant of a slot whose `push` is still
    /// in flight (index reserved, entry not yet set) — the snapshot
    /// walk skips those.
    fn try_get(&self, idx: usize) -> Option<&CachedVector> {
        let (s, off) = arena_locate(idx);
        self.segments[s].get()?[off].get()
    }

    fn len(&self) -> usize {
        self.next.load(Ordering::Relaxed)
    }

    fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// L1 front cache (thread-local)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct L1Slot {
    /// Owning cache's id; 0 = empty slot (ids start at 1).
    cache_id: u64,
    /// Cache generation at store time; a `flush` bumps the cache's
    /// generation, invalidating every thread's slots at once.
    generation: u32,
    handle: u32,
    fp: Fp128,
}

const EMPTY_SLOT: L1Slot = L1Slot {
    cache_id: 0,
    generation: 0,
    handle: 0,
    fp: Fp128 { lo: 0, hi: 0 },
};

struct ThreadState {
    /// This thread's counter stripe (round-robin assigned at first use).
    stripe: usize,
    slots: Box<[L1Slot]>,
}

impl ThreadState {
    fn new() -> ThreadState {
        static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);
        ThreadState {
            stripe: NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) % COUNTER_STRIPES,
            slots: vec![EMPTY_SLOT; L1_SLOTS].into_boxed_slice(),
        }
    }
}

thread_local! {
    static L1: RefCell<ThreadState> = RefCell::new(ThreadState::new());
}

// ---------------------------------------------------------------------------
// The cache
// ---------------------------------------------------------------------------

/// Pass-through hasher for [`Fp128`] keys: the fingerprint *is* the
/// hash, so the map must not hash it again (that second hash was half
/// the old lookup cost). The derived `Hash` writes `lo` then `hi`;
/// folding them keeps bucket bits drawn from both halves.
#[derive(Clone, Copy, Default)]
struct FpHasher(u64);

impl Hasher for FpHasher {
    #[inline]
    fn write(&mut self, _bytes: &[u8]) {
        unreachable!("Fp128 hashes via write_u64");
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.0 = self.0.rotate_left(32) ^ v;
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

#[derive(Clone, Copy, Default)]
struct FpBuildHasher;

impl BuildHasher for FpBuildHasher {
    type Hasher = FpHasher;

    #[inline]
    fn build_hasher(&self) -> FpHasher {
        FpHasher::default()
    }
}

/// One stripe of the L2 map.
#[derive(Default)]
struct Shard {
    /// Primary residents: fingerprint → arena handle.
    map: HashMap<Fp128, u32, FpBuildHasher>,
    /// Same-fingerprint overflow chain. Every entry here shares its
    /// fingerprint with a primary resident (the chain dies with its
    /// primary on eviction); expected empty on real workloads.
    side: Vec<(Fp128, u32)>,
}

/// Per-stripe hot counters (padded to a cache line).
#[repr(align(64))]
#[derive(Default)]
struct CounterStripe {
    lookups: AtomicU64,
    l1_hits: AtomicU64,
}

/// Cumulative lookup-path counters, as reported by
/// [`VectorCache::breakdown`]. All fields are monotonic;
/// [`MemoCounters::since`] yields the delta across a sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoCounters {
    /// Every `get_or_insert` call. At quiescence
    /// `lookups == l1_hits + l2_hits + misses` exactly (the CI smoke
    /// asserts it).
    pub lookups: u64,
    /// Resolved in the thread-local front table — no shared state.
    pub l1_hits: u64,
    /// Resolved in the sharded map under its mutex.
    pub l2_hits: u64,
    /// Transformed (or re-found after a racing transform).
    pub misses: u64,
    /// Byte-verification fallbacks behind a detected fingerprint
    /// collision. Zero on any collision-free workload.
    pub collision_verifies: u64,
    /// Misses whose post-transform re-check found a racing thread's
    /// identical entry — the transform was redundant. Observability for
    /// the unlock/relock window. Below capacity,
    /// `misses == inserted entries + double_computes` exactly; at
    /// capacity, misses served uncached (empty shard) add to the left
    /// side without inserting.
    pub double_computes: u64,
    /// Shard-mutex acquisitions that found the lock held (`try_lock`
    /// failed and the thread had to wait).
    pub lock_waits: u64,
    /// Entries evicted by the second-chance policy (zero until the
    /// cache first fills).
    pub evictions: u64,
}

impl MemoCounters {
    /// L1 + L2 hits.
    pub fn hits(&self) -> u64 {
        self.l1_hits + self.l2_hits
    }

    /// Counter delta since an `earlier` reading.
    pub fn since(&self, earlier: &MemoCounters) -> MemoCounters {
        MemoCounters {
            lookups: self.lookups - earlier.lookups,
            l1_hits: self.l1_hits - earlier.l1_hits,
            l2_hits: self.l2_hits - earlier.l2_hits,
            misses: self.misses - earlier.misses,
            collision_verifies: self.collision_verifies - earlier.collision_verifies,
            double_computes: self.double_computes - earlier.double_computes,
            lock_waits: self.lock_waits - earlier.lock_waits,
            evictions: self.evictions - earlier.evictions,
        }
    }
}

/// Fingerprint-keyed, two-level, capacity-bounded map from weight
/// vectors to arena-interned [`CachedVector`]s. See the module docs for
/// the lookup path.
pub struct VectorCache {
    /// Process-unique id tagging this cache's L1 slots (never recycled,
    /// so a dropped cache's stale slots can never match a live one).
    id: u64,
    /// Bumped by `flush` to invalidate every thread's L1 at once.
    generation: AtomicU32,
    shards: Vec<Mutex<Shard>>,
    arena: Arena,
    stripes: Box<[CounterStripe]>,
    l2_hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    collision_verifies: AtomicU64,
    double_computes: AtomicU64,
    lock_waits: AtomicU64,
    entries: AtomicUsize,
    capacity: usize,
}

impl VectorCache {
    /// A cache holding at most ~`capacity` entries. At capacity a new
    /// distinct vector evicts a second-chance victim from its own shard
    /// (shard selection is fingerprint-uniform, so this approximates
    /// global random-with-second-chance) instead of being dropped — a
    /// long-running `codr serve` keeps a warm hit rate on grids that
    /// overflow the cap. Only when the incoming shard is empty at
    /// capacity is the transform served uncached, which keeps the bound
    /// hard.
    pub fn with_capacity(capacity: usize) -> VectorCache {
        static NEXT_ID: AtomicU64 = AtomicU64::new(1);
        VectorCache {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            generation: AtomicU32::new(0),
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            arena: Arena::new(),
            stripes: (0..COUNTER_STRIPES).map(|_| CounterStripe::default()).collect(),
            l2_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            collision_verifies: AtomicU64::new(0),
            double_computes: AtomicU64::new(0),
            lock_waits: AtomicU64::new(0),
            entries: AtomicUsize::new(0),
            capacity: capacity.max(1),
        }
    }

    /// The shard a fingerprint lives in: the Fx half's HIGH bits. The
    /// map buckets on (a fold dominated by) the FNV half and the L1
    /// indexes on the Fx half's LOW bits, so the three indexes never
    /// share bit regions.
    #[inline]
    fn shard_of(&self, fp: Fp128) -> &Mutex<Shard> {
        &self.shards[(fp.hi >> 58) as usize % SHARDS]
    }

    /// `try_lock` first so contention is observable: a failed fast
    /// acquisition counts one `lock_wait`, then blocks normally.
    fn lock_shard<'a>(&self, shard: &'a Mutex<Shard>) -> std::sync::MutexGuard<'a, Shard> {
        match shard.try_lock() {
            Ok(guard) => guard,
            Err(_) => {
                self.lock_waits.fetch_add(1, Ordering::Relaxed);
                shard.lock().unwrap()
            }
        }
    }

    /// Resolve `fp` inside one locked shard. Fingerprint + length is
    /// the trusted fast path; a length mismatch under an identical
    /// fingerprint is a detected collision and falls back to counted
    /// byte verification over the side chain.
    fn lookup_locked(&self, shard: &Shard, fp: Fp128, weights: &[i8]) -> Option<u32> {
        let &handle = shard.map.get(&fp)?;
        if self.arena.get(handle).ucr.len == weights.len() {
            return Some(handle);
        }
        for &(cfp, chandle) in &shard.side {
            if cfp != fp {
                continue;
            }
            self.collision_verifies.fetch_add(1, Ordering::Relaxed);
            if entry_matches(weights, &self.arena.get(chandle).ucr) {
                return Some(chandle);
            }
        }
        None
    }

    /// Remember `fp → handle` in this thread's L1.
    fn l1_store(&self, fp: Fp128, generation: u32, handle: u32) {
        L1.with(|tls| {
            let mut tls = tls.borrow_mut();
            tls.slots[(fp.hi as usize) & (L1_SLOTS - 1)] = L1Slot {
                cache_id: self.id,
                generation,
                handle,
                fp,
            };
        });
    }

    /// Look up (or transform and insert) one linearized weight vector,
    /// fingerprinting it here. Prefer [`Self::get_or_insert_keyed`]
    /// when the caller already fingerprinted the bytes at extraction.
    pub fn get_or_insert(&self, weights: &[i8]) -> &CachedVector {
        self.get_or_insert_keyed(Fp128::of_i8(weights), weights)
    }

    /// [`Self::get_or_insert`] with a caller-computed fingerprint. `fp`
    /// MUST be `Fp128::of_i8(weights)` — the extraction loops compute
    /// it once per vector and thread it through; tests inject colliding
    /// values here to pin the fallback path.
    pub fn get_or_insert_keyed(&self, fp: Fp128, weights: &[i8]) -> &CachedVector {
        // Acquire pairs with the AcqRel bump in `flush`: a thread that
        // observes the new generation also observes the cleared shards,
        // so its stale L1 slots can never alias a post-flush insert.
        let generation = self.generation.load(Ordering::Acquire);
        // L1: thread-local, lock-free, counter on a thread-pinned stripe.
        let l1 = L1.with(|tls| {
            let mut tls = tls.borrow_mut();
            let stripe = &self.stripes[tls.stripe];
            stripe.lookups.fetch_add(1, Ordering::Relaxed);
            let slot = &mut tls.slots[(fp.hi as usize) & (L1_SLOTS - 1)];
            if slot.cache_id == self.id
                && slot.generation == generation
                && slot.fp == fp
                && self.arena.get(slot.handle).ucr.len == weights.len()
            {
                stripe.l1_hits.fetch_add(1, Ordering::Relaxed);
                return Some(slot.handle);
            }
            None
        });
        if let Some(handle) = l1 {
            let entry = self.arena.get(handle);
            entry.hot.store(true, Ordering::Relaxed);
            return entry;
        }

        // L2: the sharded map.
        let shard = self.shard_of(fp);
        {
            let guard = self.lock_shard(shard);
            if let Some(handle) = self.lookup_locked(&guard, fp, weights) {
                self.l2_hits.fetch_add(1, Ordering::Relaxed);
                drop(guard);
                let entry = self.arena.get(handle);
                entry.hot.store(true, Ordering::Relaxed);
                self.l1_store(fp, generation, handle);
                return entry;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);

        // Transform outside the lock, then re-check under it: a racing
        // worker may have inserted the same vector meanwhile — its
        // (identical) entry wins and the redundant transform is counted.
        let t0 = Instant::now();
        let entry = CachedVector::new(weights);
        bench::phases().add_transform(t0.elapsed());
        let mut guard = self.lock_shard(shard);
        if let Some(handle) = self.lookup_locked(&guard, fp, weights) {
            self.double_computes.fetch_add(1, Ordering::Relaxed);
            drop(guard);
            let entry = self.arena.get(handle);
            entry.hot.store(true, Ordering::Relaxed);
            self.l1_store(fp, generation, handle);
            return entry;
        }

        let handle = self.arena.push(entry);
        let entry = self.arena.get(handle);
        if self.entries.load(Ordering::Relaxed) >= self.capacity {
            // Second-chance scan: clear reference bits until a cold
            // entry turns up; if every resident was hot, the first one
            // (now cleared) goes.
            let mut victim: Option<Fp128> = None;
            for (&k, &h) in guard.map.iter() {
                if self.arena.get(h).hot.swap(false, Ordering::Relaxed) {
                    continue;
                }
                victim = Some(k);
                break;
            }
            let victim = victim.or_else(|| guard.map.keys().next().copied());
            match victim {
                Some(vfp) => {
                    let vhandle = guard.map.remove(&vfp).expect("victim resident");
                    self.arena.tombstone(vhandle);
                    let mut removed = 1usize;
                    // The collision chain dies with its primary.
                    let arena = &self.arena;
                    guard.side.retain(|&(cfp, chandle)| {
                        if cfp == vfp {
                            arena.tombstone(chandle);
                            removed += 1;
                            false
                        } else {
                            true
                        }
                    });
                    self.evictions.fetch_add(removed as u64, Ordering::Relaxed);
                    if removed > 1 {
                        self.entries.fetch_sub(removed - 1, Ordering::Relaxed);
                    }
                    if guard.map.contains_key(&fp) {
                        guard.side.push((fp, handle));
                    } else {
                        guard.map.insert(fp, handle);
                    }
                    drop(guard);
                }
                None => {
                    // Empty shard at cap: no map insert (hard bound).
                    // The arena entry is tombstoned for snapshots, but
                    // it still feeds this thread's L1 below — a hot
                    // vector stuck in an empty-at-cap shard serves from
                    // the front table instead of re-transforming.
                    self.arena.tombstone(handle);
                    drop(guard);
                }
            }
        } else {
            // A primary with this fingerprint may exist and simply not
            // match these bytes (that is what got us past the lookup):
            // chain the new entry beside it.
            if guard.map.contains_key(&fp) {
                guard.side.push((fp, handle));
            } else {
                guard.map.insert(fp, handle);
            }
            drop(guard);
            self.entries.fetch_add(1, Ordering::Relaxed);
        }
        // Every branch has released the shard lock by here.
        self.l1_store(fp, generation, handle);
        entry
    }

    /// Cumulative (hits, misses) since construction — `hits` spans both
    /// levels. Sweeps report the delta across their run; under
    /// concurrent sweeps the split between them is approximate (the
    /// totals are exact).
    pub fn counters(&self) -> (u64, u64) {
        let b = self.breakdown();
        (b.hits(), b.misses)
    }

    /// Full lookup-path counter breakdown (see [`MemoCounters`]).
    pub fn breakdown(&self) -> MemoCounters {
        let mut lookups = 0u64;
        let mut l1_hits = 0u64;
        for stripe in self.stripes.iter() {
            lookups += stripe.lookups.load(Ordering::Relaxed);
            l1_hits += stripe.l1_hits.load(Ordering::Relaxed);
        }
        MemoCounters {
            lookups,
            l1_hits,
            l2_hits: self.l2_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            collision_verifies: self.collision_verifies.load(Ordering::Relaxed),
            double_computes: self.double_computes.load(Ordering::Relaxed),
            lock_waits: self.lock_waits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Entries evicted by the second-chance policy since construction
    /// (zero until the cache first fills). Reported by the serve
    /// `status` verb.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Arena occupancy: `(interned entries, approximate bytes,
    /// tombstoned bytes)`. Entry and byte counts include tombstoned
    /// entries — the arena is append-only, so they are the memo's true
    /// memory footprint; the third field is the share of those bytes
    /// held by dead entries (the reclaimable slack a future compaction
    /// could recover).
    pub fn arena_stats(&self) -> (usize, u64, u64) {
        (
            self.arena.len(),
            self.arena.bytes(),
            self.arena.tombstoned_bytes(),
        )
    }

    /// Write the memo to `path` as a compact binary snapshot (atomic
    /// temp-file + rename; the temp file is removed on failure). The
    /// walk is over the arena — no shard lock is held, so concurrent
    /// lookups never stall behind a snapshot. At most `cap_bytes` are
    /// written; when the memo is larger, whatever fits is snapshotted
    /// and the rest simply recomputes next run. Returns the number of
    /// entries written.
    pub fn save_snapshot(&self, path: &Path, cap_bytes: u64) -> Result<usize> {
        let mut buf = Vec::with_capacity(1 << 16);
        buf.extend_from_slice(SNAPSHOT_MAGIC);
        let mut written = 0usize;
        for idx in 0..self.arena.len() {
            // Skip slots whose push is still in flight and tombstones.
            let Some(entry) = self.arena.try_get(idx) else {
                continue;
            };
            if entry.dead.load(Ordering::Relaxed) {
                continue;
            }
            // The UCR form is lossless; the snapshot keeps the PR 3
            // byte-level format by reconstructing the weights.
            let weights = entry.ucr.reconstruct();
            let payload = encode_snapshot_entry(&weights, entry);
            if (buf.len() + payload.len() + 12) as u64 > cap_bytes {
                break;
            }
            put_u32(&mut buf, payload.len() as u32);
            buf.extend_from_slice(&payload);
            put_u64(&mut buf, fnv1a64(&payload));
            written += 1;
        }
        let dir = path.parent().unwrap_or_else(|| Path::new("."));
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        let tmp = dir.join(format!(".{name}.tmp-{}", std::process::id()));
        // Injection seam: snapshot bit-rot on disk. The per-entry FNV
        // checksums make a flipped bit cost one entry (or one frame) on
        // the next restore — never a wrong transform.
        crate::faults::bitflip_point("memo.snapshot.bitflip", &mut buf);
        if let Err(e) = std::fs::write(&tmp, &buf) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("writing {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("renaming to {}", path.display()));
        }
        Ok(written)
    }

    /// [`Self::save_snapshot`] under the standard cap
    /// ([`snapshot_cap_bytes`]), unless the memo is empty — an empty
    /// save would clobber a possibly-warm on-disk snapshot with a cold
    /// one. The single policy point for every snapshot writer (serve
    /// shutdown, the periodic background writer, local `codr warm`).
    /// Returns the entries written; `Ok(0)` means skipped-or-nothing.
    pub fn save_snapshot_if_warm(&self, path: &Path) -> Result<usize> {
        if self.is_empty() {
            return Ok(0);
        }
        self.save_snapshot(path, snapshot_cap_bytes())
    }

    /// Restore entries from a snapshot written by [`Self::save_snapshot`]
    /// (this build or a pre-fingerprint one — the byte format is
    /// unchanged). A missing file is an empty snapshot (`Ok(0)`).
    /// Damage degrades by the smallest recoverable unit: a
    /// check-mismatched or structurally invalid entry is skipped, a
    /// broken frame ends the restore — either way the affected vectors
    /// just recompute on first use. Restored entries are fingerprinted
    /// from their stored bytes, so later lookups treat them exactly
    /// like in-memory inserts. Loading stops at capacity and never
    /// evicts live entries; hit/miss counters are untouched.
    pub fn load_snapshot(&self, path: &Path) -> Result<usize> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        if bytes.len() < SNAPSHOT_MAGIC.len() || !bytes.starts_with(SNAPSHOT_MAGIC) {
            bail!("{} is not a codr memo snapshot", path.display());
        }
        let mut pos = SNAPSHOT_MAGIC.len();
        let mut loaded = 0usize;
        while pos < bytes.len() {
            if self.entries.load(Ordering::Relaxed) >= self.capacity {
                break;
            }
            let Some((payload, check)) = read_frame(&bytes, &mut pos) else {
                break; // framing lost: the rest is unreachable
            };
            if fnv1a64(payload) != check {
                continue; // damaged entry, framing still intact
            }
            let Ok((weights, entry)) = decode_snapshot_entry(payload) else {
                continue;
            };
            let fp = Fp128::of_i8(&weights);
            let mut guard = self.shard_of(fp).lock().unwrap();
            if guard.map.contains_key(&fp) {
                continue;
            }
            let handle = self.arena.push(entry);
            guard.map.insert(fp, handle);
            drop(guard);
            self.entries.fetch_add(1, Ordering::Relaxed);
            loaded += 1;
        }
        Ok(loaded)
    }

    /// Unlink every cached vector (used by `codr bench` to measure the
    /// cold path). Counters are preserved; a generation bump invalidates
    /// every thread's L1 slots at once. Arena storage is retained
    /// (append-only), so handles held elsewhere stay valid.
    pub fn flush(&self) {
        for shard in &self.shards {
            let mut guard = shard.lock().unwrap();
            for &handle in guard.map.values() {
                self.arena.tombstone(handle);
            }
            for &(_, handle) in &guard.side {
                self.arena.tombstone(handle);
            }
            guard.map.clear();
            guard.side.clear();
        }
        self.entries.store(0, Ordering::Relaxed);
        self.generation.fetch_add(1, Ordering::AcqRel);
    }

    /// Cached distinct vectors (map residents, not arena slots).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let guard = s.lock().unwrap();
                guard.map.len() + guard.side.len()
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Snapshot file prefix: magic + format version byte. Bump the trailing
/// byte on any layout change — old snapshots then fail the magic check
/// and degrade to a cold cache, never to wrong transforms. (The
/// fingerprint rework did NOT bump it: entries still serialize their
/// weight bytes, so snapshots are interchangeable with PR 3/4 builds.)
const SNAPSHOT_MAGIC: &[u8; 8] = b"CODRMEM\x01";

/// Default snapshot size cap (bytes). Override with
/// `CODR_MEMO_SNAPSHOT_CAP_MB`.
pub const DEFAULT_SNAPSHOT_CAP_BYTES: u64 = 64 << 20;

/// The snapshot size cap honoring `CODR_MEMO_SNAPSHOT_CAP_MB`.
pub fn snapshot_cap_bytes() -> u64 {
    crate::analysis::env_registry::var("CODR_MEMO_SNAPSHOT_CAP_MB")
        .and_then(|v| v.parse::<u64>().ok())
        .map(|mb| mb << 20)
        .unwrap_or(DEFAULT_SNAPSHOT_CAP_BYTES)
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// One length-prefixed, checksum-suffixed frame: `len u32 | payload |
/// fnv1a64(payload) u64`, all little-endian.
fn read_frame<'a>(bytes: &'a [u8], pos: &mut usize) -> Option<(&'a [u8], u64)> {
    let len = u32::from_le_bytes(bytes.get(*pos..*pos + 4)?.try_into().ok()?) as usize;
    *pos += 4;
    let payload = bytes.get(*pos..*pos + len)?;
    *pos += len;
    let check = u64::from_le_bytes(bytes.get(*pos..*pos + 8)?.try_into().ok()?);
    *pos += 8;
    Some((payload, check))
}

fn encode_snapshot_entry(weights: &[i8], e: &CachedVector) -> Vec<u8> {
    let mut p = Vec::with_capacity(weights.len() + e.ucr.indexes.len() * 2 + 64);
    put_u32(&mut p, weights.len() as u32);
    p.extend(weights.iter().map(|&w| w as u8));
    put_u32(&mut p, e.ucr.uniques.len() as u32);
    p.extend(e.ucr.uniques.iter().map(|&w| w as u8));
    for &c in &e.ucr.counts {
        put_u32(&mut p, c);
    }
    put_u32(&mut p, e.ucr.indexes.len() as u32);
    for &i in &e.ucr.indexes {
        p.extend_from_slice(&i.to_le_bytes());
    }
    put_u32(&mut p, e.ucr.len as u32);
    put_u32(&mut p, e.size.deltas.len() as u32);
    p.extend_from_slice(&e.size.deltas);
    put_u32(&mut p, e.size.idx_deltas.len() as u32);
    for &(d, n) in &e.size.idx_deltas {
        p.extend_from_slice(&d.to_le_bytes());
        put_u32(&mut p, n);
    }
    put_u64(&mut p, e.size.n_idx_abs);
    put_u64(&mut p, e.size.n_indexes);
    p
}

/// Little-endian cursor over one snapshot payload.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .b
            .get(self.pos..self.pos + n)
            .context("truncated snapshot entry")?;
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn decode_snapshot_entry(payload: &[u8]) -> Result<(Box<[i8]>, CachedVector)> {
    let mut r = Reader { b: payload, pos: 0 };
    let w_len = r.u32()? as usize;
    let weights: Box<[i8]> = r.take(w_len)?.iter().map(|&b| b as i8).collect();
    let n_uniques = r.u32()? as usize;
    let uniques: Vec<i8> = r.take(n_uniques)?.iter().map(|&b| b as i8).collect();
    let counts: Vec<u32> = (0..n_uniques).map(|_| r.u32()).collect::<Result<_>>()?;
    let n_indexes = r.u32()? as usize;
    let indexes: Vec<u16> = (0..n_indexes).map(|_| r.u16()).collect::<Result<_>>()?;
    let len = r.u32()? as usize;
    let n_deltas = r.u32()? as usize;
    let deltas = r.take(n_deltas)?.to_vec();
    let n_idx_deltas = r.u32()? as usize;
    let idx_deltas: Vec<(u16, u32)> = (0..n_idx_deltas)
        .map(|_| Ok((r.u16()?, r.u32()?)))
        .collect::<Result<_>>()?;
    let n_idx_abs = r.u64()?;
    let size_n_indexes = r.u64()?;
    if r.pos != payload.len() {
        bail!("trailing bytes in snapshot entry");
    }
    let ucr = UcrVector {
        uniques,
        counts,
        indexes,
        len,
    };
    let size = VectorSizeStats {
        deltas,
        idx_deltas,
        n_idx_abs,
        n_indexes: size_n_indexes,
    };
    validate_snapshot_parts(&weights, &ucr, &size)?;
    Ok((weights, CachedVector::from_parts(ucr, size, false)))
}

/// Structural invariants of a restored entry — everything a cheap check
/// can promise without rerunning the transform (the per-entry checksum
/// already rules out random corruption; this rules out well-formed
/// snapshots from a build with different semantics).
fn validate_snapshot_parts(weights: &[i8], ucr: &UcrVector, size: &VectorSizeStats) -> Result<()> {
    if ucr.len != weights.len() {
        bail!("snapshot entry: vector length mismatch");
    }
    if !ucr.uniques.windows(2).all(|w| w[0] < w[1]) || ucr.uniques.contains(&0) {
        bail!("snapshot entry: uniques not sorted/distinct/non-zero");
    }
    let nnz: usize = ucr.counts.iter().map(|&c| c as usize).sum();
    if nnz != ucr.indexes.len() {
        bail!("snapshot entry: counts do not cover the index buffer");
    }
    if ucr.indexes.iter().any(|&i| i as usize >= ucr.len) {
        bail!("snapshot entry: index out of range");
    }
    if size.n_indexes != ucr.indexes.len() as u64 {
        bail!("snapshot entry: size summary disagrees with the vector");
    }
    if size.deltas.len() != ucr.uniques.len().saturating_sub(1) {
        bail!("snapshot entry: delta count disagrees with the uniques");
    }
    Ok(())
}

/// The process-wide memo every simulator path shares.
pub fn global() -> &'static VectorCache {
    static CACHE: OnceLock<VectorCache> = OnceLock::new();
    CACHE.get_or_init(|| {
        let cap = crate::analysis::env_registry::var("CODR_MEMO_CAP")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAPACITY);
        VectorCache::with_capacity(cap)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    #[test]
    fn hit_returns_identical_transform() {
        let cache = VectorCache::with_capacity(1024);
        let v = [3i8, 0, 1, 3, 0, 1, 1, 4];
        let a = cache.get_or_insert(&v);
        let b = cache.get_or_insert(&v);
        assert!(std::ptr::eq(a, b), "second lookup must share the entry");
        assert_eq!(a.ucr, UcrVector::from_weights(&v));
        assert_eq!(a.size, VectorSizeStats::collect(&a.ucr));
        assert_eq!(cache.counters(), (1, 1));
        assert_eq!(cache.len(), 1);
        // The repeat resolved in the thread-local L1 (same thread).
        let b = cache.breakdown();
        assert_eq!(b.l1_hits, 1);
        assert_eq!(b.l2_hits, 0);
        assert_eq!(b.lookups, b.l1_hits + b.l2_hits + b.misses);
    }

    #[test]
    fn second_thread_hits_in_l2_not_l1() {
        let cache = VectorCache::with_capacity(64);
        let v = [7i8, 0, -2, 7];
        cache.get_or_insert(&v);
        std::thread::scope(|s| {
            s.spawn(|| {
                let e = cache.get_or_insert(&v);
                assert_eq!(e.ucr, UcrVector::from_weights(&v));
            });
        });
        let b = cache.breakdown();
        // The other thread's L1 was cold; its hit took the shard map.
        assert_eq!((b.l1_hits, b.l2_hits, b.misses), (0, 1, 1));
        assert_eq!(b.lookups, 2);
    }

    #[test]
    fn distinct_vectors_never_alias() {
        let cache = VectorCache::with_capacity(1024);
        let a = cache.get_or_insert(&[1i8, 2, 3]);
        let b = cache.get_or_insert(&[1i8, 2, 4]);
        assert!(!std::ptr::eq(a, b));
        assert_eq!(a.ucr.reconstruct(), vec![1, 2, 3]);
        assert_eq!(b.ucr.reconstruct(), vec![1, 2, 4]);
        // Same bytes at a different length are a different vector.
        let c = cache.get_or_insert(&[1i8, 2, 3, 0]);
        assert_eq!(c.ucr.len, 4);
        assert_eq!(cache.len(), 3);
        // No fingerprint collisions among these, so no byte verifies.
        assert_eq!(cache.breakdown().collision_verifies, 0);
    }

    #[test]
    fn injected_fingerprint_collision_byte_verifies_and_stays_correct() {
        // Two different vectors forced onto ONE 128-bit fingerprint: the
        // length guard detects the collision and the counted byte-verify
        // fallback must return the right entry for each, every time.
        let cache = VectorCache::with_capacity(64);
        let fp = Fp128 { lo: 0x1234, hi: 0x5678 };
        let va = [3i8, 0, 1, 3];
        let vb = [5i8, 0, 4, 4, -1]; // different length ⇒ detectable
        let a = cache.get_or_insert_keyed(fp, &va);
        assert_eq!(a.ucr.reconstruct(), va);
        let b = cache.get_or_insert_keyed(fp, &vb);
        assert!(!std::ptr::eq(a, b), "collision must not alias");
        assert_eq!(b.ucr.reconstruct(), vb);
        assert_eq!(cache.len(), 2, "both residents (primary + side chain)");
        // Re-lookups resolve to the correct entries through the fallback.
        let a2 = cache.get_or_insert_keyed(fp, &va);
        assert_eq!(a2.ucr.reconstruct(), va);
        let b2 = cache.get_or_insert_keyed(fp, &vb);
        assert_eq!(b2.ucr.reconstruct(), vb);
        let bd = cache.breakdown();
        assert!(
            bd.collision_verifies > 0,
            "the fallback byte-verify must have fired: {bd:?}"
        );
        assert_eq!(bd.misses, 2, "each vector transformed exactly once");
        assert_eq!(bd.lookups, bd.l1_hits + bd.l2_hits + bd.misses);
    }

    #[test]
    fn prop_fingerprint_path_matches_direct_transform() {
        // The fingerprint-keyed path must be bit-for-bit identical to
        // transforming directly, and a byte-keyed reference map must
        // agree with the memo's aliasing decisions on every lookup.
        let cache = VectorCache::with_capacity(4096);
        let mut reference: std::collections::HashMap<Vec<i8>, *const CachedVector> =
            std::collections::HashMap::new();
        check(
            200,
            |r, size| {
                let n = 1 + size % 40;
                (0..n)
                    .map(|_| {
                        if r.chance(0.5) {
                            0
                        } else {
                            (r.below(9) as i16 - 4) as i8
                        }
                    })
                    .collect::<Vec<i8>>()
            },
            |v| {
                let e = cache.get_or_insert(v);
                let bitwise = e.ucr == UcrVector::from_weights(v)
                    && e.size == VectorSizeStats::collect(&e.ucr)
                    && e.ucr.reconstruct() == *v;
                let stable = match reference.get(v) {
                    Some(&p) => std::ptr::eq(p, e),
                    None => {
                        reference.insert(v.clone(), e as *const CachedVector);
                        true
                    }
                };
                bitwise && stable
            },
        );
        let b = cache.breakdown();
        assert_eq!(b.collision_verifies, 0, "no real collisions expected");
        assert_eq!(b.lookups, b.l1_hits + b.l2_hits + b.misses);
        assert_eq!(cache.len(), reference.len());
    }

    #[test]
    fn concurrent_inserts_conserve_counters_and_never_alias() {
        let cache = VectorCache::with_capacity(4096);
        let vectors: Vec<Vec<i8>> = (0..32i8)
            .map(|i| vec![i, 0, -i, i ^ 5, 0, 2])
            .collect();
        std::thread::scope(|s| {
            for t in 0..8usize {
                let cache = &cache;
                let vectors = &vectors;
                s.spawn(move || {
                    for round in 0..50usize {
                        for (vi, v) in vectors.iter().enumerate() {
                            if (vi + t + round) % 3 == 0 {
                                continue;
                            }
                            let e = cache.get_or_insert(v);
                            assert_eq!(e.ucr.len, v.len());
                            assert_eq!(e.ucr.reconstruct(), *v);
                        }
                    }
                });
            }
        });
        assert_eq!(cache.len(), vectors.len());
        let b = cache.breakdown();
        // Exact conservation at quiescence, including any racing
        // double-computes (each is a miss that inserted nothing).
        assert_eq!(b.lookups, b.l1_hits + b.l2_hits + b.misses);
        assert_eq!(
            b.double_computes,
            b.misses - vectors.len() as u64,
            "misses == inserted entries + double computes: {b:?}"
        );
        assert_eq!(b.collision_verifies, 0);
    }

    #[test]
    fn meta_for_computes_once_per_key() {
        let cache = VectorCache::with_capacity(16);
        let e = cache.get_or_insert(&[5i8, 0, 5, -1, 0, 0, 2, 2, 2]);
        let m1 = e.meta_for(2, 3, 1, 9);
        let m2 = e.meta_for(2, 3, 1, 9);
        assert!(Arc::ptr_eq(&m1, &m2));
        let m3 = e.meta_for(3, 3, 1, 9);
        assert!(!Arc::ptr_eq(&m1, &m3));
        assert_eq!(m1.nnz, 6);
    }

    #[test]
    fn capacity_bounds_entries_without_breaking_lookups() {
        let cache = VectorCache::with_capacity(2);
        cache.get_or_insert(&[1i8]);
        cache.get_or_insert(&[2i8]);
        // Full: the next distinct vector is still transformed correctly,
        // and the hard bound holds whether it was admitted by eviction
        // or served uncached.
        let e = cache.get_or_insert(&[3i8]);
        assert_eq!(e.ucr.reconstruct(), vec![3]);
        assert!(cache.len() <= 2);
        // Flush resets occupancy (and invalidates every thread's L1 via
        // the generation bump — the relookup below must miss, not serve
        // a stale front-table hit).
        cache.flush();
        assert!(cache.is_empty());
        let (_, m0) = cache.counters();
        cache.get_or_insert(&[3i8]);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.counters().1, m0 + 1, "post-flush lookup is a miss");
    }

    #[test]
    fn at_capacity_eviction_keeps_admitting_new_vectors() {
        // Capacity 1: the single resident's shard is a moving target, so
        // a stream of distinct vectors must trigger second-chance
        // evictions (expected within ~SHARDS inserts; the generous bound
        // keeps the test deterministic-by-construction, not timing).
        let cache = VectorCache::with_capacity(1);
        cache.get_or_insert(&[42i8, 1]);
        let mut evicted_key: Option<Vec<i8>> = None;
        for i in 0..10_000u32 {
            let v = [i as i8, (i >> 8) as i8, 7];
            cache.get_or_insert(&v);
            if cache.evictions() > 0 {
                evicted_key = Some(v.to_vec());
                break;
            }
        }
        let newest = evicted_key.expect("an eviction must occur well before 10k inserts");
        assert_eq!(cache.len(), 1, "hard bound holds through evictions");
        // The entry admitted by the eviction is resident: looking it up
        // again is a hit, not a re-transform.
        let (h0, m0) = cache.counters();
        cache.get_or_insert(&newest);
        assert_eq!(cache.counters(), (h0 + 1, m0));
    }

    fn snapshot_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("codr-memo-snap-{tag}-{}", std::process::id()))
    }

    #[test]
    fn snapshot_round_trips_entries_without_retransforming() {
        let a = VectorCache::with_capacity(64);
        let vectors: Vec<Vec<i8>> = vec![
            vec![3, 0, 1, 3, 0, 1, 1, 4],
            vec![0; 16], // all-zero vector (empty UCR form)
            vec![-5, 7, -5, 0, 2], // negative weights
            vec![1],
        ];
        for v in &vectors {
            a.get_or_insert(v);
        }
        let path = snapshot_path("roundtrip");
        let written = a.save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES).unwrap();
        assert_eq!(written, vectors.len());

        let b = VectorCache::with_capacity(64);
        let loaded = b.load_snapshot(&path).unwrap();
        assert_eq!(loaded, vectors.len());
        assert_eq!(b.len(), vectors.len());
        // Restoring must not count as hits or misses.
        assert_eq!(b.counters(), (0, 0));
        // Every restored entry equals a fresh transform and serves as a
        // hit (no re-transform miss).
        for v in &vectors {
            let e = b.get_or_insert(v);
            assert_eq!(e.ucr, UcrVector::from_weights(v));
            assert_eq!(e.size, VectorSizeStats::collect(&e.ucr));
        }
        assert_eq!(b.counters(), (vectors.len() as u64, 0));
        // Metadata rederives on demand from restored entries.
        let e = b.get_or_insert(&vectors[0]);
        assert_eq!(e.meta_for(2, 3, 1, 8).nnz, 6);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn snapshot_skips_tombstoned_arena_entries() {
        let a = VectorCache::with_capacity(64);
        for i in 1..=4i8 {
            a.get_or_insert(&[i, 0, i]);
        }
        a.flush(); // tombstones all four in the arena
        a.get_or_insert(&[9i8, 9]);
        let path = snapshot_path("tombstone");
        let written = a.save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES).unwrap();
        assert_eq!(written, 1, "only the live resident is snapshotted");
        let b = VectorCache::with_capacity(64);
        assert_eq!(b.load_snapshot(&path).unwrap(), 1);
        let e = b.get_or_insert(&[9i8, 9]);
        assert_eq!(e.ucr.reconstruct(), vec![9, 9]);
        assert_eq!(b.counters(), (1, 0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn snapshot_damage_degrades_to_fewer_entries_never_wrong_ones() {
        let a = VectorCache::with_capacity(64);
        for i in 1..=6i8 {
            a.get_or_insert(&[i, i, 0, -i]);
        }
        let path = snapshot_path("damage");
        a.save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES).unwrap();
        let clean = std::fs::read(&path).unwrap();

        // Flip one byte in the middle: that entry fails its checksum and
        // is skipped; the snapshot still restores the rest (entries
        // before the flip at minimum — framing after the flipped byte is
        // intact because lengths were untouched).
        let mut bent = clean.clone();
        let mid = clean.len() / 2;
        bent[mid] ^= 0x40;
        std::fs::write(&path, &bent).unwrap();
        let b = VectorCache::with_capacity(64);
        let loaded = b.load_snapshot(&path).unwrap();
        assert!(loaded < 6, "the damaged entry must be dropped");
        // Whatever restored is byte-exact.
        for i in 1..=6i8 {
            let v = [i, i, 0, -i];
            let e = b.get_or_insert(&v);
            assert_eq!(e.ucr, UcrVector::from_weights(&v));
        }

        // Truncation: restore ends at the broken frame, no panic.
        std::fs::write(&path, &clean[..clean.len() - 5]).unwrap();
        let c = VectorCache::with_capacity(64);
        assert!(c.load_snapshot(&path).unwrap() < 6);

        // Not a snapshot at all: clean error, cache untouched.
        std::fs::write(&path, b"junk").unwrap();
        let d = VectorCache::with_capacity(64);
        assert!(d.load_snapshot(&path).is_err());
        assert!(d.is_empty());

        // Missing file: an empty snapshot.
        let _ = std::fs::remove_file(&path);
        assert_eq!(d.load_snapshot(&path).unwrap(), 0);
    }

    #[test]
    fn snapshot_respects_size_and_capacity_caps() {
        let a = VectorCache::with_capacity(64);
        for i in 1..=8i8 {
            a.get_or_insert(&[i; 32]);
        }
        let path = snapshot_path("caps");
        // Tiny byte cap: only what fits is written.
        let written = a.save_snapshot(&path, 200).unwrap();
        assert!(written < 8, "{written} entries in 200 bytes is implausible");
        // Loading respects the destination's entry capacity.
        a.save_snapshot(&path, DEFAULT_SNAPSHOT_CAP_BYTES).unwrap();
        let b = VectorCache::with_capacity(3);
        let loaded = b.load_snapshot(&path).unwrap();
        assert!(loaded <= 3);
        assert!(b.len() <= 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn arena_locate_is_a_partition() {
        // Every index maps into a valid (segment, offset) and the
        // segment boundaries tile the handle space exactly.
        let mut expected = (0usize, 0usize);
        for idx in 0..(ARENA_BASE * 7 + 13) {
            let (s, off) = arena_locate(idx);
            assert_eq!((s, off), expected, "idx {idx}");
            expected = if off + 1 == ARENA_BASE << s {
                (s + 1, 0)
            } else {
                (s, off + 1)
            };
            assert!(off < ARENA_BASE << s);
        }
        // Spot-check deep indexes.
        let (s, off) = arena_locate(ARENA_MAX - 1);
        assert_eq!(s, ARENA_SEGMENTS - 1);
        assert_eq!(off, (ARENA_BASE << s) - 1);
    }

    #[test]
    fn arena_stats_track_interned_entries() {
        let cache = VectorCache::with_capacity(64);
        assert_eq!(cache.arena_stats(), (0, 0, 0));
        cache.get_or_insert(&[1i8, 2]);
        cache.get_or_insert(&[3i8]);
        let (entries, bytes, tombstoned) = cache.arena_stats();
        assert_eq!(entries, 2);
        assert!(bytes > 0);
        assert_eq!(tombstoned, 0, "live entries are not slack");
        // Flush tombstones but does not reclaim (append-only): the
        // whole footprint becomes reclaimable slack, exactly once even
        // if flushed again.
        cache.flush();
        cache.flush();
        let (entries, bytes, tombstoned) = cache.arena_stats();
        assert_eq!(entries, 2);
        assert_eq!(tombstoned, bytes, "all entries dead => all bytes slack");
    }
}
