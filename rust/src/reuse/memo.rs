//! Content-addressed weight-vector memo — cross-tile / cross-layer /
//! cross-sweep-point computation reuse for the simulator itself.
//!
//! The paper's thesis is that CNN weights repeat; the simulator should
//! exploit the same fact. Every UCR pipeline run
//! ([`UcrVector::from_weights`]), every per-vector size summary
//! ([`VectorSizeStats::collect`]) and every dataflow metadata derivation
//! ([`VectorMeta::new`]) is a pure function of the linearized weight
//! bytes (plus, for the metadata, the chosen encoding parameters and
//! tile geometry). So the transform of each **distinct** vector is done
//! exactly once per process and shared:
//!
//! * across tiles of one layer (sparse layers repeat vectors heavily —
//!   the all-zero vector alone can be a double-digit share at D=25%);
//! * across layers and models within a sweep;
//! * across sweep points and repeated requests (same seed ⇒ same base
//!   weights), including every connection of a long-running `codr serve`.
//!
//! Keys are the raw weight bytes — candidates are compared
//! byte-for-byte by the map's `Eq` on lookup, so a hash collision can
//! never alias two different vectors and cached results are exactly what
//! a fresh transform would produce. Hit/miss counters feed
//! `SweepStats::{memo_hits, memo_misses}`.

use super::UcrVector;
use crate::codr::dataflow::VectorMeta;
use crate::rle::VectorSizeStats;
use crate::util::hash::FxBuildHasher;
use std::collections::HashMap;
use std::hash::{BuildHasher, Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Lock striping: vectors hash uniformly, so 64 shards keep the memo
/// uncontended even with every pool worker hitting it.
const SHARDS: usize = 64;

/// Default soft cap on cached vectors (entries, not bytes). A 3×3 CoDR
/// vector entry is a few hundred bytes, so the default bounds the memo
/// around the low hundreds of MB in the worst case. Override with
/// `CODR_MEMO_CAP`.
const DEFAULT_CAPACITY: usize = 1 << 19;

/// `(delta_bits, count_bits, t_m, kernel)` — everything
/// [`VectorMeta::new`] depends on besides the vector itself.
type MetaKey = (u32, u32, usize, usize);

/// Everything derived from one distinct linearized weight vector.
pub struct CachedVector {
    /// The sorted/densified/unified form (UCR steps iv–v).
    pub ucr: UcrVector,
    /// Per-vector encoded-size summary for `LayerHistograms::merge_vector`.
    pub size: VectorSizeStats,
    /// Dataflow metadata per (encoding parameters, tile geometry) — a
    /// layer's parameter search picks the key, so the tiny linear map
    /// almost always holds one entry.
    metas: Mutex<Vec<(MetaKey, Arc<VectorMeta>)>>,
}

impl CachedVector {
    fn new(weights: &[i8]) -> CachedVector {
        let ucr = UcrVector::from_weights(weights);
        let size = VectorSizeStats::collect(&ucr);
        CachedVector {
            ucr,
            size,
            metas: Mutex::new(Vec::new()),
        }
    }

    /// Dataflow metadata under the given encoding parameters and tile
    /// geometry, derived once per distinct key.
    pub fn meta_for(
        &self,
        delta_bits: u32,
        count_bits: u32,
        t_m: usize,
        kernel: usize,
    ) -> Arc<VectorMeta> {
        let key: MetaKey = (delta_bits, count_bits, t_m, kernel);
        let mut metas = self.metas.lock().unwrap();
        if let Some((_, m)) = metas.iter().find(|(k, _)| *k == key) {
            return Arc::clone(m);
        }
        let m = Arc::new(VectorMeta::new(&self.ucr, delta_bits, count_bits, t_m, kernel));
        metas.push((key, Arc::clone(&m)));
        m
    }
}

/// One stripe of the cache: weight bytes → transform, FxHash-indexed.
type Shard = HashMap<Box<[i8]>, Arc<CachedVector>, FxBuildHasher>;

/// Sharded, capacity-bounded map from weight bytes to [`CachedVector`].
pub struct VectorCache {
    shards: Vec<Mutex<Shard>>,
    hits: AtomicU64,
    misses: AtomicU64,
    entries: AtomicUsize,
    capacity: usize,
}

impl VectorCache {
    /// A cache holding at most ~`capacity` entries. At capacity the cache
    /// stops inserting (lookups still hit existing entries) rather than
    /// evicting: the most frequent vectors — all-zero and near-zero ones —
    /// are seen early and stay resident, and the bound stays hard.
    pub fn with_capacity(capacity: usize) -> VectorCache {
        VectorCache {
            shards: (0..SHARDS)
                .map(|_| Mutex::new(HashMap::with_hasher(FxBuildHasher)))
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            entries: AtomicUsize::new(0),
            capacity: capacity.max(1),
        }
    }

    /// Look up (or transform and insert) one linearized weight vector.
    pub fn get_or_insert(&self, weights: &[i8]) -> Arc<CachedVector> {
        let mut hasher = FxBuildHasher.build_hasher();
        weights.hash(&mut hasher);
        // Shard on the HIGH bits: the shard's HashMap buckets on the low
        // bits of this same hash, so selecting shards by the low bits
        // would leave every table using 1/SHARDS of its buckets.
        let shard = &self.shards[(hasher.finish() >> 32) as usize % SHARDS];
        {
            let map = shard.lock().unwrap();
            if let Some(e) = map.get(weights) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(e);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Transform outside the lock; if a racing worker inserted the
        // same vector meanwhile, its (identical) entry wins.
        let entry = Arc::new(CachedVector::new(weights));
        if self.entries.load(Ordering::Relaxed) >= self.capacity {
            return entry; // full: serve the transform uncached
        }
        let mut map = shard.lock().unwrap();
        if let Some(e) = map.get(weights) {
            return Arc::clone(e);
        }
        map.insert(weights.to_vec().into_boxed_slice(), Arc::clone(&entry));
        drop(map);
        self.entries.fetch_add(1, Ordering::Relaxed);
        entry
    }

    /// Cumulative (hits, misses) since construction. Sweeps report the
    /// delta across their run; under concurrent sweeps the split between
    /// them is approximate (the totals are exact).
    pub fn counters(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Drop every cached vector (used by `codr bench` to measure the
    /// cold path). Counters are preserved.
    pub fn flush(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
        self.entries.store(0, Ordering::Relaxed);
    }

    /// Cached distinct vectors.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide memo every simulator path shares.
pub fn global() -> &'static VectorCache {
    static CACHE: OnceLock<VectorCache> = OnceLock::new();
    CACHE.get_or_init(|| {
        let cap = std::env::var("CODR_MEMO_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAPACITY);
        VectorCache::with_capacity(cap)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_returns_identical_transform() {
        let cache = VectorCache::with_capacity(1024);
        let v = [3i8, 0, 1, 3, 0, 1, 1, 4];
        let a = cache.get_or_insert(&v);
        let b = cache.get_or_insert(&v);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the entry");
        assert_eq!(a.ucr, UcrVector::from_weights(&v));
        assert_eq!(a.size, VectorSizeStats::collect(&a.ucr));
        assert_eq!(cache.counters(), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_vectors_never_alias() {
        let cache = VectorCache::with_capacity(1024);
        let a = cache.get_or_insert(&[1i8, 2, 3]);
        let b = cache.get_or_insert(&[1i8, 2, 4]);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.ucr.reconstruct(), vec![1, 2, 3]);
        assert_eq!(b.ucr.reconstruct(), vec![1, 2, 4]);
        // Same bytes at a different length are a different vector.
        let c = cache.get_or_insert(&[1i8, 2, 3, 0]);
        assert_eq!(c.ucr.len, 4);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn meta_for_computes_once_per_key() {
        let cache = VectorCache::with_capacity(16);
        let e = cache.get_or_insert(&[5i8, 0, 5, -1, 0, 0, 2, 2, 2]);
        let m1 = e.meta_for(2, 3, 1, 9);
        let m2 = e.meta_for(2, 3, 1, 9);
        assert!(Arc::ptr_eq(&m1, &m2));
        let m3 = e.meta_for(3, 3, 1, 9);
        assert!(!Arc::ptr_eq(&m1, &m3));
        assert_eq!(m1.nnz, 6);
    }

    #[test]
    fn capacity_bounds_entries_without_breaking_lookups() {
        let cache = VectorCache::with_capacity(2);
        cache.get_or_insert(&[1i8]);
        cache.get_or_insert(&[2i8]);
        // Full: the next distinct vector is transformed but not retained.
        let e = cache.get_or_insert(&[3i8]);
        assert_eq!(e.ucr.reconstruct(), vec![3]);
        assert!(cache.len() <= 2);
        // Resident entries still hit.
        let (h0, _) = cache.counters();
        cache.get_or_insert(&[1i8]);
        assert_eq!(cache.counters().0, h0 + 1);
        // Flush resets occupancy.
        cache.flush();
        assert!(cache.is_empty());
        cache.get_or_insert(&[3i8]);
        assert_eq!(cache.len(), 1);
    }
}
