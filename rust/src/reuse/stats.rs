//! Weight-distribution analysis — the data behind the paper's **Fig 2**:
//! "Average distribution of the 8-bit and 16-bit zero weights and weight
//! Δs (difference between sorted weights)."
//!
//! The distribution is computed over the *unit of reuse* — the linearized
//! per-input-channel weight vector of `T_M` kernels (Fig 3c) — and
//! averaged over all vectors of a model, which is what makes Δ=0
//! (repetition) a meaningful sub-100% number for 8-bit weights.

use crate::models::{LayerSpec, Model, Workload};
use crate::reuse::tile_layer;
use crate::util::rng::Rng;

/// Fig 2 histogram buckets. Fractions sum to 1 over all weights.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeltaDistribution {
    /// W = 0 (sparsity — exploited by densification).
    pub zero: f64,
    /// Δ = 0 among sorted non-zeros (repetition — exploited by unification).
    pub delta_zero: f64,
    /// 0 < Δ ≤ 3 (similarity — cheap differential computation, 2-bit Δ).
    pub delta_small: f64,
    /// 3 < Δ ≤ 15 (4-bit Δ).
    pub delta_mid: f64,
    /// Δ > 15 or first-of-vector absolute values.
    pub delta_large: f64,
}

impl DeltaDistribution {
    pub fn total(&self) -> f64 {
        self.zero + self.delta_zero + self.delta_small + self.delta_mid + self.delta_large
    }

    fn scale(&mut self, k: f64) {
        self.zero *= k;
        self.delta_zero *= k;
        self.delta_small *= k;
        self.delta_mid *= k;
        self.delta_large *= k;
    }

    fn add_counts(&mut self, o: &DeltaDistribution) {
        self.zero += o.zero;
        self.delta_zero += o.delta_zero;
        self.delta_small += o.delta_small;
        self.delta_mid += o.delta_mid;
        self.delta_large += o.delta_large;
    }
}

/// Distribution of one linearized weight vector, generic over precision
/// (`i32` accommodates both i8 and i16 weights). Thresholds scale with
/// precision so "small" means the same *relative* resolution in both
/// modes (the paper's 16-bit bars use the wider Δ space).
pub fn vector_distribution(v: &[i32], small_max: i32, mid_max: i32) -> DeltaDistribution {
    let mut d = DeltaDistribution::default();
    let mut nz: Vec<i32> = v.iter().copied().filter(|&x| x != 0).collect();
    d.zero = (v.len() - nz.len()) as f64;
    nz.sort_unstable();
    for w in nz.windows(2) {
        let delta = w[1] - w[0];
        if delta == 0 {
            d.delta_zero += 1.0;
        } else if delta <= small_max {
            d.delta_small += 1.0;
        } else if delta <= mid_max {
            d.delta_mid += 1.0;
        } else {
            d.delta_large += 1.0;
        }
    }
    // First non-zero of a vector has no predecessor — counted as "large"
    // (stored absolute by the encoder).
    if !nz.is_empty() {
        d.delta_large += 1.0;
    }
    d
}

/// Average Fig 2 distribution over every per-input-channel weight vector
/// of a model's conv layers, at 8-bit precision (`T_M` from the CoDR
/// tiling, Table I).
pub fn model_distribution_8bit(workload: &Workload, t_n: usize, t_m: usize) -> DeltaDistribution {
    let mut acc = DeltaDistribution::default();
    let mut total = 0usize;
    for (spec, w) in workload.conv_layers() {
        for tile in tile_layer(spec, w, t_n, t_m) {
            for v in &tile.vectors {
                let v32: Vec<i32> = v.weights.iter().map(|&x| x as i32).collect();
                acc.add_counts(&vector_distribution(&v32, 3, 15));
                total += v.len();
            }
        }
    }
    if total > 0 {
        acc.scale(1.0 / total as f64);
    }
    acc
}

/// Fig 2's 16-bit companion: quantizing the *unpruned* float weights at
/// 16-bit resolution. Sparsity and repetition nearly vanish (the paper
/// reports 0.5% and 9.0%) while small Δs dominate — the case where only
/// differential computation helps.
pub fn model_distribution_16bit(model: &Model, seed: u64, _t_n: usize, t_m: usize) -> DeltaDistribution {
    let root = Rng::new(seed).fork(model.name).fork("16bit");
    let mut acc = DeltaDistribution::default();
    let mut total = 0usize;
    for spec in model.layers.iter().filter(|l| l.kind == crate::models::LayerKind::Conv) {
        let mut rng = root.fork(&spec.name);
        // Cap the sampled vectors per layer — the distribution converges
        // long before the full VGG16 layer is drawn.
        let vec_len = t_m * spec.r_k * spec.r_k;
        let n_vectors = ((spec.num_weights() / vec_len).max(1)).min(4000);
        for _ in 0..n_vectors {
            let v = synth_vector_16bit(spec, vec_len, &mut rng);
            // Same relative thresholds as 8-bit, scaled by 256.
            acc.add_counts(&vector_distribution(&v, 3 * 256, 15 * 256));
            total += v.len();
        }
    }
    if total > 0 {
        acc.scale(1.0 / total as f64);
    }
    acc
}

fn synth_vector_16bit(spec: &LayerSpec, len: usize, rng: &mut Rng) -> Vec<i32> {
    (0..len)
        .map(|_| {
            // 16-bit quantization of unpruned floats: only 0.5% of weights
            // fall below half a quantization step.
            if rng.chance(0.005) {
                0
            } else {
                let v = (rng.normal() * spec.sigma_q * 256.0).round() as i32;
                if v == 0 {
                    1
                } else {
                    v.clamp(-32767, 32767)
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{alexnet, googlenet, vgg16, SweepGroup, Workload};

    #[test]
    fn distribution_sums_to_one() {
        let wl = Workload::generate(&alexnet(), None, None, 1);
        let d = model_distribution_8bit(&wl, 4, 4);
        assert!((d.total() - 1.0).abs() < 1e-9, "total {}", d.total());
    }

    #[test]
    fn vector_distribution_hand_example() {
        // v = [0, 5, 5, 7, 30]: 1 zero, Δs over sorted nz [5,5,7,30]:
        // 0 (rep), 2 (small), 23 (large) + 1 first-absolute.
        let d = vector_distribution(&[0, 5, 5, 7, 30], 3, 15);
        assert_eq!(d.zero, 1.0);
        assert_eq!(d.delta_zero, 1.0);
        assert_eq!(d.delta_small, 1.0);
        assert_eq!(d.delta_mid, 0.0);
        assert_eq!(d.delta_large, 2.0);
    }

    #[test]
    fn fig2_sparsity_ordering_vgg_highest() {
        // Paper Fig 2: VGG16 has the highest 8-bit sparsity (up to 94% in
        // its sparsest layers).
        let a = model_distribution_8bit(&Workload::generate(&alexnet(), None, None, 1), 4, 4);
        let v = model_distribution_8bit(&Workload::generate(&vgg16(), None, None, 1), 4, 4);
        let g = model_distribution_8bit(&Workload::generate(&googlenet(), None, None, 1), 4, 4);
        assert!(v.zero > g.zero, "vgg {} vs googlenet {}", v.zero, g.zero);
        assert!(v.zero > a.zero, "vgg {} vs alexnet {}", v.zero, a.zero);
        assert!(v.zero > 0.75, "vgg sparsity {}", v.zero);
    }

    #[test]
    fn fig2_googlenet_has_highest_repetition() {
        // Paper Fig 2: redundant computation (Δ=0) reaches 39% in
        // GoogleNet — the most concentrated weight distribution.
        let a = model_distribution_8bit(&Workload::generate(&alexnet(), None, None, 1), 4, 4);
        let v = model_distribution_8bit(&Workload::generate(&vgg16(), None, None, 1), 4, 4);
        let g = model_distribution_8bit(&Workload::generate(&googlenet(), None, None, 1), 4, 4);
        assert!(
            g.delta_zero > a.delta_zero && g.delta_zero > v.delta_zero,
            "googlenet {} vs alexnet {} / vgg {}",
            g.delta_zero,
            a.delta_zero,
            v.delta_zero
        );
        assert!(g.delta_zero > 0.15, "googlenet Δ=0 {}", g.delta_zero);
    }

    #[test]
    fn fig2_16bit_kills_sparsity_and_repetition() {
        // Paper: zero and Δ=0 drop to 0.5% and ~9% at 16-bit.
        let d16 = model_distribution_16bit(&googlenet(), 1, 4, 4);
        assert!(d16.zero < 0.02, "16-bit zeros {}", d16.zero);
        assert!(d16.delta_zero < 0.15, "16-bit Δ=0 {}", d16.delta_zero);
        // Small Δs still present: differential computation remains useful.
        assert!(
            d16.delta_small + d16.delta_mid > 0.3,
            "16-bit small+mid Δ {}",
            d16.delta_small + d16.delta_mid
        );
    }

    #[test]
    fn unique_knob_increases_repetition() {
        let orig = model_distribution_8bit(&Workload::generate(&alexnet(), None, None, 1), 4, 4);
        let (u, d) = SweepGroup::Unique(16).knobs();
        let lim = model_distribution_8bit(&Workload::generate(&alexnet(), u, d, 1), 4, 4);
        assert!(lim.delta_zero > orig.delta_zero);
        // For GoogleNet's concentrated weights, LSB-masking both repeats
        // *and* zeroes values; the total reuse-exploitable fraction
        // (W=0 ∪ Δ=0) must still grow.
        let g_orig =
            model_distribution_8bit(&Workload::generate(&googlenet(), None, None, 1), 4, 4);
        let g_lim = model_distribution_8bit(&Workload::generate(&googlenet(), u, d, 1), 4, 4);
        assert!(g_lim.zero + g_lim.delta_zero > g_orig.zero + g_orig.delta_zero);
    }

    #[test]
    fn density_knob_increases_sparsity() {
        let orig = model_distribution_8bit(&Workload::generate(&alexnet(), None, None, 1), 4, 4);
        let (u, d) = SweepGroup::Density(25).knobs();
        let deg = model_distribution_8bit(&Workload::generate(&alexnet(), u, d, 1), 4, 4);
        assert!(deg.zero > orig.zero);
    }
}
