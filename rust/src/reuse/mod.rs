//! Universal Computation Reuse — the paper's §II contribution.
//!
//! UCR exploits weight **sparsity** (W=0), **repetition** (Δ=0) and
//! **similarity** (small Δ) *simultaneously*. The offline pipeline
//! (paper §II-D, steps i–v) is:
//!
//! 1. break a conv layer into tiles of `T_N` input × `T_M` output channels;
//! 2. quantize to 8-bit fixed point (done by [`crate::quant`]);
//! 3. collect, per input channel inside the tile, one **linearized weight
//!    vector** containing the weights of the `T_M` kernels (Fig 3c);
//! 4. **sort**, **densify** (drop zeros) and **unify** (group equal
//!    weights) each vector;
//! 5. compute **Δ values** between the non-zero unique weights; the Δs,
//!    repetition counts, and output indexes go to the RLE encoders.
//!
//! The transformation is *lossless*: [`UcrVector::reconstruct`] returns
//! the original linearized vector, which the property tests verify.

pub mod memo;
pub mod stats;

use crate::models::LayerSpec;
use crate::tensor::Weights;

/// One linearized weight vector (Fig 3c): the weights of `t_m` kernels for
/// a single input channel, in index order `(m_local, k_r, k_c)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WeightVector {
    pub weights: Vec<i8>,
    /// Output channels covered (`T_M`, possibly clipped at the edge).
    pub t_m: usize,
    /// Kernel spatial size.
    pub r_k: usize,
    pub c_k: usize,
}

impl WeightVector {
    /// Linear index of `(m_local, kr, kc)` inside the vector.
    #[inline]
    pub fn index_of(&self, m_local: usize, kr: usize, kc: usize) -> usize {
        (m_local * self.r_k + kr) * self.c_k + kc
    }

    /// Inverse of [`Self::index_of`].
    #[inline]
    pub fn coords_of(&self, idx: usize) -> (usize, usize, usize) {
        let kc = idx % self.c_k;
        let rest = idx / self.c_k;
        (rest / self.r_k, rest % self.r_k, kc)
    }

    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }
}

/// A weight vector after sort + densify + unify + Δ (paper Fig 1e–i).
///
/// `uniques[i]` repeats `counts[i]` times at the vector positions given
/// by the `i`-th group of [`Self::index_groups`] (ascending). Zero
/// weights are represented implicitly — any position not listed is zero.
///
/// The index lists are stored structure-of-arrays: one flat backing
/// buffer, with the group boundaries implied by `counts` (group `i`
/// holds exactly `counts[i]` indexes). Compared to the seed's
/// `Vec<Vec<u16>>` this is three allocations per vector instead of
/// `2 + uniques` and keeps every traversal a linear scan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UcrVector {
    /// Distinct non-zero weights, sorted ascending.
    pub uniques: Vec<i8>,
    /// Repetition count per unique weight (doubles as the group length
    /// table of `indexes`).
    pub counts: Vec<u32>,
    /// Flat index buffer: the concatenation of every unique's ascending
    /// position list, in `uniques` order. `Σ counts[i] = indexes.len()` =
    /// number of non-zero weights.
    pub indexes: Vec<u16>,
    /// Original vector length.
    pub len: usize,
}

/// Iterator over the per-unique index groups of a [`UcrVector`] — yields
/// one `&[u16]` slice of the flat buffer per unique weight.
pub struct IndexGroups<'a> {
    counts: std::slice::Iter<'a, u32>,
    rest: &'a [u16],
}

impl<'a> Iterator for IndexGroups<'a> {
    type Item = &'a [u16];

    #[inline]
    fn next(&mut self) -> Option<&'a [u16]> {
        let &c = self.counts.next()?;
        let (head, tail) = self.rest.split_at(c as usize);
        self.rest = tail;
        Some(head)
    }
}

impl UcrVector {
    /// Run steps (iv)–(v) of the UCR pipeline on a linearized vector.
    ///
    /// Counting sort over the 256 possible values: a first pass takes the
    /// per-value histogram (stack array, no allocation), a second pass
    /// scatters positions into the exactly-sized flat index buffer via a
    /// per-value write cursor. This is the whole pipeline's hottest
    /// function (millions of calls per model) — see EXPERIMENTS.md §Perf;
    /// the cross-tile memo ([`memo`], keyed by the 128-bit content
    /// fingerprint each extraction loop computes per vector) ensures
    /// each distinct vector runs it only once.
    pub fn from_weights(v: &[i8]) -> Self {
        assert!(v.len() <= u16::MAX as usize + 1, "vector too long for u16 indexes");
        let mut hist = [0u32; 256];
        for &w in v {
            if w != 0 {
                hist[(w as i16 + 128) as usize] += 1;
            }
        }
        let mut uniques = Vec::new();
        let mut counts = Vec::new();
        // Flat-buffer write cursor per value slot (group start offsets).
        let mut cursor_of = [0u32; 256];
        let mut cursor = 0u32;
        for (slot, &c) in hist.iter().enumerate() {
            if c > 0 {
                uniques.push((slot as i16 - 128) as i8);
                counts.push(c);
                cursor_of[slot] = cursor;
                cursor += c;
            }
        }
        let mut indexes = vec![0u16; cursor as usize];
        for (pos, &w) in v.iter().enumerate() {
            if w != 0 {
                let slot = (w as i16 + 128) as usize;
                indexes[cursor_of[slot] as usize] = pos as u16;
                cursor_of[slot] += 1;
            }
        }
        UcrVector {
            uniques,
            counts,
            indexes,
            len: v.len(),
        }
    }

    /// The per-unique index groups (ascending within each group), in
    /// `uniques` order.
    #[inline]
    pub fn index_groups(&self) -> IndexGroups<'_> {
        IndexGroups {
            counts: self.counts.iter(),
            rest: &self.indexes,
        }
    }

    /// Δ values between successive sorted unique weights. `deltas()[0]` is
    /// meaningless for encoding (the first unique is stored absolute);
    /// subsequent entries are non-negative by construction.
    pub fn deltas(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.uniques.len());
        let mut prev: i16 = 0;
        for (i, &u) in self.uniques.iter().enumerate() {
            let d = u as i16 - prev;
            out.push(if i == 0 { 0 } else { d as u8 });
            prev = u as i16;
        }
        out
    }

    /// Number of non-zero weights.
    pub fn nnz(&self) -> usize {
        self.counts.iter().map(|&c| c as usize).sum()
    }

    /// Number of *multiplications* a scalar-matrix datapath performs for
    /// this vector: one per unique weight (instead of one per non-zero
    /// weight) — the unification saving. With differential computation the
    /// multiply operand is the Δ, whose magnitude [`Self::deltas`] gives.
    pub fn num_multiplies(&self) -> usize {
        self.uniques.len()
    }

    /// Invert the transformation (used by tests and the functional
    /// simulator): reproduce the original linearized weight vector.
    pub fn reconstruct(&self) -> Vec<i8> {
        let mut v = vec![0i8; self.len];
        for (u, group) in self.uniques.iter().zip(self.index_groups()) {
            for &i in group {
                v[i as usize] = *u;
            }
        }
        v
    }
}

/// One `T_N × T_M` channel tile of a layer (UCR step (i)).
#[derive(Clone, Debug)]
pub struct Tile {
    /// First input channel covered.
    pub n0: usize,
    /// First output channel covered.
    pub m0: usize,
    /// One weight vector per input channel in the tile.
    pub vectors: Vec<WeightVector>,
}

/// Break a layer's weights into channel tiles and linearize each tile's
/// per-input-channel weight vectors (UCR steps (i) and (iii)).
///
/// Edge tiles are clipped when `N % t_n != 0` or `M % t_m != 0`.
pub fn tile_layer(spec: &LayerSpec, weights: &Weights, t_n: usize, t_m: usize) -> Vec<Tile> {
    assert_eq!(weights.shape(), &[spec.m, spec.n, spec.r_k, spec.r_k]);
    let mut tiles = Vec::new();
    for m0 in (0..spec.m).step_by(t_m) {
        let tm = t_m.min(spec.m - m0);
        for n0 in (0..spec.n).step_by(t_n) {
            let tn = t_n.min(spec.n - n0);
            let mut vectors = Vec::with_capacity(tn);
            for n in n0..n0 + tn {
                let mut w = Vec::with_capacity(tm * spec.r_k * spec.r_k);
                for m in m0..m0 + tm {
                    for kr in 0..spec.r_k {
                        for kc in 0..spec.r_k {
                            w.push(weights.at4(m, n, kr, kc));
                        }
                    }
                }
                vectors.push(WeightVector {
                    weights: w,
                    t_m: tm,
                    r_k: spec.r_k,
                    c_k: spec.r_k,
                });
            }
            tiles.push(Tile { n0, m0, vectors });
        }
    }
    tiles
}

/// Full UCR transform of a layer: tile + linearize + sort/densify/unify/Δ.
/// Returns `(tile, per-input-channel UcrVector)` pairs in the tile order
/// the CoDR dataflow iterates them.
pub fn transform_layer(
    spec: &LayerSpec,
    weights: &Weights,
    t_n: usize,
    t_m: usize,
) -> Vec<(Tile, Vec<UcrVector>)> {
    tile_layer(spec, weights, t_n, t_m)
        .into_iter()
        .map(|tile| {
            let ucr = tile
                .vectors
                .iter()
                .map(|v| UcrVector::from_weights(&v.weights))
                .collect();
            (tile, ucr)
        })
        .collect()
}

/// UCR transform without materializing the linearized weight copies —
/// the stats-path simulators only need the [`UcrVector`]s (plus the
/// implicit geometry), and skipping the `Tile` allocation halves the
/// transform cost on VGG16-sized layers (§Perf). Tile order matches
/// [`transform_layer`]; the inner `Vec` holds the tile's `t_n` vectors.
pub fn transform_layer_ucr(
    spec: &LayerSpec,
    weights: &Weights,
    t_n: usize,
    t_m: usize,
) -> Vec<Vec<UcrVector>> {
    assert_eq!(weights.shape(), &[spec.m, spec.n, spec.r_k, spec.r_k]);
    let kernel = spec.r_k * spec.r_k;
    let data = weights.data();
    let mut out = Vec::new();
    let mut scratch: Vec<i8> = Vec::with_capacity(t_m * kernel);
    for m0 in (0..spec.m).step_by(t_m) {
        let tm = t_m.min(spec.m - m0);
        for n0 in (0..spec.n).step_by(t_n) {
            let tn = t_n.min(spec.n - n0);
            let mut vectors = Vec::with_capacity(tn);
            for n in n0..n0 + tn {
                scratch.clear();
                // Kernel elements are contiguous in the [M,N,Kr,Kc]
                // layout — copy whole kernels per output channel.
                for m in m0..m0 + tm {
                    let off = (m * spec.n + n) * kernel;
                    scratch.extend_from_slice(&data[off..off + kernel]);
                }
                vectors.push(UcrVector::from_weights(&scratch));
            }
            out.push(vectors);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{synthesize_weights, LayerKind};
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn small_spec() -> LayerSpec {
        LayerSpec {
            name: "t".into(),
            kind: LayerKind::Conv,
            n: 6,
            m: 10,
            r_i: 8,
            r_k: 3,
            stride: 1,
            pad: 1,
            groups: 1,
            sigma_q: 15.0,
            zero_frac: 0.4,
        }
    }

    /// The paper's Fig 1 running example: weight vector
    /// [w1..w8] = [3, 0, 1, 3, 0, 1, 1, 4] (one zero pattern matching
    /// Fig 1a's two ineffectual weights is equally valid; we use values
    /// that exercise sort+densify+unify+Δ the way Fig 1e–i illustrates).
    #[test]
    fn fig1_style_example() {
        let v = [3i8, 0, 1, 3, 0, 1, 1, 4];
        let u = UcrVector::from_weights(&v);
        assert_eq!(u.uniques, vec![1, 3, 4]);
        assert_eq!(u.counts, vec![3, 2, 1]);
        // Flat buffer = the groups [2,5,6] [0,3] [7] concatenated.
        assert_eq!(u.indexes, vec![2, 5, 6, 0, 3, 7]);
        let groups: Vec<&[u16]> = u.index_groups().collect();
        assert_eq!(groups, vec![&[2u16, 5, 6][..], &[0, 3][..], &[7][..]]);
        // Δs: first absolute, then 3-1=2, 4-3=1.
        assert_eq!(u.deltas()[1..], [2, 1]);
        assert_eq!(u.nnz(), 6);
        // 6 non-zero weights → only 3 multiplications after unification.
        assert_eq!(u.num_multiplies(), 3);
        assert_eq!(u.reconstruct(), v);
    }

    #[test]
    fn negative_weights_sort_first() {
        let v = [5i8, -3, 0, -3, 7];
        let u = UcrVector::from_weights(&v);
        assert_eq!(u.uniques, vec![-3, 5, 7]);
        // Δ stream after the absolute first element is non-negative.
        assert!(u.deltas()[1..].iter().all(|&d| d as i16 >= 0));
        assert_eq!(u.reconstruct(), v);
    }

    #[test]
    fn all_zero_vector() {
        let u = UcrVector::from_weights(&[0i8; 16]);
        assert!(u.uniques.is_empty());
        assert_eq!(u.nnz(), 0);
        assert_eq!(u.reconstruct(), vec![0i8; 16]);
    }

    #[test]
    fn index_linearization_roundtrip() {
        let wv = WeightVector {
            weights: vec![0; 4 * 3 * 3],
            t_m: 4,
            r_k: 3,
            c_k: 3,
        };
        for m in 0..4 {
            for kr in 0..3 {
                for kc in 0..3 {
                    let i = wv.index_of(m, kr, kc);
                    assert_eq!(wv.coords_of(i), (m, kr, kc));
                }
            }
        }
    }

    #[test]
    fn tiling_covers_all_weights_once() {
        let spec = small_spec();
        let mut rng = Rng::new(3);
        let w = synthesize_weights(&spec, &mut rng);
        let tiles = tile_layer(&spec, &w, 4, 4);
        // ceil(10/4)=3 output groups × ceil(6/4)=2 input groups.
        assert_eq!(tiles.len(), 6);
        let total: usize = tiles
            .iter()
            .flat_map(|t| t.vectors.iter().map(|v| v.len()))
            .sum();
        assert_eq!(total, spec.num_weights());
    }

    #[test]
    fn tiling_preserves_values() {
        let spec = small_spec();
        let mut rng = Rng::new(4);
        let w = synthesize_weights(&spec, &mut rng);
        for tile in tile_layer(&spec, &w, 4, 4) {
            for (dn, v) in tile.vectors.iter().enumerate() {
                for m_local in 0..v.t_m {
                    for kr in 0..3 {
                        for kc in 0..3 {
                            assert_eq!(
                                v.weights[v.index_of(m_local, kr, kc)],
                                w.at4(tile.m0 + m_local, tile.n0 + dn, kr, kc)
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_ucr_roundtrip_lossless() {
        check(
            100,
            |r, size| {
                let n = 1 + size * 4;
                (0..n)
                    .map(|_| {
                        if r.chance(0.4) {
                            0
                        } else {
                            (r.below(255) as i16 - 127) as i8
                        }
                    })
                    .collect::<Vec<i8>>()
            },
            |v| UcrVector::from_weights(v).reconstruct() == *v,
        );
    }

    #[test]
    fn prop_uniques_sorted_distinct_nonzero() {
        check(
            100,
            |r, size| {
                (0..1 + size * 3)
                    .map(|_| (r.below(17) as i16 - 8) as i8)
                    .collect::<Vec<i8>>()
            },
            |v| {
                let u = UcrVector::from_weights(v);
                u.uniques.windows(2).all(|w| w[0] < w[1])
                    && u.uniques.iter().all(|&x| x != 0)
                    && u.counts.iter().map(|&c| c as usize).sum::<usize>() == u.indexes.len()
                    && u.counts
                        .iter()
                        .zip(u.index_groups())
                        .all(|(&c, g)| c as usize == g.len())
                    && u.index_groups()
                        .all(|g| g.windows(2).all(|w| w[0] < w[1]))
            },
        );
    }

    /// The seed stored one `Vec<u16>` per unique; the flat layout must be
    /// observationally identical: same uniques, same counts, the same
    /// per-unique groups, and a byte-identical reconstruction.
    #[test]
    fn prop_flat_layout_matches_seed_nested_layout() {
        fn nested_reference(v: &[i8]) -> (Vec<i8>, Vec<u32>, Vec<Vec<u16>>) {
            let mut uniques: Vec<i8> = v.iter().copied().filter(|&w| w != 0).collect();
            uniques.sort_unstable();
            uniques.dedup();
            let mut counts = Vec::with_capacity(uniques.len());
            let mut groups = Vec::with_capacity(uniques.len());
            for &u in &uniques {
                let g: Vec<u16> = v
                    .iter()
                    .enumerate()
                    .filter(|&(_, &w)| w == u)
                    .map(|(i, _)| i as u16)
                    .collect();
                counts.push(g.len() as u32);
                groups.push(g);
            }
            (uniques, counts, groups)
        }
        check(
            100,
            |r, size| {
                (0..1 + size * 4)
                    .map(|_| {
                        if r.chance(0.5) {
                            0
                        } else {
                            (r.below(255) as i16 - 127) as i8
                        }
                    })
                    .collect::<Vec<i8>>()
            },
            |v| {
                let flat = UcrVector::from_weights(v);
                let (uniques, counts, groups) = nested_reference(v);
                flat.uniques == uniques
                    && flat.counts == counts
                    && flat
                        .index_groups()
                        .zip(&groups)
                        .all(|(a, b)| a == b.as_slice())
                    && flat.index_groups().count() == groups.len()
                    && flat.reconstruct() == *v
            },
        );
    }

    #[test]
    fn prop_multiplies_bounded_by_unique_count() {
        check(
            50,
            |r, size| {
                (0..8 + size * 2)
                    .map(|_| (r.below(9) as i16 - 4) as i8)
                    .collect::<Vec<i8>>()
            },
            |v| {
                let u = UcrVector::from_weights(v);
                // Unification bound: multiplies ≤ min(nnz, 255 possible values).
                u.num_multiplies() <= u.nnz() && u.num_multiplies() <= 255
            },
        );
    }

    #[test]
    fn transform_layer_roundtrips_whole_layer() {
        let spec = small_spec();
        let mut rng = Rng::new(9);
        let w = synthesize_weights(&spec, &mut rng);
        for (tile, ucrs) in transform_layer(&spec, &w, 4, 4) {
            for (v, u) in tile.vectors.iter().zip(&ucrs) {
                assert_eq!(u.reconstruct(), v.weights);
            }
        }
    }
}
