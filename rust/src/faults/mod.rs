//! Deterministic fault injection for the serve/store/memo stack.
//!
//! Production code is instrumented with **named injection points** at
//! its durability seams (`store.pack_write.torn`,
//! `memo.snapshot.bitflip`, `pool.worker.panic`, `serve.conn.stall`,
//! `serve.watch.drop`, `sched.point.slow`). Each point is a single
//! `faults::…_point(…)` call whose unarmed fast path is one `#[inline]`
//! load of a `OnceLock` that resolves to `None` — no spec parsing, no
//! locking, no RNG — so shipping the hooks costs nothing.
//!
//! Arming is process-wide via the `CODR_FAULTS` environment variable,
//! read once on first use:
//!
//! ```text
//! CODR_FAULTS="pool.worker.panic:1,store.pack_write.torn:3@0.5,seed=7"
//! ```
//!
//! The spec is a comma-separated list of clauses:
//!
//! * `name:count` — the point fires on its first `count` *eligible*
//!   evaluations, then disarms (count defaults to 1 if omitted);
//! * `name:count@prob` — each evaluation is eligible with probability
//!   `prob` drawn from the seeded RNG (default 1.0, i.e. always);
//! * `seed=N` — seeds the RNG shared by probability draws and byte
//!   manglers (default 42), so a failing chaos run reproduces exactly.
//!
//! The registry deliberately does not validate names against a list:
//! points live at seams spread across modules, and an unknown name in
//! the spec simply never fires. Tests construct [`Registry`] directly
//! (the global is env-armed once per process, which parallel in-process
//! tests must not fight over).

use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// One armed injection point: how many more times it fires, and with
/// what per-evaluation probability.
struct PointState {
    remaining: AtomicU64,
    prob: f64,
}

/// A parsed `CODR_FAULTS` spec. The process-global instance lives in a
/// `OnceLock<Option<Registry>>`; tests build their own.
pub struct Registry {
    points: HashMap<String, PointState>,
    rng: Mutex<Rng>,
}

impl Registry {
    /// Parse a fault spec. Errors name the offending clause so a typo in
    /// `CODR_FAULTS` fails loudly at serve startup instead of silently
    /// disarming the chaos run.
    pub fn parse(spec: &str) -> Result<Registry, String> {
        let mut points = HashMap::new();
        let mut seed = 42u64;
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(s) = clause.strip_prefix("seed=") {
                seed = s
                    .parse()
                    .map_err(|_| format!("bad fault seed `{clause}`"))?;
                continue;
            }
            let (name_count, prob) = match clause.split_once('@') {
                Some((nc, p)) => {
                    let p: f64 = p
                        .parse()
                        .map_err(|_| format!("bad probability in `{clause}`"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("probability out of [0,1] in `{clause}`"));
                    }
                    (nc, p)
                }
                None => (clause, 1.0),
            };
            let (name, count) = match name_count.split_once(':') {
                Some((n, c)) => (
                    n,
                    c.parse::<u64>()
                        .map_err(|_| format!("bad count in `{clause}`"))?,
                ),
                None => (name_count, 1),
            };
            if name.is_empty() {
                return Err(format!("empty point name in `{clause}`"));
            }
            points.insert(
                name.to_string(),
                PointState {
                    remaining: AtomicU64::new(count),
                    prob,
                },
            );
        }
        Ok(Registry {
            points,
            rng: Mutex::new(Rng::new(seed)),
        })
    }

    /// Should `name` fire now? Decrements the point's budget on a hit.
    pub fn fire(&self, name: &str) -> bool {
        let Some(p) = self.points.get(name) else {
            return false;
        };
        if p.remaining.load(Ordering::Relaxed) == 0 {
            return false;
        }
        if p.prob < 1.0 && !crate::util::sync::lock(&self.rng).chance(p.prob) {
            return false;
        }
        // Claim one shot; a concurrent evaluation that raced us past the
        // load above loses here and stays clean.
        p.remaining
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |r| r.checked_sub(1))
            .is_ok()
    }

    /// A seeded draw in `[0, bound)` for byte manglers, decorrelated per
    /// point name so two manglers armed together damage independently.
    fn draw(&self, name: &str, bound: u64) -> u64 {
        crate::util::sync::lock(&self.rng).fork(name).below(bound)
    }
}

static REGISTRY: OnceLock<Option<Registry>> = OnceLock::new();

fn registry() -> Option<&'static Registry> {
    REGISTRY
        .get_or_init(|| {
            let spec = crate::analysis::env_registry::var("CODR_FAULTS")?;
            if spec.trim().is_empty() {
                return None;
            }
            match Registry::parse(&spec) {
                Ok(r) => {
                    eprintln!("faults: armed from CODR_FAULTS ({} points)", r.points.len());
                    Some(r)
                }
                Err(e) => {
                    // A malformed spec must not silently run a "chaos"
                    // test with no chaos in it.
                    // analyze: allow(panic_policy): misconfiguration must fail loudly at arm time, not inject nothing
                    panic!("invalid CODR_FAULTS spec: {e}");
                }
            }
        })
        .as_ref()
}

/// Is any fault armed in this process?
#[inline]
pub fn armed() -> bool {
    registry().is_some()
}

/// Evaluate the injection point `name`: true iff it fires now. The
/// unarmed fast path is a single static load.
#[inline]
pub fn point(name: &str) -> bool {
    match registry() {
        None => false,
        Some(r) => r.fire(name),
    }
}

/// Panic (an injected worker crash) if `name` fires.
#[inline]
pub fn panic_point(name: &str) {
    if point(name) {
        // analyze: allow(panic_policy): this panic IS the injected fault
        panic!("fault injected: {name}");
    }
}

/// Sleep for `dur` if `name` fires — models a stalled peer or a slow
/// worker (also used to widen kill-windows deterministically in tests).
#[inline]
pub fn sleep_point(name: &str, dur: Duration) {
    if point(name) {
        std::thread::sleep(dur);
    }
}

/// Torn-write mangler: if `name` fires, truncate `buf` to a seeded
/// prefix (at least one byte shorter) — what a crash between `write`
/// and `fsync` leaves behind. Returns whether it fired.
#[inline]
pub fn torn_point(name: &str, buf: &mut Vec<u8>) -> bool {
    match registry() {
        None => false,
        Some(r) => {
            if buf.is_empty() || !r.fire(name) {
                return false;
            }
            let keep = r.draw(name, buf.len() as u64) as usize;
            buf.truncate(keep);
            eprintln!("faults: {name} fired (truncated to {keep} bytes)");
            true
        }
    }
}

/// Bit-rot mangler: if `name` fires, flip one seeded bit of `buf`.
/// Returns whether it fired.
#[inline]
pub fn bitflip_point(name: &str, buf: &mut [u8]) -> bool {
    match registry() {
        None => false,
        Some(r) => {
            if buf.is_empty() || !r.fire(name) {
                return false;
            }
            let bit = r.draw(name, buf.len() as u64 * 8);
            buf[(bit / 8) as usize] ^= 1 << (bit % 8);
            eprintln!("faults: {name} fired (flipped bit {bit})");
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_points_never_fire() {
        // The test harness never sets CODR_FAULTS; the global registry
        // must resolve to None and every point stay cold.
        assert!(!armed());
        assert!(!point("pool.worker.panic"));
        panic_point("pool.worker.panic"); // must not panic
        let mut buf = b"intact".to_vec();
        assert!(!torn_point("store.pack_write.torn", &mut buf));
        assert!(!bitflip_point("memo.snapshot.bitflip", &mut buf));
        assert_eq!(buf, b"intact");
    }

    #[test]
    fn counts_bound_firings() {
        let r = Registry::parse("a.b:2,c.d").unwrap();
        assert!(r.fire("a.b"));
        assert!(r.fire("a.b"));
        assert!(!r.fire("a.b"), "count budget must exhaust");
        assert!(r.fire("c.d"), "count defaults to 1");
        assert!(!r.fire("c.d"));
        assert!(!r.fire("never.named"));
    }

    #[test]
    fn probability_gates_are_seeded_and_reproducible() {
        let fired = |seed: u64| {
            let r = Registry::parse(&format!("p.q:1000000@0.25,seed={seed}")).unwrap();
            (0..10_000).filter(|_| r.fire("p.q")).count()
        };
        let a = fired(7);
        assert_eq!(a, fired(7), "same seed, same schedule");
        assert_ne!(a, fired(8), "different seed, different schedule");
        // Roughly a quarter of evaluations fire.
        assert!((1500..3500).contains(&a), "{a} of 10000 at p=0.25");
    }

    #[test]
    fn spec_errors_name_the_clause() {
        for bad in ["x:y", "x:1@2.0", "x:1@p", ":3", "seed=soon"] {
            let err = Registry::parse(bad).unwrap_err();
            assert!(!err.is_empty(), "{bad}");
        }
        // Empty/whitespace clauses are tolerated (trailing commas).
        assert!(Registry::parse("a:1,,").is_ok());
        assert!(Registry::parse("").unwrap().points.is_empty());
    }

    #[test]
    fn concurrent_fires_never_exceed_the_budget() {
        let r = Registry::parse("hot.point:100").unwrap();
        let hits: usize = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| s.spawn(|| (0..1000).filter(|_| r.fire("hot.point")).count()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        assert_eq!(hits, 100, "exactly the budget, no double-spend");
    }
}
