//! `codr` — leader entrypoint for the CoDR reproduction.
//!
//! See `codr help` for commands; DESIGN.md maps each figure/table of the
//! paper to its `codr figure <id>` invocation.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(codr::cli::run(&argv));
}
