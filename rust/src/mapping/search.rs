//! Bounded mapping-space search with Pareto-front reduction.
//!
//! [`search_layer`] enumerates a tile-size grid over `(PU, K, C, Y'/X')`,
//! validates each candidate mapping ([`super::Mapping::validate`]), prices
//! the legal ones through the exact dataflow walk on the work-stealing
//! pool, and reduces the results to the Pareto front over
//! `(SRAM accesses ↓, energy ↓, PE utilization ↑)`.
//!
//! Candidates are content-addressed through the [`ResultStore`]: every
//! candidate of one `(model, layer, group, seed)` search lands in a
//! single pack keyed by its derived tile configuration, so a repeated
//! search warms from one pack read. Output ordering is a *stable total
//! order* — ties on all three axes break on the mapping label — so the
//! report (and the CI smoke) is byte-identical across runs and machines.

use super::{price_mapping, CandidateResult, Mapping};
use crate::codr::Codr;
use crate::coordinator::pool;
use crate::models::{LayerSpec, SweepGroup};
use crate::serve::store::{CacheKey, LoadOutcome, ResultStore};
use crate::sim::{LayerResult, ModelResult};
use crate::tensor::Weights;
use crate::util::json::Json;

/// Knobs of one layer search.
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    /// Evaluate at most this many legal candidates (the baseline mapping
    /// is always kept); the rest are dropped and logged.
    pub max_candidates: usize,
    /// Coarse grid for smoke tests (`codr map --quick`).
    pub quick: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            max_candidates: 512,
            quick: false,
        }
    }
}

/// Everything one layer search produced.
#[derive(Clone, Debug)]
pub struct SearchReport {
    pub layer: String,
    /// Pareto-optimal candidates, in the stable report order.
    pub front: Vec<CandidateResult>,
    /// Grid points enumerated (legal + illegal + dropped).
    pub enumerated: usize,
    /// Candidates actually priced (cache hits included).
    pub evaluated: usize,
    /// Legal candidates dropped by `max_candidates`.
    pub dropped: usize,
    /// Grid points rejected by mapping validation.
    pub illegal: usize,
    /// Evaluated candidates served from the store.
    pub cache_hits: usize,
    /// The baseline (fixed-dataflow-equivalent) mapping survived to the
    /// front. When false, every front entry dominates or ties it.
    pub baseline_in_front: bool,
}

impl SearchReport {
    /// Stable JSON rendering (field order fixed, candidates in report
    /// order) — `codr map --json` and the serve `map` end event.
    pub fn to_json(&self) -> Json {
        let cand = |c: &CandidateResult| {
            Json::Obj(vec![
                ("mapping".into(), Json::str(c.mapping.to_string())),
                ("tile".into(), Json::str(c.mapping.tile_label())),
                ("sram_accesses".into(), Json::u64(c.sram_accesses)),
                ("energy_uj".into(), Json::f64(c.energy_uj)),
                ("utilization".into(), Json::f64(c.utilization)),
                ("cycles".into(), Json::u64(c.cycles)),
                (
                    "reuse".into(),
                    Json::Obj(vec![
                        (
                            "input_spatial_multicast".into(),
                            Json::f64(c.reuse.input_spatial_multicast),
                        ),
                        (
                            "input_temporal_reuse".into(),
                            Json::f64(c.reuse.input_temporal_reuse),
                        ),
                        (
                            "weight_temporal_reuse".into(),
                            Json::f64(c.reuse.weight_temporal_reuse),
                        ),
                        (
                            "output_temporal_reduction".into(),
                            Json::f64(c.reuse.output_temporal_reduction),
                        ),
                        (
                            "output_spatial_reduction".into(),
                            Json::f64(c.reuse.output_spatial_reduction),
                        ),
                    ]),
                ),
                ("cache_hit".into(), Json::Bool(c.cache_hit)),
            ])
        };
        Json::Obj(vec![
            ("layer".into(), Json::str(&self.layer)),
            ("enumerated".into(), Json::usize(self.enumerated)),
            ("evaluated".into(), Json::usize(self.evaluated)),
            ("dropped".into(), Json::usize(self.dropped)),
            ("illegal".into(), Json::usize(self.illegal)),
            ("cache_hits".into(), Json::usize(self.cache_hits)),
            ("baseline_in_front".into(), Json::Bool(self.baseline_in_front)),
            ("front".into(), Json::Arr(self.front.iter().map(cand).collect())),
        ])
    }
}

/// The tile-size axes of the searched grid.
fn grid(quick: bool) -> (&'static [usize], &'static [usize], &'static [usize], &'static [usize]) {
    if quick {
        (&[4, 8], &[2, 4], &[2, 4], &[4, 8])
    } else {
        (
            &[1, 2, 4, 8, 16, 32],
            &[1, 2, 4, 8],
            &[1, 2, 4, 8],
            &[2, 4, 8, 16],
        )
    }
}

/// Enumerate the candidate mappings for one layer: the baseline first,
/// then the legal grid points in grid order, truncated at
/// `cfg.max_candidates`. Returns `(kept, enumerated, illegal, dropped)`.
pub fn enumerate_mappings(
    spec: &LayerSpec,
    base: &Codr,
    cfg: &SearchConfig,
) -> (Vec<Mapping>, usize, usize, usize) {
    let baseline = Mapping::baseline(&base.cfg, spec);
    let (pus, ms, ns, sps) = grid(cfg.quick);
    let mut kept = Vec::new();
    let mut enumerated = 0usize;
    let mut illegal = 0usize;
    let mut dropped = 0usize;
    // The baseline rides outside the grid when legal (it is for every
    // dense layer; grouped layers may need narrower tiles).
    let baseline_kept = baseline.validate(spec, &base.cfg, &base.mem).is_ok();
    if baseline_kept {
        enumerated += 1;
        kept.push(baseline.clone());
    }
    for &t_pu in pus {
        for &t_m in ms {
            for &t_n in ns {
                for &t_sp in sps {
                    let m = Mapping::from_tiles(spec, t_pu, t_m, t_n, t_sp, t_sp);
                    if baseline_kept && m == baseline {
                        continue; // already kept, outside the cap count
                    }
                    enumerated += 1;
                    if m.validate(spec, &base.cfg, &base.mem).is_err() {
                        illegal += 1;
                    } else if kept.len() < cfg.max_candidates.max(1) {
                        kept.push(m);
                    } else {
                        dropped += 1;
                    }
                }
            }
        }
    }
    (kept, enumerated, illegal, dropped)
}

/// The stable total order of the report: SRAM ascending, then energy
/// ascending, then utilization *descending*, then the mapping label —
/// so equal-cost candidates order identically on every run and machine.
fn report_order(a: &CandidateResult, b: &CandidateResult) -> std::cmp::Ordering {
    a.sram_accesses
        .cmp(&b.sram_accesses)
        .then_with(|| a.energy_uj.total_cmp(&b.energy_uj))
        .then_with(|| b.utilization.total_cmp(&a.utilization))
        .then_with(|| a.mapping.tile_label().cmp(&b.mapping.tile_label()))
}

/// Reduce sorted candidates to the Pareto front, preserving order.
fn pareto_front(sorted: &[CandidateResult]) -> Vec<CandidateResult> {
    sorted
        .iter()
        .filter(|c| !sorted.iter().any(|o| o.dominates(c)))
        .cloned()
        .collect()
}

/// Search one layer's mapping space.
///
/// `store` enables content-addressed caching: each candidate is keyed by
/// `(map:{model}/{layer}, group, "CoDR", derived tile config, mem, seed)`
/// so all candidates of one search share a pack. `progress` fires once
/// per evaluated candidate (from pool threads, unordered).
#[allow(clippy::too_many_arguments)]
pub fn search_layer(
    base: &Codr,
    model: &str,
    group: &SweepGroup,
    seed: u64,
    spec: &LayerSpec,
    weights: &Weights,
    cfg: &SearchConfig,
    store: Option<&ResultStore>,
    progress: Option<&(dyn Fn(&CandidateResult) + Sync)>,
) -> SearchReport {
    let (mappings, enumerated, illegal, dropped) = enumerate_mappings(spec, base, cfg);
    if dropped > 0 {
        eprintln!(
            "map[{}/{}]: dropped {dropped} legal candidates past --max-candidates {}",
            model, spec.name, cfg.max_candidates
        );
    }
    let map_model = format!("map:{model}/{}", spec.name);
    let keys: Vec<CacheKey> = mappings
        .iter()
        .map(|m| {
            CacheKey::for_point(
                &map_model,
                group,
                "CoDR",
                &m.derived_config(&base.cfg),
                &base.mem,
                seed,
            )
        })
        .collect();
    // Warm every candidate of the pack in one read.
    let cached: Vec<Option<LayerResult>> = match store {
        Some(s) => s
            .load_group(&keys)
            .into_iter()
            .map(|o| match o {
                LoadOutcome::Hit(r) => r.layers.first().cloned(),
                _ => None,
            })
            .collect(),
        None => vec![None; mappings.len()],
    };
    let cache_hits = cached.iter().filter(|c| c.is_some()).count();

    let jobs: Vec<(usize, &Mapping)> = mappings.iter().enumerate().collect();
    let mut results: Vec<CandidateResult> = pool::parallel_map(&jobs, |(i, m)| {
        let (layer, hit) = match &cached[*i] {
            Some(r) => (r.clone(), true),
            None => (price_mapping(base, spec, weights, m), false),
        };
        let c = CandidateResult::from_layer((*m).clone(), &base.cfg, spec, &layer, hit);
        if !hit {
            if let Some(s) = store {
                let saved = ModelResult {
                    arch: "CoDR".into(),
                    model: map_model.clone(),
                    group: group.label(),
                    layers: vec![layer],
                };
                if let Err(e) = s.save(&keys[*i], &saved) {
                    eprintln!("map[{}/{}]: store save failed: {e:#}", model, spec.name);
                }
            }
        }
        if let Some(p) = progress {
            p(&c);
        }
        c
    });

    results.sort_by(report_order);
    let front = pareto_front(&results);
    let baseline = Mapping::baseline(&base.cfg, spec);
    SearchReport {
        layer: spec.name.clone(),
        evaluated: results.len(),
        baseline_in_front: front.iter().any(|c| c.mapping == baseline),
        front,
        enumerated,
        dropped,
        illegal,
        cache_hits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TileConfig;
    use crate::models::{synthesize_weights, LayerKind};
    use crate::util::rng::Rng;

    fn spec() -> LayerSpec {
        LayerSpec {
            name: "s1".into(),
            kind: LayerKind::Conv,
            n: 8,
            m: 16,
            r_i: 12,
            r_k: 3,
            stride: 1,
            pad: 1,
            groups: 1,
            sigma_q: 8.0,
            zero_frac: 0.5,
        }
    }

    fn run(cfg: &SearchConfig, store: Option<&ResultStore>) -> SearchReport {
        let s = spec();
        let mut rng = Rng::new(7);
        let w = synthesize_weights(&s, &mut rng);
        search_layer(
            &Codr::default(),
            "tiny",
            &SweepGroup::Original,
            7,
            &s,
            &w,
            cfg,
            store,
            None,
        )
    }

    #[test]
    fn baseline_rides_outside_the_cap() {
        let (kept, _, _, dropped) = enumerate_mappings(
            &spec(),
            &Codr::default(),
            &SearchConfig {
                max_candidates: 3,
                quick: true,
            },
        );
        assert_eq!(kept[0], Mapping::baseline(&TileConfig::codr(), &spec()));
        assert_eq!(kept.len(), 1 + 3);
        assert!(dropped > 0);
    }

    #[test]
    fn front_is_nonempty_dominance_free_and_holds_baseline() {
        let r = run(&SearchConfig::default(), None);
        assert!(!r.front.is_empty());
        assert_eq!(r.evaluated + r.illegal + r.dropped, r.enumerated);
        for a in &r.front {
            assert!(!r.front.iter().any(|b| b.dominates(a)));
        }
        if !r.baseline_in_front {
            // Price the baseline independently: some front member must
            // strictly dominate it (else it would have survived).
            let s = spec();
            let mut rng = Rng::new(7);
            let w = synthesize_weights(&s, &mut rng);
            let base = Codr::default();
            let bl = Mapping::baseline(&base.cfg, &s);
            let lr = crate::mapping::price_mapping(&base, &s, &w, &bl);
            let blc = CandidateResult::from_layer(bl, &base.cfg, &s, &lr, false);
            assert!(
                r.front.iter().any(|c| c.dominates(&blc)),
                "baseline neither in the front nor dominated by it"
            );
        }
    }

    #[test]
    fn report_is_deterministic_across_runs() {
        let cfg = SearchConfig {
            quick: true,
            ..Default::default()
        };
        let a = run(&cfg, None).to_json().to_string();
        let b = run(&cfg, None).to_json().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn store_warms_the_second_run() {
        let dir = std::env::temp_dir().join(format!("codr-map-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ResultStore::open(&dir).unwrap();
        let cfg = SearchConfig {
            quick: true,
            ..Default::default()
        };
        let cold = run(&cfg, Some(&store));
        assert_eq!(cold.cache_hits, 0);
        let warm = run(&cfg, Some(&store));
        assert_eq!(warm.cache_hits, warm.evaluated, "all candidates warm");
        assert_eq!(
            cold.to_json().to_string(),
            warm.to_json().to_string(),
            "cache round-trip must not change the report"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quick_grid_is_a_subset_of_the_full_grid() {
        let full = run(&SearchConfig::default(), None);
        let quick = run(
            &SearchConfig {
                quick: true,
                ..Default::default()
            },
            None,
        );
        assert!(quick.evaluated < full.evaluated);
        // Every quick grid point exists in the full grid.
        let (fk, ..) = enumerate_mappings(&spec(), &Codr::default(), &SearchConfig::default());
        let (qk, ..) = enumerate_mappings(
            &spec(),
            &Codr::default(),
            &SearchConfig {
                quick: true,
                ..Default::default()
            },
        );
        for m in &qk {
            assert!(fk.contains(m), "{m}");
        }
    }
}
