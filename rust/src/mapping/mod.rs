//! Data-centric mapping directives and the analytical reuse engine.
//!
//! A *mapping* describes how one conv layer's iteration space is tiled
//! onto the CoDR substrate, MAESTRO-style: one `TemporalMap(size, offset)`
//! or `SpatialMap(size, offset)` directive per dimension of
//! `{K, C, R, S, X', Y'}` (output channels, input channels, kernel rows/
//! cols, output cols/rows), plus the spatial fan-out (`PU=n`, the number
//! of processing units the one spatial directive unrolls over).
//!
//! The engine is *exact by construction*: a legal mapping lowers to a
//! derived [`TileConfig`] ([`Mapping::derived_config`]) and is priced by
//! the existing Fig 5a dataflow walk (`codr::dataflow`) under that
//! configuration, so every candidate's SRAM-access and energy numbers
//! come from the same `arch::mem` / `energy` model as the paper figures.
//! In particular the directive set equivalent to the shipped
//! input/output-stationary dataflow ([`Mapping::baseline`]) reproduces
//! the current numbers **bit for bit** — pinned by the
//! `baseline_mapping_prices_bit_for_bit` test here and the
//! `baseline_directives_reproduce_fixed_dataflow_bit_for_bit`
//! integration pin.
//!
//! [`reuse_factors`] reports the analytical reuse profile of a candidate
//! (the four MAESTRO reuse classes as they appear in CoDR: input spatial
//! multicast across PUs, input temporal reuse across m-groups, weight
//! temporal reuse across spatial tiles, output temporal reduction across
//! C·R·S; CoDR has no cross-PE spatial reduction — it is output
//! stationary).
//!
//! [`search`] enumerates the legal mapping space per layer and reduces it
//! to a Pareto front over (SRAM accesses, energy, PE utilization).

pub mod search;

use crate::arch::{MemConfig, TileConfig};
use crate::codr::Codr;
use crate::models::LayerSpec;
use crate::sim::{simulate_layer_grouped, LayerResult};
use crate::tensor::Weights;
use std::fmt;

/// A conv-layer dimension a directive maps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dim {
    /// Output channels (M in the paper's notation).
    K,
    /// Input channels (N).
    C,
    /// Kernel rows.
    R,
    /// Kernel cols.
    S,
    /// Output rows (Y').
    Yo,
    /// Output cols (X').
    Xo,
}

impl Dim {
    pub fn label(&self) -> &'static str {
        match self {
            Dim::K => "K",
            Dim::C => "C",
            Dim::R => "R",
            Dim::S => "S",
            Dim::Yo => "Y'",
            Dim::Xo => "X'",
        }
    }
}

/// Temporal (iterate over time on one PE) vs spatial (unroll across PEs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapKind {
    Temporal,
    Spatial,
}

/// One per-dimension mapping directive. `size` is the tile edge along the
/// dimension (a *cap*: edge tiles clip at the layer boundary, exactly as
/// the fixed dataflow clips `T_N`/`T_M`); `offset` is the step between
/// consecutive tiles — equal to `size` everywhere in CoDR's space (the
/// kernel window overlap lives in the derived input tile, not in the
/// directive stride).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Directive {
    pub kind: MapKind,
    pub dim: Dim,
    pub size: usize,
    pub offset: usize,
}

impl fmt::Display for Directive {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = match self.kind {
            MapKind::Temporal => "TemporalMap",
            MapKind::Spatial => "SpatialMap",
        };
        write!(f, "{kind}({},{}) {}", self.size, self.offset, self.dim.label())
    }
}

/// A complete mapping: the spatial fan-out plus one directive per
/// dimension, listed outer → inner in the Fig 5a loop order
/// (④ spatial tile, ③ m-group, ② n-tile, ① kernel walk).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mapping {
    /// PUs the spatial directive unrolls over (③'s concurrent width).
    pub t_pu: usize,
    pub directives: Vec<Directive>,
}

impl fmt::Display for Mapping {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PU={}", self.t_pu)?;
        for d in &self.directives {
            write!(f, " | {d}")?;
        }
        Ok(())
    }
}

impl Mapping {
    /// Build the canonical directive set for a tile-size choice. The
    /// kernel dims are always fully unrolled temporally (CoDR streams
    /// whole compressed kernels per vector), so the searched axes are
    /// `(t_pu, t_m, t_n, t_ro, t_co)`.
    pub fn from_tiles(
        spec: &LayerSpec,
        t_pu: usize,
        t_m: usize,
        t_n: usize,
        t_ro: usize,
        t_co: usize,
    ) -> Mapping {
        let t = |dim, size| Directive {
            kind: MapKind::Temporal,
            dim,
            size,
            offset: size,
        };
        Mapping {
            t_pu,
            directives: vec![
                t(Dim::Yo, t_ro),
                t(Dim::Xo, t_co),
                Directive {
                    kind: MapKind::Spatial,
                    dim: Dim::K,
                    size: t_m,
                    offset: t_m,
                },
                t(Dim::C, t_n),
                t(Dim::R, spec.r_k),
                t(Dim::S, spec.r_k),
            ],
        }
    }

    /// The directive set equivalent to the shipped input/output-stationary
    /// dataflow at `cfg` — the mapping whose derived configuration IS
    /// `cfg`, and whose price equals `Codr::simulate_layer` bit for bit.
    pub fn baseline(cfg: &TileConfig, spec: &LayerSpec) -> Mapping {
        Mapping::from_tiles(spec, cfg.t_pu, cfg.t_m, cfg.t_n, cfg.t_ro, cfg.t_co)
    }

    /// The directive size on `dim`, if present.
    pub fn size_of(&self, dim: Dim) -> Option<usize> {
        self.directives.iter().find(|d| d.dim == dim).map(|d| d.size)
    }

    /// Compact tile label for tables: `PU8 K4 C4 Y'8 X'8`.
    pub fn tile_label(&self) -> String {
        format!(
            "PU{} K{} C{} Y'{} X'{}",
            self.t_pu,
            self.size_of(Dim::K).unwrap_or(0),
            self.size_of(Dim::C).unwrap_or(0),
            self.size_of(Dim::Yo).unwrap_or(0),
            self.size_of(Dim::Xo).unwrap_or(0),
        )
    }

    /// Lower to the tile configuration the dataflow walk runs under. The
    /// Input RF window (`t_ri`/`t_ci`) and the total multiplier budget are
    /// hardware, inherited from `base`; the multipliers redistribute over
    /// the chosen PU count.
    pub fn derived_config(&self, base: &TileConfig) -> TileConfig {
        TileConfig {
            name: base.name,
            t_pu: self.t_pu,
            t_m: self.size_of(Dim::K).unwrap_or(base.t_m),
            t_n: self.size_of(Dim::C).unwrap_or(base.t_n),
            t_ro: self.size_of(Dim::Yo).unwrap_or(base.t_ro),
            t_co: self.size_of(Dim::Xo).unwrap_or(base.t_co),
            t_ri: base.t_ri,
            t_ci: base.t_ci,
            mults_per_pu: (base.total_mults() / self.t_pu.max(1)).max(1),
        }
    }

    /// Legality of this mapping for `spec` under the `base` arch and
    /// `mem` budgets. Returns the first violated constraint.
    ///
    /// Checks, in order: directive structure (one directive per dimension,
    /// exactly one `SpatialMap` and it must sit on K — the Selector routes
    /// along output channels), positive non-overlapping tiles, full kernel
    /// unroll, the PE budget (PU fan-out within the multiplier budget),
    /// the RF budgets (input tile window and per-PU output tile must fit
    /// `mem.rf_bytes`), and group boundaries (for grouped convs no tile
    /// may span channels of two groups).
    pub fn validate(
        &self,
        spec: &LayerSpec,
        base: &TileConfig,
        mem: &MemConfig,
    ) -> Result<(), String> {
        for dim in [Dim::K, Dim::C, Dim::R, Dim::S, Dim::Yo, Dim::Xo] {
            let n = self.directives.iter().filter(|d| d.dim == dim).count();
            if n != 1 {
                return Err(format!("dimension {} mapped {n} times (need 1)", dim.label()));
            }
        }
        let spatial: Vec<&Directive> = self
            .directives
            .iter()
            .filter(|d| d.kind == MapKind::Spatial)
            .collect();
        match spatial.as_slice() {
            [d] if d.dim == Dim::K => {}
            [d] => {
                return Err(format!(
                    "SpatialMap must sit on K (the Selector routes along output \
                     channels), found it on {}",
                    d.dim.label()
                ))
            }
            _ => return Err(format!("need exactly 1 SpatialMap, found {}", spatial.len())),
        }
        for d in &self.directives {
            if d.size == 0 {
                return Err(format!("{} has size 0", d.dim.label()));
            }
            if d.offset != d.size {
                return Err(format!(
                    "{} offset {} != size {} (overlapping tiles unsupported)",
                    d.dim.label(),
                    d.offset,
                    d.size
                ));
            }
        }
        for dim in [Dim::R, Dim::S] {
            if self.size_of(dim) != Some(spec.r_k) {
                return Err(format!(
                    "{} must be fully unrolled (TemporalMap({},{}) {})",
                    dim.label(),
                    spec.r_k,
                    spec.r_k,
                    dim.label()
                ));
            }
        }
        if self.t_pu == 0 {
            return Err("PU fan-out is 0".into());
        }
        if self.t_pu > base.total_mults() {
            return Err(format!(
                "{} PUs exceed the {}-multiplier budget",
                self.t_pu,
                base.total_mults()
            ));
        }
        let t_m = self.size_of(Dim::K).unwrap();
        let t_n = self.size_of(Dim::C).unwrap();
        let t_ro = self.size_of(Dim::Yo).unwrap();
        let t_co = self.size_of(Dim::Xo).unwrap();
        let in_rf = t_n * base.t_ri * base.t_ci;
        if in_rf as f64 > mem.rf_bytes {
            return Err(format!(
                "input tile {t_n}x{}x{} = {in_rf} B exceeds the {} B Input RF",
                base.t_ri, base.t_ci, mem.rf_bytes
            ));
        }
        // APEs hold t_ro×t_co running 32-bit partials per output channel.
        let out_rf = t_m * t_ro * t_co * 4;
        if out_rf as f64 > mem.rf_bytes {
            return Err(format!(
                "output tile {t_m}x{t_ro}x{t_co}x4 = {out_rf} B exceeds the {} B Output RF",
                mem.rf_bytes
            ));
        }
        if spec.groups > 1 {
            if t_n > spec.n_per_group() {
                return Err(format!(
                    "C tile {t_n} spans a group boundary (N/groups = {})",
                    spec.n_per_group()
                ));
            }
            if t_m > spec.m_per_group() {
                return Err(format!(
                    "K tile {t_m} spans a group boundary (M/groups = {})",
                    spec.m_per_group()
                ));
            }
        }
        Ok(())
    }
}

/// The analytical reuse profile of one (layer, mapping) candidate — the
/// four MAESTRO reuse classes as they manifest in CoDR's dataflow.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReuseFactors {
    /// PUs each Input-RF tile is multicast to per fetch (spatial).
    pub input_spatial_multicast: f64,
    /// Times each input feature is re-fetched from SRAM over the layer
    /// (the paper's `M/(T_PU·T_M)` passes).
    pub input_temporal_reuse: f64,
    /// Times the compressed weight stream is re-read (once per spatial
    /// tile — §III-B's deliberate trade).
    pub weight_temporal_reuse: f64,
    /// Accumulations folded into each output feature over time
    /// (`N/groups · R_K²` in a dense walk).
    pub output_temporal_reduction: f64,
    /// Cross-PE reduction fan-in per output — 1 for CoDR (output
    /// stationary: no partial sums ever cross PUs).
    pub output_spatial_reduction: f64,
}

/// Compute the reuse factors of a mapping on a layer (per group; every
/// group of a grouped conv has the identical profile).
pub fn reuse_factors(spec: &LayerSpec, mapping: &Mapping, base: &TileConfig) -> ReuseFactors {
    let cfg = mapping.derived_config(base);
    let t_ro_eff = cfg.t_ro_eff(spec.r_k, spec.stride);
    let t_co_eff = cfg.t_co_eff(spec.r_k, spec.stride);
    let r_o = spec.r_o();
    let n_sp = r_o.div_ceil(t_ro_eff) * r_o.div_ceil(t_co_eff);
    let m_tiles = spec.m_per_group().div_ceil(cfg.t_m);
    let m_groups = m_tiles.div_ceil(cfg.t_pu);
    ReuseFactors {
        input_spatial_multicast: cfg.t_pu.min(m_tiles) as f64,
        input_temporal_reuse: m_groups as f64,
        weight_temporal_reuse: n_sp as f64,
        output_temporal_reduction: (spec.n_per_group() * spec.r_k * spec.r_k) as f64,
        output_spatial_reduction: 1.0,
    }
}

/// Price one (layer, mapping) candidate through the exact dataflow walk:
/// lower the mapping to its derived tile configuration and run the same
/// `codr::dataflow` loop nest (with per-group decomposition for grouped
/// convs) that prices the paper figures.
pub fn price_mapping(
    base: &Codr,
    spec: &LayerSpec,
    weights: &Weights,
    mapping: &Mapping,
) -> LayerResult {
    let design = Codr {
        cfg: mapping.derived_config(&base.cfg),
        cacti: base.cacti.clone(),
        mem: base.mem,
    };
    simulate_layer_grouped(&design, spec, weights)
}

/// One priced candidate: its mapping, the three Pareto axes, and the
/// analytical reuse profile.
#[derive(Clone, Debug)]
pub struct CandidateResult {
    pub mapping: Mapping,
    /// Fig 7 metric: total SRAM accesses of the layer.
    pub sram_accesses: u64,
    /// Total energy of the layer, µJ.
    pub energy_uj: f64,
    /// Multiplier-array utilization in [0, 1].
    pub utilization: f64,
    pub cycles: u64,
    pub reuse: ReuseFactors,
    /// Served from the content-addressed store rather than simulated.
    pub cache_hit: bool,
}

impl CandidateResult {
    /// Assemble from a priced layer result.
    pub fn from_layer(
        mapping: Mapping,
        base: &TileConfig,
        spec: &LayerSpec,
        r: &LayerResult,
        cache_hit: bool,
    ) -> CandidateResult {
        let reuse = reuse_factors(spec, &mapping, base);
        CandidateResult {
            utilization: r.alu.utilization(base.total_mults(), r.cycles),
            sram_accesses: r.mem.sram_accesses(),
            energy_uj: r.energy.total_uj(),
            cycles: r.cycles,
            reuse,
            mapping,
            cache_hit,
        }
    }

    /// `self` Pareto-dominates `other` on (SRAM↓, energy↓, utilization↑).
    pub fn dominates(&self, other: &CandidateResult) -> bool {
        let no_worse = self.sram_accesses <= other.sram_accesses
            && self.energy_uj <= other.energy_uj
            && self.utilization >= other.utilization;
        let better = self.sram_accesses < other.sram_accesses
            || self.energy_uj < other.energy_uj
            || self.utilization > other.utilization;
        no_worse && better
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mobile, synthesize_weights, LayerKind};
    use crate::util::rng::Rng;

    fn layer(n: usize, m: usize, r_i: usize, r_k: usize, stride: usize) -> LayerSpec {
        LayerSpec {
            name: "map-test".into(),
            kind: LayerKind::Conv,
            n,
            m,
            r_i,
            r_k,
            stride,
            pad: 0,
            groups: 1,
            sigma_q: 10.0,
            zero_frac: 0.5,
        }
    }

    #[test]
    fn baseline_mapping_lowers_to_the_arch_config() {
        let cfg = TileConfig::codr();
        let spec = layer(16, 32, 14, 3, 1);
        let m = Mapping::baseline(&cfg, &spec);
        assert_eq!(m.derived_config(&cfg), cfg);
        assert!(m.validate(&spec, &cfg, &MemConfig::default()).is_ok());
        let s = m.to_string();
        assert!(s.contains("SpatialMap(4,4) K"), "{s}");
        assert!(s.contains("TemporalMap(3,3) R"), "{s}");
    }

    #[test]
    fn baseline_mapping_prices_bit_for_bit() {
        // The tentpole invariance pin: fixed dataflow ≡ its directive set.
        for (spec, seed) in [
            (layer(10, 14, 12, 3, 1), 41u64),
            (layer(3, 96, 227, 11, 4), 42), // alexnet conv1 geometry
        ] {
            let mut rng = Rng::new(seed);
            let w = synthesize_weights(&spec, &mut rng);
            let base = Codr::default();
            let fixed = crate::codr::dataflow::simulate_layer(&base, &spec, &w);
            let mapped = price_mapping(&base, &spec, &w, &Mapping::baseline(&base.cfg, &spec));
            assert_eq!(mapped, fixed, "seed {seed}");
        }
    }

    #[test]
    fn validation_rejects_malformed_directive_sets() {
        let cfg = TileConfig::codr();
        let mem = MemConfig::default();
        let spec = layer(16, 32, 14, 3, 1);
        // Spatial on the wrong dimension.
        let mut m = Mapping::from_tiles(&spec, 8, 4, 4, 8, 8);
        m.directives[2].kind = MapKind::Temporal;
        m.directives[3].kind = MapKind::Spatial;
        assert!(m.validate(&spec, &cfg, &mem).unwrap_err().contains("SpatialMap"));
        // Overlapping tiles.
        let mut m = Mapping::from_tiles(&spec, 8, 4, 4, 8, 8);
        m.directives[0].offset = 4;
        assert!(m.validate(&spec, &cfg, &mem).unwrap_err().contains("offset"));
        // Partial kernel unroll.
        let mut m = Mapping::from_tiles(&spec, 8, 4, 4, 8, 8);
        m.directives[4].size = 1;
        m.directives[4].offset = 1;
        assert!(m.validate(&spec, &cfg, &mem).unwrap_err().contains("unrolled"));
        // PE budget.
        let m = Mapping::from_tiles(&spec, 1024, 4, 4, 8, 8);
        assert!(m.validate(&spec, &cfg, &mem).unwrap_err().contains("budget"));
        // RF budgets.
        let m = Mapping::from_tiles(&spec, 8, 4, 64, 8, 8);
        assert!(m.validate(&spec, &cfg, &mem).unwrap_err().contains("Input RF"));
        let m = Mapping::from_tiles(&spec, 8, 64, 4, 8, 8);
        assert!(m.validate(&spec, &cfg, &mem).unwrap_err().contains("Output RF"));
    }

    #[test]
    fn validation_enforces_group_boundaries() {
        let cfg = TileConfig::codr();
        let mem = MemConfig::default();
        let zoo = mobile();
        let dw = zoo.layers.iter().find(|l| l.name == "dw2").unwrap();
        assert_eq!(dw.n_per_group(), 1, "depthwise");
        // A C tile wider than one channel would mix groups: reject.
        let m = Mapping::from_tiles(dw, 8, 1, 4, 8, 8);
        assert!(m.validate(dw, &cfg, &mem).unwrap_err().contains("group boundary"));
        // One channel per tile is legal.
        let m = Mapping::from_tiles(dw, 8, 1, 1, 8, 8);
        assert!(m.validate(dw, &cfg, &mem).is_ok());
        // Grouped conv from the zoo stays legal at the baseline tiles.
        let g3 = zoo.layers.iter().find(|l| l.name == "g3").unwrap();
        assert_eq!(g3.m_per_group(), 16);
        let m = Mapping::from_tiles(g3, 8, 4, 4, 8, 8);
        assert!(m.validate(g3, &cfg, &mem).is_ok());
        // A K tile wider than the per-group channel count rejects (tight
        // groups so the RF budget is not the binding constraint).
        let tight = LayerSpec {
            groups: 4,
            ..layer(8, 8, 14, 3, 1)
        };
        assert_eq!(tight.m_per_group(), 2);
        let m = Mapping::from_tiles(&tight, 8, 4, 2, 8, 8);
        assert!(m.validate(&tight, &cfg, &mem).unwrap_err().contains("group boundary"));
    }

    #[test]
    fn reuse_factors_match_paper_formulas() {
        let cfg = TileConfig::codr();
        let spec = layer(4, 64, 16, 3, 1);
        let f = reuse_factors(&spec, &Mapping::baseline(&cfg, &spec), &cfg);
        // M/(T_PU·T_M) = 64/32 = 2 input passes; full PU multicast.
        assert_eq!(f.input_temporal_reuse, 2.0);
        assert_eq!(f.input_spatial_multicast, 8.0);
        // 14x14 output over the 8x8 (RF-unclipped, 3x3 s1) tiles → 4 tiles.
        assert_eq!(f.weight_temporal_reuse, 4.0);
        assert_eq!(f.output_temporal_reduction, (4 * 9) as f64);
        assert_eq!(f.output_spatial_reduction, 1.0);
        // Fewer PUs → more input passes, narrower multicast.
        let small = Mapping::from_tiles(&spec, 2, 4, 4, 8, 8);
        let f2 = reuse_factors(&spec, &small, &cfg);
        assert_eq!(f2.input_spatial_multicast, 2.0);
        assert_eq!(f2.input_temporal_reuse, 8.0);
    }

    #[test]
    fn grouped_pricing_decomposes_per_group() {
        let zoo = mobile();
        let g3 = zoo.layers.iter().find(|l| l.name == "g3").unwrap();
        let mut rng = Rng::new(9);
        let w = synthesize_weights(g3, &mut rng);
        let base = Codr::default();
        let r = price_mapping(&base, g3, &w, &Mapping::from_tiles(g3, 8, 4, 4, 8, 8));
        // Outputs written exactly once across all groups.
        assert_eq!(r.mem.output_sram.accesses, g3.output_features() as u64);
        assert!(r.cycles > 0);
    }

    #[test]
    fn dominance_is_strict_and_axiswise() {
        let spec = layer(4, 8, 8, 3, 1);
        let cfg = TileConfig::codr();
        let mk = |sram: u64, e: f64, u: f64| CandidateResult {
            mapping: Mapping::baseline(&cfg, &spec),
            sram_accesses: sram,
            energy_uj: e,
            utilization: u,
            cycles: 1,
            reuse: reuse_factors(&spec, &Mapping::baseline(&cfg, &spec), &cfg),
            cache_hit: false,
        };
        let a = mk(100, 1.0, 0.5);
        assert!(mk(90, 1.0, 0.5).dominates(&a));
        assert!(!a.dominates(&a), "equal never dominates");
        assert!(!mk(90, 2.0, 0.5).dominates(&a), "worse on one axis");
        assert!(mk(100, 1.0, 0.6).dominates(&a));
    }
}
