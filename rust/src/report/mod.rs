//! Report generators — one per paper table/figure (DESIGN.md experiment
//! index E1–E8). Each renders an ASCII table mirroring the paper's rows
//! and an optional CSV for plotting.

pub mod figures;

pub use figures::{
    fig2_report, fig6_report, fig7_report, fig8_report, headline_report, sram_detail_report,
    table1_report,
};

/// Render an ASCII table.
pub fn ascii_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    let render_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("|")
    };
    out.push_str(&render_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    ));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&render_row(row));
        out.push('\n');
    }
    out
}

/// Render rows as CSV (headers first).
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&headers.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Write a report artifact under `results/`.
pub fn write_results_file(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = ascii_table(
            "t",
            &["a", "long_header"],
            &[
                vec!["1".into(), "2".into()],
                vec!["wide_cell".into(), "3".into()],
            ],
        );
        assert!(t.contains("long_header"));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 5);
        // Header and data rows align on the separator.
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn csv_shape() {
        let c = csv(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(c, "x,y\n1,2\n");
    }
}
