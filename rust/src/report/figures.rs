//! Per-figure renderers. Each takes simulation results and prints the
//! same rows/series the paper reports (DESIGN.md E1–E8).

use super::ascii_table;
use crate::arch::TileConfig;
use crate::coordinator::{headline, Arch, SweepResults};
use crate::models::{Model, SweepGroup, Workload};
use crate::reuse::stats::{model_distribution_16bit, model_distribution_8bit};
use anyhow::Result;

fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// **Fig 2** — average distribution of zero weights and sorted-weight Δs,
/// 8-bit and 16-bit, per model.
pub fn fig2_report(models: &[Model], seed: u64) -> String {
    let headers = vec![
        "model", "prec", "W=0", "Δ=0", "0<Δ≤3", "3<Δ≤15", "Δ>15/abs",
    ];
    let mut rows = Vec::new();
    for m in models {
        let wl = Workload::generate(m, None, None, seed);
        let d8 = model_distribution_8bit(&wl, 4, 4);
        rows.push(vec![
            m.name.to_string(),
            "8-bit".into(),
            pct(d8.zero),
            pct(d8.delta_zero),
            pct(d8.delta_small),
            pct(d8.delta_mid),
            pct(d8.delta_large),
        ]);
        let d16 = model_distribution_16bit(m, seed, 4, 4);
        rows.push(vec![
            m.name.to_string(),
            "16-bit".into(),
            pct(d16.zero),
            pct(d16.delta_zero),
            pct(d16.delta_small),
            pct(d16.delta_mid),
            pct(d16.delta_large),
        ]);
    }
    ascii_table(
        "Fig 2: weight / Δ distribution (per reuse vector, averaged)",
        &headers,
        &rows,
    )
}

/// **Table I** — the RTL tiling parameters.
pub fn table1_report() -> String {
    let cfgs = [TileConfig::codr(), TileConfig::ucnn(), TileConfig::scnn()];
    let headers = vec!["Parameter", "CoDR", "UCNN", "SCNN"];
    let row = |name: &str, f: &dyn Fn(&TileConfig) -> String| -> Vec<String> {
        let mut r = vec![name.to_string()];
        r.extend(cfgs.iter().map(f));
        r
    };
    let rows = vec![
        row("T_PU", &|c| c.t_pu.to_string()),
        row("T_M, T_N", &|c| format!("{}, {}", c.t_m, c.t_n)),
        row("T_RO, T_CO", &|c| format!("{}, {}", c.t_ro, c.t_co)),
        row("T_RI, T_CI", &|c| format!("{}, {}", c.t_ri, c.t_ci)),
        row("x per PU", &|c| c.mults_per_pu.to_string()),
    ];
    ascii_table("Table I: RTL design tiling parameters", &headers, &rows)
}

/// **Fig 6** — weight compression rate (× vs dense 8-bit) per model,
/// sweep group, and design.
pub fn fig6_report(results: &SweepResults, models: &[&str], groups: &[SweepGroup]) -> String {
    let headers = vec!["model", "group", "CoDR", "UCNN", "SCNN", "CoDR b/w"];
    let mut rows = Vec::new();
    for model in models {
        for &g in groups {
            let get = |a: Arch| results.get(model, g, a).map(|r| r.compression());
            let (c, u, s) = (get(Arch::Codr), get(Arch::Ucnn), get(Arch::Scnn));
            rows.push(vec![
                model.to_string(),
                g.label(),
                c.map_or("-".into(), |x| format!("{:.2}x", x.rate())),
                u.map_or("-".into(), |x| format!("{:.2}x", x.rate())),
                s.map_or("-".into(), |x| format!("{:.2}x", x.rate())),
                c.map_or("-".into(), |x| format!("{:.2}", x.bits_per_weight())),
            ]);
        }
    }
    ascii_table(
        "Fig 6: weight compression rate vs dense 8-bit",
        &headers,
        &rows,
    )
}

/// **Fig 7** — SRAM accesses by data type (paper plots GoogleNet).
pub fn fig7_report(results: &SweepResults, model: &str, groups: &[SweepGroup]) -> String {
    let headers = vec![
        "group", "arch", "weight", "input", "output", "total", "wgt BW%",
    ];
    let fmt = |x: u64| {
        if x >= 1_000_000_000 {
            format!("{:.2}G", x as f64 / 1e9)
        } else if x >= 1_000_000 {
            format!("{:.1}M", x as f64 / 1e6)
        } else {
            format!("{:.0}k", x as f64 / 1e3)
        }
    };
    let mut rows = Vec::new();
    for &g in groups {
        for &a in &Arch::all() {
            if let Some(r) = results.get(model, g, a) {
                let m = r.mem();
                rows.push(vec![
                    g.label(),
                    a.name().into(),
                    fmt(m.weight_sram.accesses),
                    fmt(m.input_sram.accesses),
                    fmt(m.output_sram.accesses),
                    fmt(m.sram_accesses()),
                    pct(m.weight_bw_fraction()),
                ]);
            }
        }
    }
    ascii_table(
        &format!("Fig 7: SRAM accesses by data type ({model})"),
        &headers,
        &rows,
    )
}

/// **Fig 8** — energy breakdown (µJ) per model/group/design.
pub fn fig8_report(results: &SweepResults, models: &[&str], groups: &[SweepGroup]) -> String {
    let headers = vec![
        "model", "group", "arch", "DRAM", "SRAM", "RF", "ALU", "xbar", "total µJ",
    ];
    let mut rows = Vec::new();
    for model in models {
        for &g in groups {
            for &a in &Arch::all() {
                if let Some(r) = results.get(model, g, a) {
                    let e = r.energy();
                    rows.push(vec![
                        model.to_string(),
                        g.label(),
                        a.name().into(),
                        format!("{:.0}", e.dram_uj),
                        format!("{:.0}", e.sram_uj),
                        format!("{:.0}", e.rf_uj),
                        format!("{:.0}", e.alu_uj),
                        format!("{:.1}", e.xbar_uj),
                        format!("{:.0}", e.total_uj()),
                    ]);
                }
            }
        }
    }
    ascii_table("Fig 8: energy breakdown (µJ)", &headers, &rows)
}

/// **§V-C detail** — per-access cost ratios and per-feature access counts
/// (the paper reports: UCNN/SCNN read inputs 20.4×/21.3× more than CoDR,
/// UCNN touches each output 72.1 times, CoDR spends 50% of SRAM BW on
/// weights vs UCNN's 1.4%).
pub fn sram_detail_report(results: &SweepResults, model: &Model) -> String {
    let headers = vec![
        "arch",
        "wgt acc",
        "in acc (x CoDR)",
        "out acc/feature",
        "wgt BW%",
    ];
    let mut rows = Vec::new();
    let codr_in = results
        .get(model.name, SweepGroup::Original, Arch::Codr)
        .map(|r| r.mem().input_sram.accesses)
        .unwrap_or(1)
        .max(1);
    let out_feats: u64 = model
        .conv_layers()
        .map(|l| l.output_features() as u64)
        .sum::<u64>()
        .max(1);
    for &a in &Arch::all() {
        if let Some(r) = results.get(model.name, SweepGroup::Original, a) {
            let m = r.mem();
            rows.push(vec![
                a.name().into(),
                m.weight_sram.accesses.to_string(),
                format!(
                    "{} ({:.1}x)",
                    m.input_sram.accesses,
                    m.input_sram.accesses as f64 / codr_in as f64
                ),
                format!("{:.1}", m.output_sram.accesses as f64 / out_feats as f64),
                pct(m.weight_bw_fraction()),
            ]);
        }
    }
    ascii_table(
        &format!("§V-C: SRAM access detail ({}, original group)", model.name),
        &headers,
        &rows,
    )
}

/// **Headline** (abstract / §V) — CoDR vs UCNN and SCNN. Errors when the
/// sweep lacks a requested (model, arch) point at the original group.
pub fn headline_report(results: &SweepResults, models: &[&str]) -> Result<String> {
    let h = headline(results, models)?;
    let headers = vec!["metric", "vs UCNN (paper)", "vs SCNN (paper)", "measured UCNN", "measured SCNN"];
    let rows = vec![
        vec![
            "weight compression".into(),
            "1.69x".into(),
            "2.80x".into(),
            format!("{:.2}x", h.compression_vs_ucnn),
            format!("{:.2}x", h.compression_vs_scnn),
        ],
        vec![
            "SRAM access reduction".into(),
            "5.08x".into(),
            "7.99x".into(),
            format!("{:.2}x", h.sram_vs_ucnn),
            format!("{:.2}x", h.sram_vs_scnn),
        ],
        vec![
            "energy reduction".into(),
            "3.76x".into(),
            "6.84x".into(),
            format!("{:.2}x", h.energy_vs_ucnn),
            format!("{:.2}x", h.energy_vs_scnn),
        ],
        vec![
            "CoDR bits/weight".into(),
            "1.69".into(),
            "-".into(),
            format!("{:.2}", h.codr_bits_per_weight),
            "-".into(),
        ],
    ];
    Ok(ascii_table(
        "Headline: CoDR vs UCNN / SCNN (paper vs measured)",
        &headers,
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_sweep;
    use crate::models::tiny_cnn;

    #[test]
    fn table1_matches_paper_cells() {
        let t = table1_report();
        assert!(t.contains("T_PU"));
        assert!(t.contains("48"));
        assert!(t.contains("21"));
        assert!(t.contains("20, 20"));
    }

    #[test]
    fn fig_reports_render_on_tiny_sweep() {
        let models = [tiny_cnn()];
        let groups = [SweepGroup::Original, SweepGroup::Density(50)];
        let r = run_sweep(&models, &groups, &Arch::all(), 3);
        let f6 = fig6_report(&r, &["tiny"], &groups);
        assert!(f6.contains("tiny") && f6.contains("D=50%"));
        let f7 = fig7_report(&r, "tiny", &groups);
        assert!(f7.contains("CoDR") && f7.contains("SCNN"));
        let f8 = fig8_report(&r, &["tiny"], &groups);
        assert!(f8.contains("total µJ"));
        let h = headline_report(&r, &["tiny"]).unwrap();
        assert!(h.contains("5.08x"));
        // A sweep that misses the grid surfaces as an error, not a panic.
        assert!(headline_report(&r, &["vgg16"]).is_err());
        let d = sram_detail_report(&r, &tiny_cnn());
        assert!(d.contains("wgt BW%"));
    }

    #[test]
    fn fig2_renders_all_models_and_precisions() {
        let t = fig2_report(&[tiny_cnn()], 1);
        assert!(t.contains("8-bit") && t.contains("16-bit"));
    }
}
