//! A minimal scoped thread pool: `parallel_map` over a slice with an
//! atomic work cursor. Order-preserving (results land at their input
//! index), panic-propagating, and allocation-light.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` using up to `available_parallelism` threads.
/// Results are returned in input order.
pub fn parallel_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    parallel_map_with(items, default_threads(), f)
}

/// Number of worker threads used by [`parallel_map`].
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Map with an explicit thread count.
pub fn parallel_map_with<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let threads = threads.clamp(1, items.len().max(1));
    if threads == 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker skipped a slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = parallel_map(&xs, |&x| x * x);
        assert_eq!(ys, xs.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(parallel_map(&xs, |&x| x).is_empty());
    }

    #[test]
    fn single_item() {
        assert_eq!(parallel_map(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let calls = AtomicU64::new(0);
        let xs: Vec<usize> = (0..57).collect();
        let ys = parallel_map_with(&xs, 8, |&x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(calls.load(Ordering::Relaxed), 57);
        assert_eq!(ys, xs);
    }

    #[test]
    fn more_threads_than_items() {
        // `threads` clamps to the item count: no idle spawns, no panics,
        // every item mapped exactly once.
        let calls = AtomicU64::new(0);
        let xs: Vec<usize> = (0..3).collect();
        let ys = parallel_map_with(&xs, 64, |&x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x * 10
        });
        assert_eq!(ys, vec![0, 10, 20]);
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        // Degenerate corners: zero threads requested, and one item.
        assert_eq!(parallel_map_with(&[5], 0, |&x| x + 1), vec![6]);
        assert_eq!(parallel_map_with(&[5], 1000, |&x| x + 1), vec![6]);
    }

    #[test]
    #[should_panic]
    fn propagates_worker_panics() {
        let xs: Vec<u32> = (0..16).collect();
        let _ = parallel_map_with(&xs, 4, |&x| {
            if x == 7 {
                panic!("boom");
            }
            x
        });
    }
}
