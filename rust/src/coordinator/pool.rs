//! A minimal scoped thread pool: `parallel_map` over a slice with
//! work-stealing scheduling. Order-preserving (results land at their
//! input index), panic-propagating, and allocation-light.
//!
//! Scheduling: each worker owns a contiguous index range; it pops from
//! its own front (uncontended in the common case — no shared cursor
//! cacheline bouncing across every task), and when dry it steals the
//! top half of the largest remaining range. Long tasks at the tail of
//! the input (one giant conv layer's chunks, say) therefore get
//! redistributed instead of serializing behind whoever drew them.

use crate::util::sync;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` using up to `available_parallelism` threads.
/// Results are returned in input order.
pub fn parallel_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    parallel_map_with(items, default_threads(), f)
}

/// Run `f` with panics contained: a panic (including one injected at
/// the `pool.worker.panic` fault point) becomes `Err(message)` instead
/// of unwinding into the caller's bookkeeping. This is the seam the
/// scheduler wraps around each chunk/finalize/assemble computation, so
/// one crashing task fails its own sweep point while completion
/// counters, claim release, and waiter wakeups all still run.
pub fn run_isolated<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    catch_unwind(AssertUnwindSafe(|| {
        crate::faults::panic_point("pool.worker.panic");
        f()
    }))
    .map_err(|payload| panic_message(payload.as_ref()))
}

/// Best-effort text of a panic payload (`panic!` with a literal or a
/// formatted string covers everything this codebase throws).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

/// Number of worker threads used by [`parallel_map`].
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Map with an explicit thread count.
pub fn parallel_map_with<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let threads = threads.clamp(1, items.len().max(1));
    if threads == 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let queue = StealQueue::new(items.len(), threads);
    let slots: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    // A panicking item must not tear down the pool mid-map: the worker
    // catches it, the remaining items still run, and the first payload
    // re-raises after the join — same contract as before (the caller
    // sees the panic), but siblings complete and the queue drains, so a
    // crash never strands work that later bookkeeping depends on.
    let first_panic: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for worker in 0..threads {
            let queue = &queue;
            let slots = &slots;
            let f = &f;
            let first_panic = &first_panic;
            scope.spawn(move || {
                while let Some(i) = queue.pop(worker) {
                    match catch_unwind(AssertUnwindSafe(|| f(&items[i]))) {
                        Ok(r) => *sync::lock(&slots[i]) = Some(r),
                        Err(payload) => {
                            let mut slot = sync::lock(first_panic);
                            if slot.is_none() {
                                *slot = Some(payload);
                            }
                        }
                    }
                }
            });
        }
    });
    if let Some(payload) = sync::into_inner(first_panic) {
        resume_unwind(payload);
    }
    slots
        .into_iter()
        // analyze: allow(panic_policy): scoped threads joined above and the queue partitions indexes, so every slot is filled
        .map(|s| sync::into_inner(s).expect("worker skipped a slot"))
        .collect()
}

/// Per-worker index ranges with steal-half rebalancing. Invariants: the
/// ranges always partition the not-yet-handed-out indexes (every
/// mutation happens under the owning range's lock and preserves the
/// partition), so each index is popped exactly once; and `remaining`
/// counts indexes not yet returned by `pop`, so termination is judged
/// against it, never against a scan of the ranges — a stolen half is
/// briefly invisible (out of the victim, not yet published as the
/// thief's range), and a scan-based exit would let idle workers quit
/// while that half still holds work.
struct StealQueue {
    ranges: Vec<Mutex<(usize, usize)>>,
    remaining: AtomicUsize,
}

impl StealQueue {
    fn new(n: usize, workers: usize) -> StealQueue {
        StealQueue {
            ranges: (0..workers)
                .map(|w| Mutex::new((n * w / workers, n * (w + 1) / workers)))
                .collect(),
            remaining: AtomicUsize::new(n),
        }
    }

    /// Next index for `me`: own range first, else steal the top half of
    /// the victim with the most work left. `None` only once every index
    /// has been handed out (work already popped may still be executing
    /// elsewhere).
    fn pop(&self, me: usize) -> Option<usize> {
        loop {
            {
                let mut own = sync::lock(&self.ranges[me]);
                if own.0 < own.1 {
                    let i = own.0;
                    own.0 += 1;
                    self.remaining.fetch_sub(1, Ordering::AcqRel);
                    return Some(i);
                }
            }
            if self.remaining.load(Ordering::Acquire) == 0 {
                return None; // everything handed out
            }
            // Dry: pick the victim with the largest remaining range
            // (locks taken one at a time — never two held at once).
            let mut victim: Option<(usize, usize)> = None; // (worker, remaining)
            for (w, range) in self.ranges.iter().enumerate() {
                if w == me {
                    continue;
                }
                let r = sync::lock(range);
                let rem = r.1 - r.0;
                if rem > victim.map_or(0, |(_, best)| best) {
                    victim = Some((w, rem));
                }
            }
            let Some((w, _)) = victim else {
                // Nothing visible, but `remaining > 0`: a thief holds an
                // unpublished stolen half. Yield and rescan — it becomes
                // stealable the moment the thief publishes it.
                std::thread::yield_now();
                continue;
            };
            // Re-check under the victim's lock (it may have drained or
            // been stolen from since the scan), then take the top half.
            let (mid, hi) = {
                let mut r = sync::lock(&self.ranges[w]);
                let rem = r.1 - r.0;
                if rem == 0 {
                    continue; // lost the race; rescan
                }
                let take = (rem + 1) / 2;
                let mid = r.1 - take;
                let hi = r.1;
                r.1 = mid;
                (mid, hi)
            };
            // Publish the rest of the stolen half as our range BEFORE
            // returning, so it is invisible only for these few lines.
            {
                let mut own = sync::lock(&self.ranges[me]);
                debug_assert!(own.0 >= own.1, "stealing while local work remains");
                *own = (mid + 1, hi);
            }
            self.remaining.fetch_sub(1, Ordering::AcqRel);
            return Some(mid);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = parallel_map(&xs, |&x| x * x);
        assert_eq!(ys, xs.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(parallel_map(&xs, |&x| x).is_empty());
    }

    #[test]
    fn single_item() {
        assert_eq!(parallel_map(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let calls = AtomicU64::new(0);
        let xs: Vec<usize> = (0..57).collect();
        let ys = parallel_map_with(&xs, 8, |&x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(calls.load(Ordering::Relaxed), 57);
        assert_eq!(ys, xs);
    }

    #[test]
    fn more_threads_than_items() {
        // `threads` clamps to the item count: no idle spawns, no panics,
        // every item mapped exactly once.
        let calls = AtomicU64::new(0);
        let xs: Vec<usize> = (0..3).collect();
        let ys = parallel_map_with(&xs, 64, |&x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x * 10
        });
        assert_eq!(ys, vec![0, 10, 20]);
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        // Degenerate corners: zero threads requested, and one item.
        assert_eq!(parallel_map_with(&[5], 0, |&x| x + 1), vec![6]);
        assert_eq!(parallel_map_with(&[5], 1000, |&x| x + 1), vec![6]);
    }

    #[test]
    fn steal_queue_hands_out_every_index_exactly_once() {
        // Single-threaded exhaustion through one worker: it must drain
        // its own range, then strip-mine the other range by halves.
        let q = StealQueue::new(10, 2);
        let mut seen = std::collections::BTreeSet::new();
        while let Some(i) = q.pop(0) {
            assert!(seen.insert(i), "index {i} handed out twice");
        }
        assert_eq!(seen.len(), 10);
        assert_eq!(q.pop(1), None, "nothing left for the other worker");
    }

    #[test]
    fn skewed_tail_work_gets_stolen() {
        // All the heavy items sit in the LAST worker's initial range; a
        // single shared-cursor pool would also survive this, but here
        // the steal path itself is what executes — every item must still
        // run exactly once with correct results.
        let xs: Vec<u64> = (0..64).collect();
        let ys = parallel_map_with(&xs, 4, |&x| {
            if x >= 48 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x + 1
        });
        assert_eq!(ys, (1..=64).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn propagates_worker_panics() {
        let xs: Vec<u32> = (0..16).collect();
        let _ = parallel_map_with(&xs, 4, |&x| {
            if x == 7 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn panicking_item_does_not_strand_its_siblings() {
        // Containment: every non-panicking item still runs to completion
        // before the original panic re-raises out of the map.
        let calls = AtomicU64::new(0);
        let xs: Vec<u32> = (0..32).collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map_with(&xs, 4, |&x| {
                if x == 3 {
                    panic!("early boom");
                }
                calls.fetch_add(1, Ordering::Relaxed);
                x
            })
        }));
        assert!(caught.is_err(), "the panic must still propagate");
        assert_eq!(
            calls.load(Ordering::Relaxed),
            31,
            "all siblings of the panicking item must have run"
        );
    }

    #[test]
    fn run_isolated_contains_panics_as_errors() {
        assert_eq!(run_isolated(|| 41 + 1), Ok(42));
        let err = run_isolated(|| -> u32 { panic!("chunk exploded") }).unwrap_err();
        assert_eq!(err, "chunk exploded");
        let err = run_isolated(|| -> u32 { panic!("formatted {}", 7) }).unwrap_err();
        assert_eq!(err, "formatted 7");
    }
}
