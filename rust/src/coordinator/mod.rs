//! Sweep coordinator: fans the (model × sweep-group × architecture ×
//! layer × tile-chunk) grid out over a thread pool, caches per-point
//! results, and computes the paper's headline aggregates.
//!
//! tokio is unavailable in the offline registry; the pool is
//! `std::thread::scope` over per-worker work-stealing ranges. The task
//! unit is a *tile chunk* of one (arch, layer) simulation
//! ([`layer_chunks`] splits big layers over their m-tile ranges, merged
//! exactly by [`finalize_layer`]), so one giant conv layer no longer
//! serializes the tail of a sweep point — its chunks spread across the
//! pool and stragglers get stolen.
//!
//! [`run_sweep_with`] threads an optional [`ResultStore`] through the
//! sweep: points already in the store are loaded instead of simulated
//! (format v2 reads one packed group file per (model, group), so a warm
//! grid of P points across G groups costs G reads, not P), and newly
//! computed points are persisted into their packs as each one's last
//! layer completes. [`SweepStats`] reports what happened —
//! `simulated_layers == 0` is the proof that a warm store served the
//! whole grid without a single `simulate_layer` call.

pub mod pool;

use crate::arch::TileConfig;
use crate::baselines::{ucnn, Scnn, Ucnn};
use crate::codr::{dataflow, Codr};
use crate::models::{LayerSpec, Model, SweepGroup, Workload};
use crate::reuse::memo;
use crate::serve::{ResultStore, Scheduler};
use crate::sim::{Accelerator, LayerResult, ModelResult};
use crate::tensor::Weights;
use anyhow::{bail, Result};
use std::time::Instant;

/// The three designs of the evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arch {
    Codr,
    Ucnn,
    Scnn,
}

impl Arch {
    pub fn all() -> [Arch; 3] {
        [Arch::Codr, Arch::Ucnn, Arch::Scnn]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Arch::Codr => "CoDR",
            Arch::Ucnn => "UCNN",
            Arch::Scnn => "SCNN",
        }
    }

    pub fn build(&self) -> Box<dyn Accelerator> {
        match self {
            Arch::Codr => Box::new(Codr::default()),
            Arch::Ucnn => Box::new(Ucnn::default()),
            Arch::Scnn => Box::new(Scnn::default()),
        }
    }

    /// Parse one design name (case-insensitive).
    pub fn parse(name: &str) -> Result<Arch> {
        match name.trim().to_ascii_lowercase().as_str() {
            "codr" => Ok(Arch::Codr),
            "ucnn" => Ok(Arch::Ucnn),
            "scnn" => Ok(Arch::Scnn),
            other => bail!("unknown arch `{other}` (use CoDR | UCNN | SCNN)"),
        }
    }

    /// Parse a comma-separated design list; `all` expands to every design.
    pub fn parse_list(spec: &str) -> Result<Vec<Arch>> {
        if spec.trim().eq_ignore_ascii_case("all") {
            return Ok(Arch::all().to_vec());
        }
        spec.split(',').map(Arch::parse).collect()
    }
}

/// Smallest per-chunk extraction grain worth a task of its own: below
/// this, task bookkeeping beats the parallelism.
const CHUNK_MIN_WEIGHTS: usize = 1 << 15;

/// Fan-out bound per layer (tasks, not threads — the pool balances).
const MAX_LAYER_CHUNKS: usize = 8;

/// One tile-chunk's worth of a layer simulation, produced by
/// [`simulate_layer_chunk`] and reduced by [`finalize_layer`].
pub enum LayerPartial {
    Codr(dataflow::CodrExtract),
    Ucnn(ucnn::UcnnExtract),
    /// Designs whose extraction does not chunk (SCNN's zero-run scan is
    /// one sequential pass and already the cheapest path) simulate
    /// whole in their single chunk.
    Whole(LayerResult),
}

/// How many tile-chunk tasks this (arch, layer) simulation splits into.
/// Deterministic in the layer alone (never in thread count or timing),
/// so chunked results are reproducible across machines; `1` for small
/// layers and for SCNN.
pub fn layer_chunks(arch: Arch, spec: &LayerSpec) -> usize {
    if spec.groups > 1 {
        // Grouped convs decompose per group (sim::simulate_layer_grouped),
        // not per m-tile range; they ride a single whole-layer task.
        return 1;
    }
    let m_tiles = match arch {
        Arch::Codr => spec.m.div_ceil(TileConfig::codr().t_m),
        Arch::Ucnn => spec.m.div_ceil(TileConfig::ucnn().t_m),
        Arch::Scnn => return 1,
    };
    if spec.num_weights() < 2 * CHUNK_MIN_WEIGHTS {
        return 1;
    }
    (spec.num_weights() / CHUNK_MIN_WEIGHTS).clamp(1, MAX_LAYER_CHUNKS.min(m_tiles))
}

/// The m-tile sub-range of chunk `ci` of `n` (balanced split).
fn chunk_range(total: usize, ci: usize, n: usize) -> (usize, usize) {
    (total * ci / n, total * (ci + 1) / n)
}

/// Run chunk `ci` of `n_chunks` of one (arch, layer) simulation.
pub fn simulate_layer_chunk(
    arch: Arch,
    spec: &LayerSpec,
    weights: &Weights,
    ci: usize,
    n_chunks: usize,
) -> LayerPartial {
    if spec.groups > 1 {
        debug_assert_eq!(n_chunks, 1, "grouped layers never chunk");
        return LayerPartial::Whole(crate::sim::simulate_layer_grouped(
            arch.build().as_ref(),
            spec,
            weights,
        ));
    }
    match arch {
        Arch::Codr => {
            let design = Codr::default();
            let m_tiles = spec.m.div_ceil(design.cfg.t_m);
            let (mt0, mt1) = chunk_range(m_tiles, ci, n_chunks);
            LayerPartial::Codr(dataflow::extract_chunk(&design, spec, weights, mt0, mt1))
        }
        Arch::Ucnn => {
            let design = Ucnn::default();
            let m_tiles = spec.m.div_ceil(design.cfg.t_m);
            let (mt0, mt1) = chunk_range(m_tiles, ci, n_chunks);
            LayerPartial::Ucnn(ucnn::extract_chunk(&design, spec, weights, mt0, mt1))
        }
        Arch::Scnn => {
            debug_assert_eq!(n_chunks, 1, "SCNN never chunks");
            LayerPartial::Whole(Scnn::default().simulate_layer(spec, weights))
        }
    }
}

/// Reduce a layer's chunk partials (in chunk order) to its
/// [`LayerResult`]. Bit-identical to the unchunked `simulate_layer` for
/// every design (pinned by the dataflow/ucnn chunk tests and the
/// determinism sweep test).
pub fn finalize_layer(arch: Arch, spec: &LayerSpec, parts: &[LayerPartial]) -> LayerResult {
    // A single whole-layer partial is already final regardless of design
    // (SCNN always, and grouped layers on every design).
    if let [LayerPartial::Whole(r)] = parts {
        return r.clone();
    }
    match arch {
        Arch::Codr => {
            let chunks: Vec<&dataflow::CodrExtract> = parts
                .iter()
                .map(|p| match p {
                    LayerPartial::Codr(c) => c,
                    _ => unreachable!("CoDR layer carried a foreign partial"),
                })
                .collect();
            dataflow::price_extracted(&Codr::default(), spec, &chunks)
        }
        Arch::Ucnn => {
            let chunks: Vec<ucnn::UcnnExtract> = parts
                .iter()
                .map(|p| match p {
                    LayerPartial::Ucnn(c) => *c,
                    _ => unreachable!("UCNN layer carried a foreign partial"),
                })
                .collect();
            ucnn::price_extracted(&Ucnn::default(), spec, &chunks)
        }
        Arch::Scnn => match parts {
            [LayerPartial::Whole(r)] => r.clone(),
            _ => unreachable!("SCNN layer must be a single whole partial"),
        },
    }
}

/// What the sweep did for each requested point — the cache-hit counters
/// the acceptance checks and the `serve` status verb report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    /// Points in the requested grid.
    pub requested: usize,
    /// Points served from the result store.
    pub cache_hits: usize,
    /// Points simulated in this call.
    pub computed: usize,
    /// Points that waited on an identical in-flight computation instead
    /// of duplicating it (only possible under `codr serve`).
    pub deduped: usize,
    /// Store entries that existed but failed to load (recomputed).
    pub corrupt: usize,
    /// Total `simulate_layer` calls made. Zero on a fully warm store.
    pub simulated_layers: usize,
    /// Weight-vector memo hits/misses during this sweep (deltas of the
    /// process-wide [`memo`] counters — approximate when sweeps run
    /// concurrently, exact otherwise). `memo_hits = l1_hits + l2_hits`.
    pub memo_hits: usize,
    pub memo_misses: usize,
    /// Memo hits resolved in the thread-local L1 front table (no shared
    /// state touched).
    pub l1_hits: usize,
    /// Memo hits that took a shard of the L2 map.
    pub l2_hits: usize,
    /// Byte-verification fallbacks behind detected fingerprint
    /// collisions — zero on any collision-free workload.
    pub collision_verifies: usize,
    /// Memo shard-mutex acquisitions that had to wait (lock contention).
    pub lock_waits: usize,
    /// Points whose computation panicked (isolated per point — see
    /// [`pool::run_isolated`]); their results were neither produced nor
    /// stored, and the job completes as `state:"partial"`.
    pub failed: usize,
    /// Wall-clock of the whole sweep call, in milliseconds.
    pub wall_ms: u64,
}

impl SweepStats {
    /// Memo hit rate in [0, 1], or `None` before any lookup happened
    /// (e.g. a fully store-warm run that never simulated).
    pub fn memo_hit_rate(&self) -> Option<f64> {
        let total = self.memo_hits + self.memo_misses;
        if total == 0 {
            None
        } else {
            Some(self.memo_hits as f64 / total as f64)
        }
    }
}

/// All results of a sweep, queryable by (model, group, arch).
#[derive(Debug, Default)]
pub struct SweepResults {
    pub results: Vec<ModelResult>,
    pub stats: SweepStats,
}

impl SweepResults {
    pub fn get(&self, model: &str, group: SweepGroup, arch: Arch) -> Option<&ModelResult> {
        self.results
            .iter()
            .find(|r| r.model == model && r.group == group.label() && r.arch == arch.name())
    }

    pub fn models(&self) -> Vec<String> {
        let mut m: Vec<String> = self.results.iter().map(|r| r.model.clone()).collect();
        m.sort();
        m.dedup();
        m
    }
}

/// Run the full (or restricted) evaluation grid in parallel, without a
/// result store (every point is simulated).
///
/// Workload generation is seeded per (model, knobs), so results are
/// deterministic regardless of scheduling.
pub fn run_sweep(
    models: &[Model],
    groups: &[SweepGroup],
    archs: &[Arch],
    seed: u64,
) -> SweepResults {
    run_sweep_with(models, groups, archs, seed, None)
}

/// Run the grid through an optional result store: cached points load
/// instead of simulating, missing points are computed and persisted.
///
/// The returned results are ordered (model × group) then arch — the same
/// order as the storeless path — and carry [`SweepStats`] describing the
/// cache behavior. A cold store followed by a warm re-run produces
/// identical `results` with `simulated_layers == 0` on the second pass.
pub fn run_sweep_with(
    models: &[Model],
    groups: &[SweepGroup],
    archs: &[Arch],
    seed: u64,
    store: Option<&ResultStore>,
) -> SweepResults {
    if let Some(store) = store {
        return Scheduler::new(store.clone()).run_grid(models, groups, archs, seed);
    }
    let t0 = Instant::now();
    let memo0 = memo::global().breakdown();

    // Phase 1: synthesize each (model × group) workload once, in
    // parallel — the weights are shared by every design (regenerating
    // them per design tripled the sweep cost, §Perf).
    let mut points = Vec::new();
    for model in models {
        for &group in groups {
            points.push((model.clone(), group));
        }
    }
    let workloads = pool::parallel_map(&points, |(model, group)| {
        let (unique, density) = group.knobs();
        Workload::generate(model, unique, density, seed)
    });

    // Phase 2: fan the layers out as tile-chunk tasks — one pool task
    // per (point, arch, layer, chunk) — then reduce each layer in a
    // second parallel pass. Chunking keeps the tail of a sweep point
    // parallel: one giant VGG16 conv used to ride a single task and
    // serialize the grid's last seconds. `parallel_map` preserves task
    // order and chunk reduction is exact integer merging, so results
    // are deterministic regardless of scheduling.
    let mut chunk_tasks: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
    let mut layer_index: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
    for (pi, wl) in workloads.iter().enumerate() {
        for ai in 0..archs.len() {
            for (li, (spec, _)) in wl.conv_layers().enumerate() {
                let n_chunks = layer_chunks(archs[ai], spec);
                layer_index.push((pi, ai, li, chunk_tasks.len(), n_chunks));
                for ci in 0..n_chunks {
                    chunk_tasks.push((pi, ai, li, ci, n_chunks));
                }
            }
        }
    }
    let partials = pool::parallel_map(&chunk_tasks, |&(pi, ai, li, ci, n_chunks)| {
        let (spec, w) = workloads[pi].conv_layers().nth(li).expect("task layer index");
        simulate_layer_chunk(archs[ai], spec, w, ci, n_chunks)
    });
    let layer_results = pool::parallel_map(&layer_index, |&(pi, ai, li, start, n)| {
        let (spec, _) = workloads[pi]
            .conv_layers()
            .nth(li)
            .expect("finalize layer index");
        finalize_layer(archs[ai], spec, &partials[start..start + n])
    });

    // Phase 3: reassemble in (model × group) then arch order — the same
    // order the seed's nested map produced.
    let mut results = Vec::with_capacity(points.len() * archs.len());
    let mut remaining = layer_results.into_iter();
    for (pi, wl) in workloads.iter().enumerate() {
        let n_layers = wl.conv_layers().count();
        for arch in archs {
            let layers: Vec<LayerResult> = remaining.by_ref().take(n_layers).collect();
            results.push(ModelResult {
                arch: arch.name().to_string(),
                model: wl.model.name.to_string(),
                group: points[pi].1.label(),
                layers,
            });
        }
    }
    let simulated_layers = results.iter().map(|r| r.layers.len()).sum();
    let memo = memo::global().breakdown().since(&memo0);
    let stats = SweepStats {
        requested: results.len(),
        computed: results.len(),
        simulated_layers,
        memo_hits: memo.hits() as usize,
        memo_misses: memo.misses as usize,
        l1_hits: memo.l1_hits as usize,
        l2_hits: memo.l2_hits as usize,
        collision_verifies: memo.collision_verifies as usize,
        lock_waits: memo.lock_waits as usize,
        wall_ms: t0.elapsed().as_millis() as u64,
        ..Default::default()
    };
    SweepResults { results, stats }
}

/// The abstract's headline comparisons at the original sweep group,
/// aggregated over the given models (ratios of sums, as the paper does).
#[derive(Clone, Copy, Debug, Default)]
pub struct Headline {
    /// CoDR compression improvement over UCNN / SCNN (paper: 1.69×, 2.80×).
    pub compression_vs_ucnn: f64,
    pub compression_vs_scnn: f64,
    /// SRAM access reduction (paper: 5.08×, 7.99×).
    pub sram_vs_ucnn: f64,
    pub sram_vs_scnn: f64,
    /// Energy reduction (paper: 3.76×, 6.84×).
    pub energy_vs_ucnn: f64,
    pub energy_vs_scnn: f64,
    /// CoDR's average compressed bits per weight (paper: ≈1.69).
    pub codr_bits_per_weight: f64,
}

/// Compute the headline ratios from sweep results at
/// [`SweepGroup::Original`]. Errors (instead of panicking) when the sweep
/// does not cover a requested (model, arch) point.
pub fn headline(results: &SweepResults, models: &[&str]) -> Result<Headline> {
    let mut agg = std::collections::HashMap::new();
    for &arch in &Arch::all() {
        let mut bits = 0f64;
        let mut weights = 0f64;
        let mut sram = 0f64;
        let mut energy = 0f64;
        for model in models {
            let Some(r) = results.get(model, SweepGroup::Original, arch) else {
                bail!(
                    "missing sweep point {model}/{}/{} — the sweep must cover \
                     the Orig group for every model and design",
                    SweepGroup::Original.label(),
                    arch.name()
                );
            };
            let c = r.compression();
            bits += c.encoded_bits as f64;
            weights += c.num_weights as f64;
            sram += r.mem().sram_accesses() as f64;
            energy += r.energy().total_uj();
        }
        agg.insert(arch, (bits / weights, sram, energy));
    }
    let codr = agg[&Arch::Codr];
    let ucnn = agg[&Arch::Ucnn];
    let scnn = agg[&Arch::Scnn];
    Ok(Headline {
        compression_vs_ucnn: ucnn.0 / codr.0,
        compression_vs_scnn: scnn.0 / codr.0,
        sram_vs_ucnn: ucnn.1 / codr.1,
        sram_vs_scnn: scnn.1 / codr.1,
        energy_vs_ucnn: ucnn.2 / codr.2,
        energy_vs_scnn: scnn.2 / codr.2,
        codr_bits_per_weight: codr.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::tiny_cnn;

    #[test]
    fn sweep_covers_grid_and_is_deterministic() {
        let models = [tiny_cnn()];
        let groups = [SweepGroup::Original, SweepGroup::Density(50)];
        let archs = [Arch::Codr, Arch::Scnn];
        let a = run_sweep(&models, &groups, &archs, 42);
        assert_eq!(a.results.len(), 4);
        assert_eq!(a.stats.requested, 4);
        assert_eq!(a.stats.computed, 4);
        assert_eq!(a.stats.cache_hits, 0);
        let b = run_sweep(&models, &groups, &archs, 42);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.model, y.model);
            assert_eq!(x.cycles(), y.cycles());
            assert_eq!(x.mem(), y.mem());
        }
    }

    #[test]
    fn lookup_by_point() {
        let models = [tiny_cnn()];
        let r = run_sweep(&models, &[SweepGroup::Original], &[Arch::Ucnn], 1);
        assert!(r.get("tiny", SweepGroup::Original, Arch::Ucnn).is_some());
        assert!(r.get("tiny", SweepGroup::Original, Arch::Codr).is_none());
        assert!(r.get("alexnet", SweepGroup::Original, Arch::Ucnn).is_none());
    }

    #[test]
    fn headline_ratios_favor_codr_on_tiny() {
        let models = [tiny_cnn()];
        let r = run_sweep(&models, &[SweepGroup::Original], &Arch::all(), 7);
        let h = headline(&r, &["tiny"]).unwrap();
        assert!(h.compression_vs_ucnn > 1.0, "{h:?}");
        assert!(h.compression_vs_scnn > 1.0, "{h:?}");
        assert!(h.sram_vs_ucnn > 1.0, "{h:?}");
        assert!(h.sram_vs_scnn > 1.0, "{h:?}");
        assert!(h.energy_vs_ucnn > 1.0, "{h:?}");
        assert!(h.energy_vs_scnn > 1.0, "{h:?}");
    }

    #[test]
    fn headline_reports_missing_points_as_errors() {
        // Sweep without CoDR: headline must error, not panic (the seed's
        // `unwrap_or_else(panic!)` took the whole process down).
        let models = [tiny_cnn()];
        let r = run_sweep(&models, &[SweepGroup::Original], &[Arch::Ucnn, Arch::Scnn], 7);
        let err = headline(&r, &["tiny"]).unwrap_err().to_string();
        assert!(err.contains("missing sweep point"), "{err}");
        // Unknown model likewise.
        let full = run_sweep(&models, &[SweepGroup::Original], &Arch::all(), 7);
        assert!(headline(&full, &["alexnet"]).is_err());
    }

    #[test]
    fn layer_chunking_policy_bounds() {
        use crate::models::{alexnet, LayerKind};
        // Small layers never chunk; SCNN never chunks; chunk counts are
        // bounded, deterministic, and chunk ranges tile the m-tiles.
        for model in [tiny_cnn(), alexnet()] {
            for spec in model.layers.iter().filter(|l| l.kind == LayerKind::Conv) {
                for arch in Arch::all() {
                    let n = layer_chunks(arch, spec);
                    assert!((1..=MAX_LAYER_CHUNKS).contains(&n), "{} {n}", spec.name);
                    assert_eq!(n, layer_chunks(arch, spec), "deterministic");
                    if arch == Arch::Scnn || spec.num_weights() < 2 * CHUNK_MIN_WEIGHTS {
                        assert_eq!(n, 1, "{} must not chunk", spec.name);
                    }
                    let m_tiles = match arch {
                        Arch::Codr => spec.m.div_ceil(TileConfig::codr().t_m),
                        Arch::Ucnn => spec.m.div_ceil(TileConfig::ucnn().t_m),
                        Arch::Scnn => 1,
                    };
                    assert!(n <= m_tiles);
                    // Ranges partition [0, m_tiles) in order.
                    let mut prev = 0;
                    for ci in 0..n {
                        let (lo, hi) = chunk_range(m_tiles, ci, n);
                        assert_eq!(lo, prev);
                        assert!(hi >= lo);
                        prev = hi;
                    }
                    assert_eq!(prev, m_tiles);
                }
            }
        }
        // The zoo's big convs actually fan out.
        let big = alexnet();
        let widest = big
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Conv)
            .max_by_key(|l| l.num_weights())
            .unwrap();
        assert!(layer_chunks(Arch::Codr, widest) > 1, "{}", widest.name);
    }

    #[test]
    fn arch_parsing() {
        assert_eq!(Arch::parse("codr").unwrap(), Arch::Codr);
        assert_eq!(Arch::parse(" UCNN ").unwrap(), Arch::Ucnn);
        assert!(Arch::parse("tpu").is_err());
        assert_eq!(Arch::parse_list("all").unwrap().len(), 3);
        assert_eq!(
            Arch::parse_list("scnn,codr").unwrap(),
            vec![Arch::Scnn, Arch::Codr]
        );
    }
}
