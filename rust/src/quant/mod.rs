//! Fixed-point quantization (paper §II, §V-A).
//!
//! CoDR quantizes weights and biases to **8-bit fixed point** offline
//! (step (ii) of the Universal Computation Reuse pipeline). The evaluation
//! additionally sweeps:
//!
//! * **density D** — "randomly eliminating the non-zero weights";
//! * **unique-weight count U** — "making the 8 − log2(U) least significant
//!   bits of weights zero".
//!
//! Both knobs are implemented here exactly as described, plus the 16-bit
//! mode used by Fig 2's comparison.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Bit precision of the quantized weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// 8-bit fixed point (the accelerator's operating mode).
    Int8,
    /// 16-bit fixed point (Fig 2 analysis only).
    Int16,
}

impl Precision {
    pub fn bits(self) -> u32 {
        match self {
            Precision::Int8 => 8,
            Precision::Int16 => 16,
        }
    }
    pub fn max_mag(self) -> i32 {
        match self {
            Precision::Int8 => 127,
            Precision::Int16 => 32767,
        }
    }
}

/// Symmetric linear quantization of float weights to `i8`.
///
/// Returns `(quantized, scale)` with `w_float ≈ q · scale`.
pub fn quantize_weights_f32(w: &[f32], precision: Precision) -> (Vec<i16>, f32) {
    let max_abs = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if max_abs == 0.0 {
        return (vec![0; w.len()], 1.0);
    }
    let scale = max_abs / precision.max_mag() as f32;
    let q = w
        .iter()
        .map(|&x| {
            let v = (x / scale).round() as i32;
            v.clamp(-precision.max_mag(), precision.max_mag()) as i16
        })
        .collect();
    (q, scale)
}

/// The paper's **U knob**: limit the number of unique weights to `u`
/// (a power of two) by zeroing the `8 − log2(u)` least significant bits.
///
/// `u = 256` is a no-op for 8-bit weights.
pub fn limit_unique_weights(w: &mut [i8], u: u32) {
    assert!(u.is_power_of_two() && (2..=256).contains(&u), "U must be a power of two in [2,256]");
    let drop_bits = 8 - u.ilog2();
    if drop_bits == 0 {
        return;
    }
    // Arithmetic shift keeps the sign; shifting back zeroes the LSBs.
    for x in w.iter_mut() {
        *x = (*x >> drop_bits) << drop_bits;
    }
}

/// The paper's **D knob**: randomly eliminate non-zero weights until only
/// a `density` fraction of the *original non-zeros* survives.
pub fn degrade_density(w: &mut [i8], density: f64, rng: &mut Rng) {
    assert!((0.0..=1.0).contains(&density));
    let nz: Vec<usize> = (0..w.len()).filter(|&i| w[i] != 0).collect();
    let keep = (nz.len() as f64 * density).round() as usize;
    let kill = nz.len() - keep;
    if kill == 0 {
        return;
    }
    let mut order = nz;
    rng.shuffle(&mut order);
    for &i in order.iter().take(kill) {
        w[i] = 0;
    }
}

/// Fraction of non-zero entries.
pub fn density(w: &[i8]) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    w.iter().filter(|&&x| x != 0).count() as f64 / w.len() as f64
}

/// Number of distinct values among the non-zero entries.
pub fn unique_nonzero(w: &[i8]) -> usize {
    let mut seen = [false; 256];
    let mut count = 0;
    for &x in w {
        if x != 0 {
            let i = (x as i16 + 128) as usize;
            if !seen[i] {
                seen[i] = true;
                count += 1;
            }
        }
    }
    count
}

/// Apply both evaluation knobs to a weight tensor (U first, then D — the
/// order the paper's §V-A describes them; D operates on the post-U
/// non-zeros).
pub fn apply_knobs(w: &mut Tensor<i8>, unique: Option<u32>, dens: Option<f64>, rng: &mut Rng) {
    if let Some(u) = unique {
        limit_unique_weights(w.data_mut(), u);
    }
    if let Some(d) = dens {
        degrade_density(w.data_mut(), d, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn quantize_zero_and_symmetry() {
        let (q, s) = quantize_weights_f32(&[0.0, 0.5, -0.5, 1.0], Precision::Int8);
        assert_eq!(q[0], 0);
        assert_eq!(q[3], 127);
        assert_eq!(q[1], -q[2]);
        assert!((s - 1.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn quantize_all_zero_is_safe() {
        let (q, s) = quantize_weights_f32(&[0.0; 4], Precision::Int8);
        assert!(q.iter().all(|&x| x == 0));
        assert_eq!(s, 1.0);
    }

    #[test]
    fn quantize_16bit_range() {
        let (q, _) = quantize_weights_f32(&[1.0, -1.0, 0.25], Precision::Int16);
        assert_eq!(q[0], 32767);
        assert_eq!(q[1], -32767);
    }

    #[test]
    fn unique_limit_examples() {
        // U=16 → zero the 4 LSBs.
        let mut w = vec![0x11i8, 0x1F, -0x1F, 127, -128, 0];
        limit_unique_weights(&mut w, 16);
        assert_eq!(w, vec![0x10, 0x10, -0x20, 0x70, -128, 0]);
    }

    #[test]
    fn unique_256_is_identity() {
        let mut w: Vec<i8> = (-128i16..=127).map(|x| x as i8).collect();
        let orig = w.clone();
        limit_unique_weights(&mut w, 256);
        assert_eq!(w, orig);
    }

    #[test]
    fn prop_unique_limit_bounds_unique_count() {
        check(
            40,
            |r, size| {
                let n = 10 + size * 10;
                let w: Vec<i8> = (0..n).map(|_| (r.below(256) as i16 - 128) as i8).collect();
                let u = [2u32, 4, 16, 64, 256][r.index(5)];
                (w, u)
            },
            |(w, u)| {
                let mut w2 = w.clone();
                limit_unique_weights(&mut w2, *u);
                // Unique values (including zero) after masking ≤ U.
                let mut seen = std::collections::HashSet::new();
                for &x in &w2 {
                    seen.insert(x);
                }
                seen.len() <= *u as usize
            },
        );
    }

    #[test]
    fn density_knob_hits_target() {
        let mut rng = Rng::new(1);
        let mut w: Vec<i8> = (0..1000).map(|i| if i % 2 == 0 { 3 } else { 0 }).collect();
        degrade_density(&mut w, 0.5, &mut rng);
        let nz = w.iter().filter(|&&x| x != 0).count();
        assert_eq!(nz, 250);
    }

    #[test]
    fn density_one_is_identity() {
        let mut rng = Rng::new(2);
        let mut w = vec![1i8, 0, -3, 5];
        let orig = w.clone();
        degrade_density(&mut w, 1.0, &mut rng);
        assert_eq!(w, orig);
    }

    #[test]
    fn density_zero_kills_everything() {
        let mut rng = Rng::new(3);
        let mut w = vec![1i8, 2, 3, 0];
        degrade_density(&mut w, 0.0, &mut rng);
        assert!(w.iter().all(|&x| x == 0));
    }

    #[test]
    fn prop_density_never_creates_nonzeros() {
        check(
            40,
            |r, size| {
                let n = 10 + size * 5;
                let w: Vec<i8> = (0..n)
                    .map(|_| if r.chance(0.5) { (r.below(255) as i16 - 127) as i8 } else { 0 })
                    .collect();
                let d = r.f64();
                let seed = r.next_u64();
                (w, d, seed)
            },
            |(w, d, seed)| {
                let mut w2 = w.clone();
                let mut rng = Rng::new(*seed);
                degrade_density(&mut w2, *d, &mut rng);
                // Zeros stay zero; non-zeros either survive unchanged or die.
                w.iter().zip(&w2).all(|(&a, &b)| b == a || b == 0)
            },
        );
    }

    #[test]
    fn density_and_unique_helpers() {
        let w = vec![0i8, 1, 1, 2, 0, -1];
        assert!((density(&w) - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(unique_nonzero(&w), 3);
    }
}
