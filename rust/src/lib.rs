//! # CoDR — Computation and Data Reuse Aware CNN Accelerator
//!
//! Full-system reproduction of *CoDR: Computation and Data Reuse Aware CNN
//! Accelerator* (Khadem, Ye, Mudge; University of Michigan, 2021).
//!
//! The crate contains, per DESIGN.md:
//!
//! * the **Universal Computation Reuse** offline pipeline ([`reuse`]) —
//!   tiling, sorting, densification, unification, Δ computation;
//! * the **customized Run-Length Encoding** codec ([`rle`]) with
//!   per-structure, per-layer parameter search;
//! * cycle-level simulators for **CoDR** ([`codr`]) and the two baselines
//!   **SCNN** / **UCNN** ([`baselines`]);
//! * the memory-hierarchy and energy models ([`arch`], [`energy`]);
//! * the model zoo + synthetic weight synthesis ([`models`]);
//! * the sweep coordinator and report generators ([`coordinator`],
//!   [`report`]), plus the PJRT golden-model runtime (`runtime`, behind
//!   the off-by-default `pjrt` feature — the `xla` crate is absent from
//!   the offline registry);
//! * the **mapping-space search engine** ([`mapping`]): data-centric
//!   `TemporalMap`/`SpatialMap` directives, an analytical reuse engine
//!   priced through the exact dataflow walk, and a bounded Pareto-front
//!   explorer (`codr map`);
//! * the **fault-injection harness** ([`faults`]): named, seeded
//!   injection points at the durability seams (torn pack writes, memo
//!   snapshot bit-rot, worker panics, stalled connections), armed via
//!   `CODR_FAULTS`, zero-cost when unarmed;
//! * the **project-invariant static analyzer** ([`analysis`]): a
//!   dependency-free comment/string-aware lexer plus checks surfaced as
//!   `codr analyze` — lock hierarchy, atomic-ordering audit, no-panic
//!   request paths, fault-seam coverage, and the env-var registry that
//!   generates the README table;
//! * the **persistent sweep service** ([`serve`]): a content-addressed
//!   result store (multi-writer safe via advisory pack locks), an
//!   incremental grid scheduler with per-point progress observation,
//!   and the `codr serve` TCP service (streaming `watch`, draining
//!   shutdown) with `codr submit` / `codr watch` / `codr warm` clients.
//!
//! The Python side (`python/compile/`) authors the JAX + Pallas golden
//! model and AOT-lowers it to HLO text in `artifacts/`; it never runs at
//! simulation time.

pub mod analysis;
pub mod arch;
pub mod baselines;
pub mod cli;
pub mod codr;
pub mod coordinator;
pub mod energy;
pub mod faults;
pub mod mapping;
pub mod models;
pub mod quant;
pub mod report;
pub mod reuse;
pub mod rle;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod util;
