//! SCNN [1] baseline: exploits weight **sparsity** only. Weights are kept
//! in a compressed-sparse format — the raw 8-bit value plus a 4-bit count
//! of zeros since the previous non-zero (runs longer than 15 insert an
//! explicit zero weight). The dataflow is input-stationary with a
//! cartesian-product multiplier array: partial products are scattered
//! through a crossbar into accumulator banks addressed by output
//! coordinate.
//!
//! Table I configuration: `T_PU=21, T_M=2, T_N=1, T_RO=T_CO=1`, 16
//! multipliers/PU (4×4 F×I cartesian product). With only two output
//! channels of accumulator storage, the stationary inputs are re-read per
//! output-channel pair — SCNN's input traffic ends up ≈21× CoDR's on
//! GoogleNet (§V-C) and every partial product pays an accumulator-bank
//! access, which is what Fig 7/8's SCNN bars are made of.

use crate::arch::{CactiLite, MemConfig, MemoryKind, TileConfig};
use crate::models::LayerSpec;
use crate::rle::bitstream::BitWriter;
use crate::rle::CompressionStats;
use crate::sim::{Accelerator, LayerResult};
use crate::tensor::Weights;

/// Zero-run field width (4 bits → max run 15) from the SCNN paper.
pub const SCNN_RUN_BITS: u32 = 4;

#[derive(Clone, Debug)]
pub struct Scnn {
    pub cfg: TileConfig,
    pub cacti: CactiLite,
    pub mem: MemConfig,
    /// Input channels accumulated on-chip before the accumulator banks
    /// spill partials to the output SRAM (microarchitectural calibration).
    pub accum_depth: usize,
}

impl Default for Scnn {
    fn default() -> Self {
        Scnn {
            cfg: TileConfig::scnn(),
            cacti: CactiLite::default(),
            mem: MemConfig::default(),
            accum_depth: 3,
        }
    }
}

/// SCNN weight compression: `(4-bit zero run, 8-bit weight)` per non-zero;
/// zero runs longer than 15 insert an explicit zero weight. Returns the
/// encoded stream (for round-trip tests) and its stats.
pub fn compress_weights(weights: &[i8]) -> (BitWriter, CompressionStats) {
    let mut out = BitWriter::new();
    let mut run = 0u32;
    for &w in weights {
        if w == 0 {
            run += 1;
            if run > 15 {
                // Overflow: explicit zero weight with run 15.
                out.push(15, SCNN_RUN_BITS);
                out.push(0, 8);
                run = 0;
            }
        } else {
            out.push(run, SCNN_RUN_BITS);
            out.push(w as u8 as u32, 8);
            run = 0;
        }
    }
    let stats = compression_stats(weights);
    debug_assert_eq!(out.len(), stats.encoded_bits);
    (out, stats)
}

/// One pass over the raw weights counting stream entries and non-zeros —
/// the whole compression model without touching a [`BitWriter`] (each
/// entry is a fixed 12 bits).
fn scan(weights: &[i8]) -> (usize, u64) {
    let mut entries = 0usize;
    let mut nnz = 0u64;
    let mut run = 0u32;
    for &w in weights {
        if w == 0 {
            run += 1;
            if run > 15 {
                entries += 1; // explicit zero weight with run 15
                run = 0;
            }
        } else {
            entries += 1;
            nnz += 1;
            run = 0;
        }
    }
    (entries, nnz)
}

/// The fixed 12-bits-per-entry size model, shared by every stats path.
fn stats_from_entries(entries: usize, num_weights: usize) -> CompressionStats {
    CompressionStats {
        num_weights,
        encoded_bits: entries * 12,
        delta_bits: entries * 8,
        count_bits: entries * SCNN_RUN_BITS as usize,
        index_bits: 0,
        header_bits: 0,
    }
}

/// [`compress_weights`]'s stats, computed arithmetically (no emission).
pub fn compression_stats(weights: &[i8]) -> CompressionStats {
    let (entries, _) = scan(weights);
    stats_from_entries(entries, weights.len())
}

/// Decode an SCNN stream back to a dense weight vector of length `len`.
pub fn decompress_weights(stream: &BitWriter, len: usize) -> Vec<i8> {
    let mut r = stream.reader();
    let mut out = Vec::with_capacity(len);
    while r.remaining() >= (SCNN_RUN_BITS + 8) as usize && out.len() < len {
        let run = r.read(SCNN_RUN_BITS);
        let w = r.read(8) as u8 as i8;
        for _ in 0..run {
            out.push(0);
        }
        if out.len() < len {
            out.push(w);
        }
    }
    // Trailing zeros are implicit.
    out.resize(len, 0);
    out
}

/// The seed implementation — emits the stream via [`compress_weights`]
/// and recounts non-zeros. Oracle for the `invariance` tests and the
/// `codr bench` baseline.
pub fn simulate_layer_reference(design: &Scnn, spec: &LayerSpec, weights: &Weights) -> LayerResult {
    let (_, compression) = compress_weights(weights.data());
    let nnz = weights.data().iter().filter(|&&x| x != 0).count() as u64;
    layer_result(design, spec, compression, nnz)
}

impl Accelerator for Scnn {
    fn name(&self) -> &'static str {
        "SCNN"
    }

    fn tile_config(&self) -> TileConfig {
        self.cfg
    }

    /// Hot path: one allocation-free pass over the raw weights yields
    /// both the compression stats and the non-zero count. The zero-run
    /// state carries across the whole flat weight buffer, so the scan
    /// stays a single sequential chunk in the coordinator's tile-chunk
    /// fan-out (it is the cheapest of the three extraction paths by far
    /// — chunk-merging the run state would buy nothing).
    fn simulate_layer(&self, spec: &LayerSpec, weights: &Weights) -> LayerResult {
        let t0 = std::time::Instant::now();
        let (entries, nnz) = scan(weights.data());
        crate::util::bench::phases().add_extract(t0.elapsed());
        let t1 = std::time::Instant::now();
        let compression = stats_from_entries(entries, weights.data().len());
        let res = layer_result(self, spec, compression, nnz);
        crate::util::bench::phases().add_price(t1.elapsed());
        res
    }
}

/// Traffic/datapath accounting shared by the hot path and the oracle.
fn layer_result(
    design: &Scnn,
    spec: &LayerSpec,
    compression: CompressionStats,
    nnz: u64,
) -> LayerResult {
    let cfg = &design.cfg;
    let mut res = LayerResult {
        layer: spec.name.clone(),
        compression,
        ..Default::default()
    };
    let mem = &mut res.mem;
    let alu = &mut res.alu;
    alu.delta_bits = 8;
    alu.xbar_bits = 16;

    let out_positions = (spec.r_o() * spec.r_o()) as u64;
    let passes = spec.m.div_ceil(cfg.t_m) as u64; // output-channel pairs

    // --- Weights stream once over the layer (multicast to all PUs):
    // each (run, weight) entry is one 12-bit access.
    let entries = res.compression.encoded_bits as u64 / 12;
    mem.record(MemoryKind::WeightSram, entries, 12);
    mem.record(MemoryKind::WeightRf, entries, 12);

    // --- Inputs: stationary across one pass, re-read per pass. The
    // 21 PUs tile the feature map spatially with only a 1×1 local
    // tile, so each pass also pays the inter-PU halo exchange and
    // multicast overhead (§V-C puts SCNN's input traffic at ≈21× CoDR).
    const HALO_MULTICAST: f64 = 1.6;
    let input_reads = (spec.input_features() as f64 * passes as f64 * HALO_MULTICAST) as u64;
    mem.record(MemoryKind::InputSram, input_reads, 8);
    mem.record(MemoryKind::InputRf, input_reads, 8);

    // --- Cartesian product: every non-zero weight multiplies every
    // output position it overlaps (dense activations).
    let mults = nnz * out_positions;
    alu.mults_full += mults;
    alu.adds += mults;
    mem.record(MemoryKind::InputRf, mults, 8); // F operand reads
    // Every partial product crosses the scatter crossbar and pays a
    // read-modify-write on its accumulator bank.
    alu.xbar_transfers += mults;
    mem.record(MemoryKind::OutputRf, 2 * mults, 24);

    // --- Accumulator banks spill to output SRAM every `accum_depth`
    // input channels (read-modify-write), and the final pass writes.
    let spills = (spec.n as u64).div_ceil(design.accum_depth as u64);
    mem.record(
        MemoryKind::OutputSram,
        2 * spec.output_features() as u64 * spills,
        16,
    );

    // --- DRAM once.
    mem.record(MemoryKind::Dram, 1, res.compression.encoded_bits as u64);
    mem.record(MemoryKind::Dram, 1, spec.input_features() as u64 * 8);
    mem.record(MemoryKind::Dram, 1, spec.output_features() as u64 * 8);

    // --- Cycles: multiplies spread over the PU array, plus crossbar
    // serialization when partials collide on a bank (model: 1.2×).
    let lanes = (cfg.t_pu * cfg.mults_per_pu) as u64;
    res.cycles = mults * 12 / (lanes * 10) + 1;

    res.finish(&design.cacti, &design.mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{synthesize_weights, LayerKind};
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn spec(n: usize, m: usize, r_i: usize, r_k: usize, zero_frac: f64) -> LayerSpec {
        LayerSpec {
            name: "s".into(),
            kind: LayerKind::Conv,
            n,
            m,
            r_i,
            r_k,
            stride: 1,
            pad: 1,
            groups: 1,
            sigma_q: 12.0,
            zero_frac,
        }
    }

    #[test]
    fn compress_hand_example() {
        // [0,0,5,0,0,0,-1] → (run 2, 5), (run 3, -1) = 24 bits.
        let (s, st) = compress_weights(&[0, 0, 5, 0, 0, 0, -1]);
        assert_eq!(st.encoded_bits, 24);
        assert_eq!(decompress_weights(&s, 7), vec![0, 0, 5, 0, 0, 0, -1]);
    }

    #[test]
    fn long_zero_run_overflows() {
        let mut v = vec![0i8; 20];
        v.push(9);
        let (s, st) = compress_weights(&v);
        // One explicit zero entry (run 15) + the real entry (run 4).
        assert_eq!(st.encoded_bits, 2 * 12);
        assert_eq!(decompress_weights(&s, 21), v);
    }

    #[test]
    fn trailing_zeros_cost_nothing() {
        let (_, st) = compress_weights(&[1, 0, 0, 0, 0, 0]);
        assert_eq!(st.encoded_bits, 12);
    }

    #[test]
    fn prop_scnn_roundtrip() {
        check(
            80,
            |r, size| {
                (0..1 + size * 4)
                    .map(|_| {
                        if r.chance(0.8) {
                            0
                        } else {
                            (r.below(255) as i16 - 127) as i8
                        }
                    })
                    .collect::<Vec<i8>>()
            },
            |v| {
                let (s, _) = compress_weights(v);
                decompress_weights(&s, v.len()) == *v
            },
        );
    }

    #[test]
    fn arithmetic_stats_match_emitted_stream() {
        let s = spec(16, 16, 14, 3, 0.6);
        let mut rng = Rng::new(12);
        let w = synthesize_weights(&s, &mut rng);
        let (_, emitted) = compress_weights(w.data());
        assert_eq!(compression_stats(w.data()), emitted);
    }

    #[test]
    fn hot_path_equals_reference_bit_for_bit() {
        let s = spec(11, 13, 14, 3, 0.7);
        let mut rng = Rng::new(13);
        let w = synthesize_weights(&s, &mut rng);
        let design = Scnn::default();
        assert_eq!(
            design.simulate_layer(&s, &w),
            simulate_layer_reference(&design, &s, &w)
        );
    }

    #[test]
    fn scnn_does_not_exploit_repetition() {
        // Limiting unique weights must NOT change SCNN's multiply count
        // (it has no unification) — only sparsity does.
        let s = spec(16, 16, 14, 3, 0.5);
        let mut rng = Rng::new(1);
        let w = synthesize_weights(&s, &mut rng);
        let mut w_lim = w.clone();
        crate::quant::limit_unique_weights(w_lim.data_mut(), 8);
        let scnn = Scnn::default();
        let r = scnn.simulate_layer(&s, &w);
        let r_lim = scnn.simulate_layer(&s, &w_lim);
        // U-limiting may create new zeros (values that round to 0), so
        // allow mults to *drop* only from that effect.
        let nnz = w.data().iter().filter(|&&x| x != 0).count();
        let nnz_lim = w_lim.data().iter().filter(|&&x| x != 0).count();
        assert_eq!(
            r.alu.mults() as f64 / nnz as f64,
            r_lim.alu.mults() as f64 / nnz_lim as f64
        );
    }

    #[test]
    fn sparsity_cuts_mults_proportionally() {
        let dense = spec(16, 16, 14, 3, 0.1);
        let sparse = spec(16, 16, 14, 3, 0.9);
        let mut rng = Rng::new(2);
        let wd = synthesize_weights(&dense, &mut rng);
        let ws = synthesize_weights(&sparse, &mut rng);
        let scnn = Scnn::default();
        assert!(scnn.simulate_layer(&sparse, &ws).alu.mults() * 4
            < scnn.simulate_layer(&dense, &wd).alu.mults());
    }

    #[test]
    fn compression_is_12_bits_per_nnz_plus_overflows() {
        let s = spec(16, 16, 14, 3, 0.6);
        let mut rng = Rng::new(3);
        let w = synthesize_weights(&s, &mut rng);
        let (_, st) = compress_weights(w.data());
        let nnz = w.data().iter().filter(|&&x| x != 0).count();
        assert!(st.encoded_bits >= nnz * 12);
        assert!(st.encoded_bits < nnz * 12 + w.data().len());
    }

    #[test]
    fn outputs_pay_per_partial_product() {
        let s = spec(8, 8, 10, 3, 0.5);
        let mut rng = Rng::new(4);
        let w = synthesize_weights(&s, &mut rng);
        let nnz = w.data().iter().filter(|&&x| x != 0).count() as u64;
        let r = Scnn::default().simulate_layer(&s, &w);
        assert_eq!(r.mem.output_rf.accesses, 2 * nnz * (s.r_o() as u64).pow(2));
    }
}
