//! UCNN [5] baseline: exploits weight **repetition** by factorizing equal
//! weights of a dot product into *activation groups* — sum the inputs of a
//! group first, multiply by the unique weight once. Also skips zero
//! weights (eliminating their activation groups).
//!
//! Encoding (paper §V-B): RLE over unique-weight Δs and indexes with a
//! **fixed bit-length of 5 for all layers** (no per-layer search), **no
//! repetition-count stream** — instead **1 extra bit per index** marks the
//! transition to the next unique weight.
//!
//! Dataflow (Table I: `T_PU=48, T_M=1, T_N=4, T_RO×T_CO=1×8, T_CI=12`):
//! each PU computes one output channel over an 8-wide output strip with a
//! 12-entry input buffer. Outputs are *not* stationary across input
//! channels: each output is read-modified-written once per input-channel
//! tile (the paper measures 72.1 accesses per output feature on
//! GoogleNet), and inputs are re-fetched per output channel (20.4× CoDR's
//! input traffic), with only ~1.4% of SRAM bandwidth spent on weights.

use crate::arch::{CactiLite, MemConfig, MemoryKind, TileConfig};
use crate::models::LayerSpec;
use crate::reuse::memo::{self, Fp128};
use crate::reuse::UcrVector;
use crate::rle::bitstream::BitWriter;
use crate::rle::{CoderSpec, CompressionStats, VectorSizeStats};
use crate::sim::{Accelerator, LayerResult};
use crate::tensor::Weights;
use crate::util::bench;
use std::time::Instant;

/// Fixed RLE bit-length UCNN uses for weights and indexes (§V-B).
pub const UCNN_RLE_BITS: u32 = 5;

#[derive(Clone, Debug)]
pub struct Ucnn {
    pub cfg: TileConfig,
    pub cacti: CactiLite,
    pub mem: MemConfig,
}

impl Default for Ucnn {
    fn default() -> Self {
        Ucnn {
            cfg: TileConfig::ucnn(),
            cacti: CactiLite::default(),
            mem: MemConfig::default(),
        }
    }
}

/// UCNN's per-input-channel-tile weight vector: the paper configures
/// `T_M = 1, T_N = 4`, and UCNN's dot-product factorization spans the
/// input-channel dimension, so the unit of unification is the
/// concatenation of one kernel across the tile's `T_N` input channels.
/// Built with a reusable scratch buffer — no intermediate tile copies.
pub fn ucnn_vectors(spec: &LayerSpec, weights: &Weights, cfg: &TileConfig) -> Vec<UcrVector> {
    let kernel = spec.r_k * spec.r_k;
    let data = weights.data();
    let mut out = Vec::new();
    let mut scratch: Vec<i8> = Vec::with_capacity(cfg.t_m * cfg.t_n * kernel);
    for m0 in (0..spec.m).step_by(cfg.t_m) {
        let tm = cfg.t_m.min(spec.m - m0);
        for n0 in (0..spec.n).step_by(cfg.t_n) {
            let tn = cfg.t_n.min(spec.n - n0);
            scratch.clear();
            for n in n0..n0 + tn {
                for m in m0..m0 + tm {
                    // Kernel elements are contiguous in [M,N,Kr,Kc].
                    let off = (m * spec.n + n) * kernel;
                    scratch.extend_from_slice(&data[off..off + kernel]);
                }
            }
            out.push(UcrVector::from_weights(&scratch));
        }
    }
    out
}

/// Encode one UCNN vector; returns (delta_bits, index_bits) appended.
fn encode_vector(u: &UcrVector, spec: CoderSpec, deltas: &mut BitWriter, indexes: &mut BitWriter) {
    let k = UCNN_RLE_BITS;
    let ds = u.deltas();
    for (i, &d) in ds.iter().enumerate() {
        if i == 0 {
            deltas.push_bit(false);
            deltas.push(u.uniques[0] as u8 as u32, 8);
        } else if (d as u32) < (1 << k) {
            deltas.push_bit(true);
            deltas.push(d as u32, k);
        } else {
            deltas.push_bit(false);
            deltas.push(d as u32, 8);
        }
    }
    // Indexes: Δ-coded at fixed j=5 with the same mode flag, PLUS the
    // 1-bit group-transition indicator UCNN appends to every index.
    let mut prev: i64 = -1;
    let mut first = true;
    for group in u.index_groups() {
        for (ii, &idx) in group.iter().enumerate() {
            let last_of_group = ii + 1 == group.len();
            let d = idx as i64 - prev;
            if !first && d > 0 && d <= (1 << UCNN_RLE_BITS) {
                indexes.push_bit(true);
                indexes.push((d - 1) as u32, UCNN_RLE_BITS);
            } else {
                indexes.push_bit(false);
                indexes.push(idx as u32, spec.abs_bits());
            }
            indexes.push_bit(last_of_group); // transition indicator
            prev = idx as i64;
            first = false;
        }
    }
}

/// Compress a layer UCNN-style; returns stats (per-vector headers carry
/// the unique count, same as CoDR's, so the decoder knows group counts).
pub fn compress_layer(spec: &LayerSpec, weights: &Weights, cfg: &TileConfig) -> CompressionStats {
    let vectors = ucnn_vectors(spec, weights, cfg);
    compress_vectors(spec, &vectors, cfg)
}

/// [`compress_layer`] over pre-built vectors (the simulator reuses the
/// same vectors for datapath accounting — building them twice doubled the
/// UCNN simulation cost, §Perf).
pub fn compress_vectors(
    spec: &LayerSpec,
    vectors: &[UcrVector],
    cfg: &TileConfig,
) -> CompressionStats {
    let coder = CoderSpec::new(cfg.t_m * cfg.t_n * spec.r_k * spec.r_k);
    let mut deltas = BitWriter::new();
    let mut indexes = BitWriter::new();
    let mut header = 0usize;
    for u in vectors {
        encode_vector(u, coder, &mut deltas, &mut indexes);
        header += coder.len_bits() as usize;
    }
    CompressionStats {
        num_weights: spec.num_weights(),
        encoded_bits: deltas.len() + indexes.len() + header,
        delta_bits: deltas.len(),
        count_bits: 0,
        index_bits: indexes.len(),
        header_bits: header,
    }
}

/// Per-vector encoded size (Δ-stream bits, index-stream bits) computed
/// arithmetically from the cached [`VectorSizeStats`] — bit-identical to
/// what [`encode_vector`] emits (asserted by the
/// `arithmetic_sizes_match_emitted_streams` test), so the hot path never
/// touches a [`BitWriter`].
pub fn vector_stream_bits(s: &VectorSizeStats, n_uniques: usize, spec: CoderSpec) -> (u64, u64) {
    let k = UCNN_RLE_BITS as u64;
    let mut delta_bits = 0u64;
    if n_uniques > 0 {
        delta_bits += 1 + 8; // absolute vector-first weight
    }
    for &d in &s.deltas {
        delta_bits += if (d as u64) < (1 << k) { 1 + k } else { 1 + 8 };
    }
    let abs = spec.abs_bits() as u64;
    let mut n_abs = s.n_idx_abs;
    let mut index_bits = 0u64;
    for &(d, n) in &s.idx_deltas {
        if (d as u64) <= (1 << k) {
            index_bits += n as u64 * (1 + k);
        } else {
            n_abs += n as u64;
        }
    }
    index_bits += n_abs * (1 + abs);
    index_bits += s.n_indexes; // 1-bit group-transition indicator per index
    (delta_bits, index_bits)
}

/// The datapath/traffic accounting shared by the memoized hot path and
/// the reference oracle — everything after the per-vector totals
/// (`total_uniques`, `total_nnz`) and compression stats are known.
fn layer_result(
    design: &Ucnn,
    spec: &LayerSpec,
    compression: CompressionStats,
    total_uniques: u64,
    total_nnz: u64,
) -> LayerResult {
    let cfg = &design.cfg;
    let mut res = LayerResult {
        layer: spec.name.clone(),
        compression,
        ..Default::default()
    };
    let r_o = spec.r_o() as u64;
    let c_o = spec.r_o() as u64;
    let n_tiles_n = spec.n.div_ceil(cfg.t_n) as u64;
    let strips = r_o * c_o.div_ceil(cfg.t_co as u64); // 1×8 output strips
    let mem = &mut res.mem;
    let alu = &mut res.alu;
    alu.delta_bits = 8; // UCNN multiplies full-precision weights
    alu.xbar_bits = 16;

    // --- Weight traffic: the compressed stream is re-read once per
    // output row (strip row) — weight reuse across the row's strips.
    // Accesses counted per decoded element (unique Δs + indexes),
    // energy word-amortized over the stream bits, same convention as
    // CoDR so Fig 7 compares like with like.
    let elements = total_uniques + total_nnz;
    let weight_bits = res.compression.encoded_bits as u64 * r_o;
    mem.record(MemoryKind::WeightSram, elements * r_o, 0);
    mem.counter_mut(MemoryKind::WeightSram).bits += weight_bits;
    mem.record(
        MemoryKind::WeightRf,
        weight_bits.div_ceil(design.mem.sram_word_bits as u64),
        design.mem.sram_word_bits as u64,
    );

    // --- Input traffic: for every (output channel, strip, n-tile) the
    // 12-entry line buffer is filled with the strip's input columns;
    // a row is fetched once per strip (the line buffer feeds all R_K
    // kernel rows) and vertically adjacent strips retain the shared
    // (C_K−1)-column overlap (VERTICAL_REUSE, calibrated so UCNN's
    // input traffic lands at the paper's ≈20.4× CoDR on GoogleNet).
    // Nothing is reused across output channels (T_M = 1).
    const VERTICAL_REUSE: f64 = 1.56;
    let cols_needed = ((cfg.t_co - 1) * spec.stride + spec.r_k) as u64;
    let input_reads_per_strip = cfg.t_n as u64 * cols_needed;
    let input_reads = (spec.m as u64 * strips * n_tiles_n * input_reads_per_strip) as f64
        / cfg.t_m as f64
        / VERTICAL_REUSE;
    let input_reads = input_reads as u64;
    mem.record(MemoryKind::InputSram, input_reads, 8);
    mem.record(MemoryKind::InputRf, input_reads, 8); // buffer fills

    // --- Output traffic: partial sums are read-modified-written per
    // input-channel tile (not output stationary).
    let out_accesses = 2 * spec.output_features() as u64 * n_tiles_n;
    mem.record(MemoryKind::OutputSram, out_accesses, 16);

    // --- DRAM: compressed weights + features once.
    mem.record(MemoryKind::Dram, 1, res.compression.encoded_bits as u64);
    mem.record(MemoryKind::Dram, 1, spec.input_features() as u64 * 8);
    mem.record(MemoryKind::Dram, 1, spec.output_features() as u64 * 8);

    // --- Datapath: per output position and vector, gather-sum each
    // activation group (adds = nnz) then multiply once per unique.
    // Vectors span all (m-tile, n-tile) pairs; each runs once per output
    // position of its channel.
    let positions = r_o * c_o;
    let per_pos_mults = total_uniques;
    let per_pos_adds = total_nnz + total_uniques;
    alu.mults_full += per_pos_mults * positions;
    alu.adds += per_pos_adds * positions;
    // Input buffer read per gathered activation.
    mem.record(MemoryKind::InputRf, total_nnz * positions, 8);
    // Output mux/small crossbar per multiply result.
    alu.xbar_transfers += per_pos_mults * positions;

    // --- Cycles: total gather+multiply work spread over T_PU PUs with
    // `mults_per_pu` parallel lanes.
    let work = (per_pos_mults + per_pos_adds) * positions;
    res.cycles = work / (cfg.t_pu as u64 * cfg.mults_per_pu as u64).max(1) + 1;

    res.finish(&design.cacti, &design.mem)
}

/// The seed implementation — builds every vector afresh and emits the
/// real bitstreams. Oracle for the `invariance` tests and the
/// `codr bench` baseline.
pub fn simulate_layer_reference(design: &Ucnn, spec: &LayerSpec, weights: &Weights) -> LayerResult {
    let vectors = ucnn_vectors(spec, weights, &design.cfg);
    let compression = compress_vectors(spec, &vectors, &design.cfg);
    let mut total_uniques = 0u64;
    let mut total_nnz = 0u64;
    for u in &vectors {
        total_uniques += u.uniques.len() as u64;
        total_nnz += u.nnz() as u64;
    }
    layer_result(design, spec, compression, total_uniques, total_nnz)
}

/// One tile-chunk's extraction totals: every field is a plain sum, so
/// chunks merge by addition in any order and reproduce the sequential
/// walk exactly (pinned by `chunked_extraction_equals_whole_layer`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UcnnExtract {
    pub delta_bits: u64,
    pub index_bits: u64,
    pub n_vectors: usize,
    pub total_uniques: u64,
    pub total_nnz: u64,
}

/// Extract the m-tile range `[mt0, mt1)` (m-tile step `T_M`): linearize
/// each `(m-tile, n-tile)` vector, fingerprint it once at extraction,
/// resolve it through the two-level memo, and price its streams
/// arithmetically from the cached per-vector summary — no `BitWriter`,
/// no per-vector allocation.
pub fn extract_chunk(
    design: &Ucnn,
    spec: &LayerSpec,
    weights: &Weights,
    mt0: usize,
    mt1: usize,
) -> UcnnExtract {
    let t0 = Instant::now();
    let cfg = &design.cfg;
    let kernel = spec.r_k * spec.r_k;
    let coder = CoderSpec::new(cfg.t_m * cfg.t_n * kernel);
    let cache = memo::global();
    let data = weights.data();
    let mut scratch: Vec<i8> = Vec::with_capacity(cfg.t_m * cfg.t_n * kernel);
    let mut acc = UcnnExtract::default();
    for mt in mt0..mt1 {
        let m0 = mt * cfg.t_m;
        let tm = cfg.t_m.min(spec.m - m0);
        for n0 in (0..spec.n).step_by(cfg.t_n) {
            let tn = cfg.t_n.min(spec.n - n0);
            scratch.clear();
            // Same linearization as ucnn_vectors: T_N input channels'
            // kernels concatenated, inner loop over output channels.
            for n in n0..n0 + tn {
                for m in m0..m0 + tm {
                    let off = (m * spec.n + n) * kernel;
                    scratch.extend_from_slice(&data[off..off + kernel]);
                }
            }
            let fp = Fp128::of_i8(&scratch);
            let entry = cache.get_or_insert_keyed(fp, &scratch);
            let (db, ib) = vector_stream_bits(&entry.size, entry.ucr.uniques.len(), coder);
            acc.delta_bits += db;
            acc.index_bits += ib;
            acc.n_vectors += 1;
            acc.total_uniques += entry.ucr.uniques.len() as u64;
            acc.total_nnz += entry.ucr.nnz() as u64;
        }
    }
    bench::phases().add_extract(t0.elapsed());
    acc
}

/// The pricing back half: sum the chunks' totals and run the shared
/// traffic/datapath accounting.
pub fn price_extracted(design: &Ucnn, spec: &LayerSpec, chunks: &[UcnnExtract]) -> LayerResult {
    let t0 = Instant::now();
    let coder = CoderSpec::new(design.cfg.t_m * design.cfg.t_n * spec.r_k * spec.r_k);
    let mut total = UcnnExtract::default();
    for c in chunks {
        total.delta_bits += c.delta_bits;
        total.index_bits += c.index_bits;
        total.n_vectors += c.n_vectors;
        total.total_uniques += c.total_uniques;
        total.total_nnz += c.total_nnz;
    }
    let header_bits = total.n_vectors * coder.len_bits() as usize;
    let compression = CompressionStats {
        num_weights: spec.num_weights(),
        encoded_bits: total.delta_bits as usize + total.index_bits as usize + header_bits,
        delta_bits: total.delta_bits as usize,
        count_bits: 0,
        index_bits: total.index_bits as usize,
        header_bits,
    };
    let res = layer_result(design, spec, compression, total.total_uniques, total.total_nnz);
    bench::phases().add_price(t0.elapsed());
    res
}

impl Accelerator for Ucnn {
    fn name(&self) -> &'static str {
        "UCNN"
    }

    fn tile_config(&self) -> TileConfig {
        self.cfg
    }

    /// Memoized hot path: one full-range [`extract_chunk`] +
    /// [`price_extracted`]. The coordinator splits big layers into
    /// several chunks over the pool instead.
    fn simulate_layer(&self, spec: &LayerSpec, weights: &Weights) -> LayerResult {
        let m_tiles = spec.m.div_ceil(self.cfg.t_m);
        let chunk = extract_chunk(self, spec, weights, 0, m_tiles);
        price_extracted(self, spec, &[chunk])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{synthesize_weights, LayerKind};
    use crate::util::rng::Rng;

    fn spec(n: usize, m: usize, r_i: usize, r_k: usize, zero_frac: f64) -> LayerSpec {
        LayerSpec {
            name: "u".into(),
            kind: LayerKind::Conv,
            n,
            m,
            r_i,
            r_k,
            stride: 1,
            pad: 1,
            groups: 1,
            sigma_q: 12.0,
            zero_frac,
        }
    }

    #[test]
    fn vectors_cover_all_weights() {
        let s = spec(8, 6, 10, 3, 0.4);
        let mut rng = Rng::new(1);
        let w = synthesize_weights(&s, &mut rng);
        let vs = ucnn_vectors(&s, &w, &TileConfig::ucnn());
        let nnz: usize = vs.iter().map(|v| v.nnz()).sum();
        let expect = w.data().iter().filter(|&&x| x != 0).count();
        assert_eq!(nnz, expect);
        // M=6 m-tiles × ceil(8/4)=2 n-tiles.
        assert_eq!(vs.len(), 12);
    }

    #[test]
    fn compression_worse_than_codr_customized() {
        // §V-B: CoDR compresses 1.69× more than UCNN thanks to the
        // per-layer parameter search and count-based group encoding.
        let s = spec(32, 32, 14, 3, 0.55);
        let mut rng = Rng::new(2);
        let w = synthesize_weights(&s, &mut rng);
        let ucnn = compress_layer(&s, &w, &TileConfig::ucnn());
        let codr_cfg = TileConfig::codr();
        let tiled = crate::reuse::transform_layer(&s, &w, codr_cfg.t_n, codr_cfg.t_m);
        let vs: Vec<UcrVector> = tiled.iter().flat_map(|(_, v)| v.iter().cloned()).collect();
        let enc = crate::rle::encode_layer(&vs, CoderSpec::new(codr_cfg.t_m * 9));
        let codr = enc.stats(s.num_weights());
        assert!(
            codr.bits_per_weight() < ucnn.bits_per_weight(),
            "codr {} vs ucnn {}",
            codr.bits_per_weight(),
            ucnn.bits_per_weight()
        );
    }

    #[test]
    fn outputs_not_stationary() {
        let s = spec(64, 16, 14, 3, 0.5);
        let mut rng = Rng::new(3);
        let w = synthesize_weights(&s, &mut rng);
        let r = Ucnn::default().simulate_layer(&s, &w);
        // 2 accesses × N/T_N = 2×16 = 32 accesses per output feature.
        let per_output = r.mem.output_sram.accesses as f64 / s.output_features() as f64;
        assert!((per_output - 32.0).abs() < 1e-9, "per_output {per_output}");
    }

    #[test]
    fn weight_bw_fraction_is_small() {
        // §V-C: UCNN spends ~1.4% of SRAM bandwidth on weights.
        let s = spec(192, 64, 28, 3, 0.5);
        let mut rng = Rng::new(4);
        let w = synthesize_weights(&s, &mut rng);
        let r = Ucnn::default().simulate_layer(&s, &w);
        let f = r.mem.weight_bw_fraction();
        assert!(f < 0.15, "weight bw fraction {f}");
    }

    #[test]
    fn repetition_reduces_mults() {
        let s = spec(16, 16, 14, 3, 0.4);
        let mut rng = Rng::new(5);
        let w = synthesize_weights(&s, &mut rng);
        let mut w_lim = w.clone();
        crate::quant::limit_unique_weights(w_lim.data_mut(), 8);
        let u = Ucnn::default();
        assert!(u.simulate_layer(&s, &w_lim).alu.mults() < u.simulate_layer(&s, &w).alu.mults());
    }

    #[test]
    fn arithmetic_sizes_match_emitted_streams() {
        // The memo fast path prices every vector without a BitWriter;
        // per-vector arithmetic must equal emission bit for bit.
        let s = spec(13, 11, 12, 3, 0.5);
        let mut rng = Rng::new(7);
        let w = synthesize_weights(&s, &mut rng);
        let cfg = TileConfig::ucnn();
        let vectors = ucnn_vectors(&s, &w, &cfg);
        let coder = CoderSpec::new(cfg.t_m * cfg.t_n * s.r_k * s.r_k);
        let emitted = compress_vectors(&s, &vectors, &cfg);
        let mut delta_bits = 0u64;
        let mut index_bits = 0u64;
        for u in &vectors {
            let (db, ib) = vector_stream_bits(
                &crate::rle::VectorSizeStats::collect(u),
                u.uniques.len(),
                coder,
            );
            delta_bits += db;
            index_bits += ib;
        }
        assert_eq!(delta_bits as usize, emitted.delta_bits);
        assert_eq!(index_bits as usize, emitted.index_bits);
    }

    #[test]
    fn memoized_path_equals_reference_bit_for_bit() {
        for (s, seed) in [
            (spec(13, 11, 12, 3, 0.5), 8u64), // clipped edge tiles
            (spec(8, 6, 10, 3, 0.4), 9),
            (spec(3, 8, 23, 11, 0.6), 10), // big kernel
        ] {
            let mut rng = Rng::new(seed);
            let w = synthesize_weights(&s, &mut rng);
            let design = Ucnn::default();
            let oracle = simulate_layer_reference(&design, &s, &w);
            assert_eq!(design.simulate_layer(&s, &w), oracle, "seed {seed}");
            assert_eq!(design.simulate_layer(&s, &w), oracle, "warm, seed {seed}");
        }
    }

    #[test]
    fn chunked_extraction_equals_whole_layer() {
        // Any m-tile split must price to the identical LayerResult.
        let s = spec(13, 11, 12, 3, 0.5); // M=11: clipped range math
        let mut rng = Rng::new(23);
        let w = synthesize_weights(&s, &mut rng);
        let design = Ucnn::default();
        let whole = design.simulate_layer(&s, &w);
        let m_tiles = s.m.div_ceil(design.cfg.t_m);
        for n_chunks in [1usize, 2, 4, m_tiles] {
            let chunks: Vec<UcnnExtract> = (0..n_chunks)
                .map(|ci| {
                    extract_chunk(
                        &design,
                        &s,
                        &w,
                        m_tiles * ci / n_chunks,
                        m_tiles * (ci + 1) / n_chunks,
                    )
                })
                .collect();
            assert_eq!(price_extracted(&design, &s, &chunks), whole, "split {n_chunks}");
        }
    }

    #[test]
    fn mults_bounded_by_unique_count_times_positions() {
        let s = spec(8, 8, 10, 3, 0.5);
        let mut rng = Rng::new(6);
        let w = synthesize_weights(&s, &mut rng);
        let vs = ucnn_vectors(&s, &w, &TileConfig::ucnn());
        let uniques: u64 = vs.iter().map(|v| v.num_multiplies() as u64).sum();
        let r = Ucnn::default().simulate_layer(&s, &w);
        assert_eq!(r.alu.mults_full, uniques * (s.r_o() as u64).pow(2));
    }
}
