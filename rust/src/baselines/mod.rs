//! Baseline accelerator models: **SCNN** [1] (weight sparsity via
//! compressed-sparse weights, input-stationary cartesian-product dataflow)
//! and **UCNN** [5] (weight repetition via activation-group factorization,
//! fixed-parameter RLE). Both are configured per paper Table I at the same
//! 2.85 mm² area as CoDR and evaluated with identical memory/energy
//! models, so Figs 6–8 compare dataflows, not technology assumptions.

pub mod scnn;
pub mod ucnn;

pub use scnn::Scnn;
pub use ucnn::Ucnn;
