//! Customized Run-Length Encoding (paper §III-C, Fig 4).
//!
//! CoDR stores three data structures per layer, each with its own
//! per-layer-optimal encoding parameter found by exhaustive search:
//!
//! * **Unique-weight Δs** — the first unique of each vector is stored
//!   absolute (8 bits); subsequent Δs carry a 1-bit *precision flag*:
//!   `1` + `k` bits when `Δ < 2^k` (low precision), `0` + 8 bits otherwise.
//! * **Repetition counts** — fixed `r`-bit numbers storing `count−1`.
//!   A count overflowing `2^r` is split: the encoder inserts a **dummy
//!   unique weight with Δ=0** carrying the remainder (Δ=0 cannot occur
//!   between real distinct uniques, so the decoder merges dummies back
//!   unambiguously).
//! * **Indexes** — Δ-coded against the previous index with a 1-bit
//!   *mode flag*: `1` + `j` bits storing `Δ−1` when `0 < Δ ≤ 2^j`;
//!   absolute (`0` + `ceil(log2 L)` bits) when the Δ is negative, zero is
//!   impossible, it does not fit, or the index is the vector's first.
//!
//! The parameter search evaluates sizes from histograms collected in one
//! pass (O(1) per candidate parameter), then a second pass emits the
//! actual bitstreams. `encoded ⇄ decoded` round-trips are property-tested
//! and the histogram size-model is asserted equal to the emitted size.

pub mod bitstream;
mod coder;

pub use coder::{
    decode_layer, decode_vector, encode_layer, encode_layer_refs, encode_vector, CoderSpec,
    EncodedLayer,
    LayerHistograms, RleParams, VectorSizeStats, PARAM_HEADER_BITS,
};

/// Compression summary for one encoded layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompressionStats {
    /// Weights in the raw layer (including zeros).
    pub num_weights: usize,
    /// Total encoded bits (streams + per-vector headers + parameter header).
    pub encoded_bits: usize,
    /// Bits of the delta / count / index streams individually.
    pub delta_bits: usize,
    pub count_bits: usize,
    pub index_bits: usize,
    /// Per-vector length headers.
    pub header_bits: usize,
}

impl CompressionStats {
    /// Average encoded bits per weight (the paper's ≈1.69 b/w for CoDR).
    pub fn bits_per_weight(&self) -> f64 {
        if self.num_weights == 0 {
            0.0
        } else {
            self.encoded_bits as f64 / self.num_weights as f64
        }
    }

    /// Compression rate versus dense 8-bit storage.
    pub fn rate(&self) -> f64 {
        if self.encoded_bits == 0 {
            0.0
        } else {
            (self.num_weights * 8) as f64 / self.encoded_bits as f64
        }
    }

    pub fn add(&mut self, o: &CompressionStats) {
        self.num_weights += o.num_weights;
        self.encoded_bits += o.encoded_bits;
        self.delta_bits += o.delta_bits;
        self.count_bits += o.count_bits;
        self.index_bits += o.index_bits;
        self.header_bits += o.header_bits;
    }
}
