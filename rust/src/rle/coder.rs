//! The CoDR RLE coder: histogram collection, parameter search, encode,
//! decode. See module docs in [`super`] for the exact bit formats.

use super::bitstream::{BitReader, BitWriter};
use super::CompressionStats;
use crate::reuse::UcrVector;

/// `ceil(log2(n))` — width needed to store values in `[0, n)`.
#[inline]
pub(crate) fn bits_for(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

/// Fixed geometry of the vectors being coded (identical for every vector
/// of a layer once the tiling parameters are chosen).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoderSpec {
    /// Linearized vector length `L = T_M · R_K · C_K`.
    pub vec_len: usize,
}

impl CoderSpec {
    pub fn new(vec_len: usize) -> Self {
        assert!(vec_len >= 1);
        CoderSpec { vec_len }
    }

    /// Absolute-index width: `ceil(log2 L)`.
    pub fn abs_bits(&self) -> u32 {
        bits_for(self.vec_len)
    }

    /// Per-vector entry-count header width: `ceil(log2 (L+1))` (the entry
    /// count including dummies never exceeds the non-zero count ≤ L).
    pub fn len_bits(&self) -> u32 {
        bits_for(self.vec_len + 1)
    }
}

/// The per-layer encoding parameters chosen by the search (paper: "RLE
/// Encoder iterates on the encoding parameter of each data structure").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RleParams {
    /// Low-precision Δ width `k` (weights).
    pub delta_bits: u32,
    /// Fixed repetition-count width `r`.
    pub count_bits: u32,
    /// Low-precision index-Δ width `j`.
    pub index_bits: u32,
    /// Per-vector entry-count header width `h`: counts in
    /// `[0, 2^h − 2]` are stored directly; the all-ones escape code is
    /// followed by a full `len_bits` value. Searched like the other
    /// structures — sparse layers pick a tiny `h` because most vectors
    /// hold only a few uniques.
    pub header_bits: u32,
}

/// Bits of the per-layer parameter header written to DRAM alongside the
/// streams (three 4-bit parameters + 16-bit vector-geometry tag, rounded
/// up to a byte multiple).
pub const PARAM_HEADER_BITS: usize = 32;

// ---------------------------------------------------------------------------
// Histograms + size model
// ---------------------------------------------------------------------------

/// One-pass histograms from which the encoded size under any candidate
/// parameter set is computed in O(1).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerHistograms {
    spec: CoderSpec,
    pub n_vectors: usize,
    /// Vectors with at least one unique weight (each emits one absolute
    /// first entry).
    pub n_nonempty: usize,
    /// Real unique entries, including each vector's first.
    pub n_uniques: usize,
    /// Δ values of non-first entries (0..=254 after sort, always ≥ 1 for
    /// real entries; kept full-width for safety).
    pub delta_hist: [u64; 256],
    /// Repetition counts, indexed by count (1..=L).
    pub count_hist: Vec<u64>,
    /// Positive index Δs (`idx − prev`), indexed by Δ (1..=L−1).
    pub idx_delta_hist: Vec<u64>,
    /// Indexes forced to absolute mode (vector-first or non-positive Δ).
    pub n_idx_abs: u64,
    /// Total indexes (= total non-zeros).
    pub n_indexes: u64,
    /// Unique counts per vector (before dummy insertion), indexed by count.
    pub vec_unique_hist: Vec<u64>,
}

impl LayerHistograms {
    pub fn new(spec: CoderSpec) -> Self {
        LayerHistograms {
            spec,
            n_vectors: 0,
            n_nonempty: 0,
            n_uniques: 0,
            delta_hist: [0; 256],
            count_hist: vec![0; spec.vec_len + 1],
            idx_delta_hist: vec![0; spec.vec_len + 1],
            n_idx_abs: 0,
            n_indexes: 0,
            vec_unique_hist: vec![0; spec.vec_len + 1],
        }
    }

    /// Accumulate one UCR vector.
    pub fn add_vector(&mut self, u: &UcrVector) {
        assert!(u.len <= self.spec.vec_len, "vector longer than coder spec");
        self.n_vectors += 1;
        self.vec_unique_hist[u.uniques.len()] += 1;
        if u.uniques.is_empty() {
            return;
        }
        self.n_nonempty += 1;
        self.n_uniques += u.uniques.len();
        let deltas = u.deltas();
        for &d in &deltas[1..] {
            self.delta_hist[d as usize] += 1;
        }
        for &c in &u.counts {
            self.count_hist[c as usize] += 1;
        }
        // Index Δs in emission order: ascending within each unique's list,
        // restarting (possibly negative Δ) at group boundaries.
        let mut prev: i64 = -1;
        let mut first = true;
        for group in u.index_groups() {
            for &idx in group {
                let idx = idx as i64;
                if first {
                    self.n_idx_abs += 1;
                    first = false;
                } else {
                    let d = idx - prev;
                    if d > 0 {
                        self.idx_delta_hist[d as usize] += 1;
                    } else {
                        self.n_idx_abs += 1;
                    }
                }
                prev = idx;
                self.n_indexes += 1;
            }
        }
    }

    /// Accumulate one vector from its precomputed per-vector summary —
    /// the memo-served fast path of [`Self::add_vector`]. Must stay
    /// behaviorally identical (asserted by the
    /// `merge_vector_equals_add_vector` test).
    pub fn merge_vector(&mut self, u: &UcrVector, s: &VectorSizeStats) {
        assert!(u.len <= self.spec.vec_len, "vector longer than coder spec");
        self.n_vectors += 1;
        self.vec_unique_hist[u.uniques.len()] += 1;
        if u.uniques.is_empty() {
            return;
        }
        self.n_nonempty += 1;
        self.n_uniques += u.uniques.len();
        for &d in &s.deltas {
            self.delta_hist[d as usize] += 1;
        }
        for &c in &u.counts {
            self.count_hist[c as usize] += 1;
        }
        for &(d, n) in &s.idx_deltas {
            self.idx_delta_hist[d as usize] += n as u64;
        }
        self.n_idx_abs += s.n_idx_abs;
        self.n_indexes += s.n_indexes;
    }

    /// Fold another histogram of the SAME coder spec into this one —
    /// the reduction step of chunked layer extraction: each tile-chunk
    /// task accumulates a private histogram over its m-tile range, and
    /// the finalizer merges them in chunk order. Every field is a plain
    /// integer sum, so any merge order is bit-identical to one
    /// sequential `add_vector`/`merge_vector` pass (asserted by
    /// `merged_chunks_equal_sequential_accumulation`).
    pub fn merge(&mut self, other: &LayerHistograms) {
        assert_eq!(self.spec, other.spec, "merging histograms of different specs");
        self.n_vectors += other.n_vectors;
        self.n_nonempty += other.n_nonempty;
        self.n_uniques += other.n_uniques;
        for (d, &n) in other.delta_hist.iter().enumerate() {
            self.delta_hist[d] += n;
        }
        for (c, &n) in other.count_hist.iter().enumerate() {
            self.count_hist[c] += n;
        }
        for (d, &n) in other.idx_delta_hist.iter().enumerate() {
            self.idx_delta_hist[d] += n;
        }
        self.n_idx_abs += other.n_idx_abs;
        self.n_indexes += other.n_indexes;
        for (g, &n) in other.vec_unique_hist.iter().enumerate() {
            self.vec_unique_hist[g] += n;
        }
    }

    /// Dummy entries created by count overflow at count width `r`.
    ///
    /// Count-field semantics: the all-ones field means "this chunk carries
    /// `2^r − 1` repetitions and a continuation dummy follows"; any other
    /// field `f` means "final chunk of `f + 1` repetitions". A unique with
    /// count `c` therefore needs `⌈c / (2^r − 1)⌉` chunks, i.e.
    /// `⌊(c − 1) / (2^r − 1)⌋` dummies.
    pub fn dummies(&self, r: u32) -> u64 {
        let cap = (1u64 << r) - 1;
        self.count_hist
            .iter()
            .enumerate()
            .skip(1)
            .map(|(c, &n)| n * ((c as u64 - 1) / cap))
            .sum()
    }

    /// Per-vector header stream size at width `h`: real-unique counts in
    /// `[0, 2^h − 2]` are direct; the all-ones escape prefixes a full
    /// `len_bits` value.
    pub fn header_stream_bits(&self, h: u32) -> u64 {
        let escape = (1u64 << h) - 1;
        let len_bits = self.spec.len_bits() as u64;
        self.vec_unique_hist
            .iter()
            .enumerate()
            .map(|(g, &n)| {
                let w = if (g as u64) < escape { h as u64 } else { h as u64 + len_bits };
                n * w
            })
            .sum()
    }

    /// Size of the Δ stream at low-precision width `k`, with the dummies
    /// induced by count width `r` (dummies are Δ=0 → always low precision).
    pub fn delta_stream_bits(&self, k: u32, r: u32) -> u64 {
        let mut bits = self.n_nonempty as u64 * (1 + 8);
        let threshold = 1u64 << k;
        for (d, &n) in self.delta_hist.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let w = if (d as u64) < threshold { k } else { 8 };
            bits += n * (1 + w) as u64;
        }
        bits + self.dummies(r) * (1 + k) as u64
    }

    /// Size of the count stream at width `r`.
    pub fn count_stream_bits(&self, r: u32) -> u64 {
        (self.n_uniques as u64 + self.dummies(r)) * r as u64
    }

    /// Size of the index stream at low-precision width `j` (stores `Δ−1`,
    /// so Δ ∈ [1, 2^j] fits).
    pub fn index_stream_bits(&self, j: u32) -> u64 {
        let abs = self.spec.abs_bits();
        let mut bits = self.n_idx_abs * (1 + abs) as u64;
        let threshold = 1u64 << j;
        for (d, &n) in self.idx_delta_hist.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let w = if (d as u64) <= threshold { j } else { abs };
            bits += n * (1 + w) as u64;
        }
        bits
    }

    /// Total size under a parameter set.
    pub fn total_bits(&self, p: RleParams) -> u64 {
        self.delta_stream_bits(p.delta_bits, p.count_bits)
            + self.count_stream_bits(p.count_bits)
            + self.index_stream_bits(p.index_bits)
            + self.header_stream_bits(p.header_bits)
            + PARAM_HEADER_BITS as u64
    }

    /// Compression stats under a parameter set, straight from the size
    /// model — no bitstreams are emitted. Bit-identical to
    /// [`EncodedLayer::stats`] after encoding the same vectors (asserted
    /// by `histogram_model_matches_emitted_size_exactly` and the
    /// `encode_layer_refs` debug assertion), which is what lets the
    /// stats-path simulators skip stream emission entirely.
    pub fn stats(&self, p: RleParams, num_weights: usize) -> CompressionStats {
        CompressionStats {
            num_weights,
            encoded_bits: self.total_bits(p) as usize,
            delta_bits: self.delta_stream_bits(p.delta_bits, p.count_bits) as usize,
            count_bits: self.count_stream_bits(p.count_bits) as usize,
            index_bits: self.index_stream_bits(p.index_bits) as usize,
            header_bits: (self.header_stream_bits(p.header_bits)
                + PARAM_HEADER_BITS as u64) as usize,
        }
    }

    /// Exhaustive parameter search (paper §III-C): k and r are coupled
    /// through dummy insertion; j and h are independent.
    pub fn best_params(&self) -> RleParams {
        let r_max = bits_for(self.spec.vec_len).max(1);
        let mut best = RleParams {
            delta_bits: 1,
            count_bits: 1,
            index_bits: 1,
            header_bits: 1,
        };
        let mut best_wc = u64::MAX;
        for r in 1..=r_max {
            for k in 1..=7 {
                let bits = self.delta_stream_bits(k, r) + self.count_stream_bits(r);
                if bits < best_wc {
                    best_wc = bits;
                    best.delta_bits = k;
                    best.count_bits = r;
                }
            }
        }
        let j_max = self.spec.abs_bits().max(1);
        let mut best_ib = u64::MAX;
        for j in 1..=j_max {
            let bits = self.index_stream_bits(j);
            if bits < best_ib {
                best_ib = bits;
                best.index_bits = j;
            }
        }
        let h_max = self.spec.len_bits().max(1);
        let mut best_hb = u64::MAX;
        for h in 1..=h_max {
            let bits = self.header_stream_bits(h);
            if bits < best_hb {
                best_hb = bits;
                best.header_bits = h;
            }
        }
        best
    }
}

/// Per-vector sufficient statistics for the encoded-size model — the
/// content-addressed memo caches one of these per distinct weight vector
/// so repeated vectors contribute to [`LayerHistograms`] (via
/// [`LayerHistograms::merge_vector`]) without re-walking their indexes.
///
/// Everything here is a pure function of the [`UcrVector`] alone (no
/// layer geometry), which is what makes the summary shareable across
/// tiles, layers, and sweep points.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct VectorSizeStats {
    /// Non-first Δs between successive sorted uniques (1..=254 each).
    pub deltas: Vec<u8>,
    /// Sparse histogram of positive index Δs: `(Δ, occurrences)`,
    /// ascending by Δ.
    pub idx_deltas: Vec<(u16, u32)>,
    /// Indexes forced to absolute mode (vector-first or non-positive Δ).
    pub n_idx_abs: u64,
    /// Total indexes (= non-zero weights).
    pub n_indexes: u64,
}

impl VectorSizeStats {
    /// Summarize one UCR vector (one-time cost at memo insertion).
    pub fn collect(u: &UcrVector) -> VectorSizeStats {
        let mut s = VectorSizeStats::default();
        if u.uniques.is_empty() {
            return s;
        }
        let mut prev = u.uniques[0] as i16;
        for &w in &u.uniques[1..] {
            s.deltas.push((w as i16 - prev) as u8);
            prev = w as i16;
        }
        // Positive index Δs in emission order, then aggregated sparse.
        let mut raw: Vec<u16> = Vec::new();
        let mut prev_idx: i64 = -1;
        let mut first = true;
        for group in u.index_groups() {
            for &idx in group {
                let idx = idx as i64;
                if first {
                    s.n_idx_abs += 1;
                    first = false;
                } else {
                    let d = idx - prev_idx;
                    if d > 0 {
                        raw.push(d as u16);
                    } else {
                        s.n_idx_abs += 1;
                    }
                }
                prev_idx = idx;
                s.n_indexes += 1;
            }
        }
        raw.sort_unstable();
        for d in raw {
            if let Some(last) = s.idx_deltas.last_mut() {
                if last.0 == d {
                    last.1 += 1;
                    continue;
                }
            }
            s.idx_deltas.push((d, 1));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Encode
// ---------------------------------------------------------------------------

/// The three encoded streams plus per-vector headers of one layer.
#[derive(Clone, Debug)]
pub struct EncodedLayer {
    pub spec: CoderSpec,
    pub params: RleParams,
    pub header: BitWriter,
    pub deltas: BitWriter,
    pub counts: BitWriter,
    pub indexes: BitWriter,
    pub n_vectors: usize,
}

impl EncodedLayer {
    pub fn new(spec: CoderSpec, params: RleParams) -> Self {
        EncodedLayer {
            spec,
            params,
            header: BitWriter::new(),
            deltas: BitWriter::new(),
            counts: BitWriter::new(),
            indexes: BitWriter::new(),
            n_vectors: 0,
        }
    }

    /// Total encoded bits including headers.
    pub fn total_bits(&self) -> usize {
        self.header.len() + self.deltas.len() + self.counts.len() + self.indexes.len()
            + PARAM_HEADER_BITS
    }

    pub fn stats(&self, num_weights: usize) -> CompressionStats {
        CompressionStats {
            num_weights,
            encoded_bits: self.total_bits(),
            delta_bits: self.deltas.len(),
            count_bits: self.counts.len(),
            index_bits: self.indexes.len(),
            header_bits: self.header.len() + PARAM_HEADER_BITS,
        }
    }
}

/// Split one repetition count into chunks per the continuation scheme:
/// all-but-last chunks carry exactly `2^r − 1` repetitions (encoded as the
/// all-ones field, which doubles as the "more follows" marker), the last
/// carries `[1, 2^r − 1]` (encoded as `count − 1`).
pub(crate) fn split_count(c: u32, r: u32) -> Vec<u32> {
    let cap = (1u32 << r) - 1;
    let n_cont = ((c - 1) / cap) as usize;
    let last = c - n_cont as u32 * cap;
    let mut chunks = vec![cap; n_cont];
    chunks.push(last);
    chunks
}

/// Append one UCR vector to the layer's streams.
pub fn encode_vector(enc: &mut EncodedLayer, u: &UcrVector) {
    assert!(u.len <= enc.spec.vec_len);
    let p = enc.params;

    // Split counts into chunks (dummy Δ=0 entries carry overflow).
    // Chunks: (delta_entry, count). delta_entry None = vector-first abs.
    let deltas = u.deltas();
    let mut entries: Vec<(Option<u8>, u32)> = Vec::new();
    for (i, &c) in u.counts.iter().enumerate() {
        for (ci, chunk) in split_count(c, p.count_bits).into_iter().enumerate() {
            let delta = if ci == 0 {
                if i == 0 {
                    None // vector-first: absolute weight
                } else {
                    Some(deltas[i])
                }
            } else {
                Some(0) // dummy
            };
            entries.push((delta, chunk));
        }
    }

    // Per-vector header: the *real* unique count, h-bit with escape.
    let g = u.uniques.len() as u32;
    let escape = (1u32 << p.header_bits) - 1;
    if g < escape {
        enc.header.push(g, p.header_bits);
    } else {
        enc.header.push(escape, p.header_bits);
        enc.header.push(g, enc.spec.len_bits());
    }
    enc.n_vectors += 1;

    // Δ stream.
    for &(delta, _) in &entries {
        match delta {
            None => {
                // Absolute first unique: flag 0 + 8-bit two's complement.
                enc.deltas.push_bit(false);
                enc.deltas.push(u.uniques[0] as u8 as u32, 8);
            }
            Some(d) => {
                if (d as u32) < (1u32 << p.delta_bits) {
                    enc.deltas.push_bit(true);
                    enc.deltas.push(d as u32, p.delta_bits);
                } else {
                    enc.deltas.push_bit(false);
                    enc.deltas.push(d as u32, 8);
                }
            }
        }
    }

    // Count stream: continuation chunks (carrying 2^r − 1) are the
    // all-ones field; final chunks encode `count − 1`. A continuation is
    // always followed by a dummy entry, so "is this entry a continuation"
    // is recoverable: it is iff the *next* entry's Δ is 0 — but the field
    // encoding makes it explicit without lookahead.
    let cap = (1u32 << p.count_bits) - 1;
    for (i, &(_, c)) in entries.iter().enumerate() {
        let next_is_dummy = entries.get(i + 1).is_some_and(|&(d, _)| d == Some(0));
        if next_is_dummy {
            debug_assert_eq!(c, cap);
            enc.counts.push((1 << p.count_bits) - 1, p.count_bits);
        } else {
            enc.counts.push(c - 1, p.count_bits);
        }
    }

    // Index stream: Δ−1 coded with mode flag, running prev across the
    // vector's whole emission order.
    let mut prev: i64 = -1;
    let mut first = true;
    for group in u.index_groups() {
        for &idx in group {
            let idx = idx as i64;
            let d = idx - prev;
            if !first && d > 0 && d <= (1i64 << p.index_bits) {
                enc.indexes.push_bit(true);
                enc.indexes.push((d - 1) as u32, p.index_bits);
            } else {
                enc.indexes.push_bit(false);
                enc.indexes.push(idx as u32, enc.spec.abs_bits());
            }
            prev = idx;
            first = false;
        }
    }
}

/// Encode a whole layer (vectors in dataflow order): collect histograms,
/// search parameters, emit streams.
pub fn encode_layer(vectors: &[UcrVector], spec: CoderSpec) -> EncodedLayer {
    let refs: Vec<&UcrVector> = vectors.iter().collect();
    encode_layer_refs(&refs, spec)
}

/// [`encode_layer`] over borrowed vectors (avoids cloning the transformed
/// layer — the simulators keep the tile structure alive alongside).
pub fn encode_layer_refs(vectors: &[&UcrVector], spec: CoderSpec) -> EncodedLayer {
    let mut hist = LayerHistograms::new(spec);
    for u in vectors {
        hist.add_vector(u);
    }
    let params = hist.best_params();
    let mut enc = EncodedLayer::new(spec, params);
    for u in vectors {
        encode_vector(&mut enc, u);
    }
    debug_assert_eq!(
        enc.total_bits() as u64,
        hist.total_bits(params),
        "histogram size model disagrees with emitted streams"
    );
    enc
}

// ---------------------------------------------------------------------------
// Decode
// ---------------------------------------------------------------------------

/// Streaming decoder state over an [`EncodedLayer`] (this is what the
/// MPE's Weight Decoder implements in hardware, Fig 5c).
pub struct LayerDecoder<'a> {
    enc: &'a EncodedLayer,
    header: BitReader<'a>,
    deltas: BitReader<'a>,
    counts: BitReader<'a>,
    indexes: BitReader<'a>,
    decoded: usize,
}

impl<'a> LayerDecoder<'a> {
    pub fn new(enc: &'a EncodedLayer) -> Self {
        LayerDecoder {
            enc,
            header: enc.header.reader(),
            deltas: enc.deltas.reader(),
            counts: enc.counts.reader(),
            indexes: enc.indexes.reader(),
            decoded: 0,
        }
    }

    /// Vectors remaining.
    pub fn remaining(&self) -> usize {
        self.enc.n_vectors - self.decoded
    }

    /// Decode the next vector. `vec_len` is the true linearized length of
    /// this vector (edge tiles may be shorter than the spec's `L`).
    pub fn next_vector(&mut self, vec_len: usize) -> UcrVector {
        assert!(self.remaining() > 0, "decoder exhausted");
        let p = self.enc.params;
        let spec = self.enc.spec;
        // Header: real unique count, h-bit with all-ones escape.
        let escape = (1u32 << p.header_bits) - 1;
        let mut n_uniques = self.header.read(p.header_bits);
        if n_uniques == escape {
            n_uniques = self.header.read(spec.len_bits());
        }

        let mut uniques: Vec<i8> = Vec::new();
        let mut counts: Vec<u32> = Vec::new();
        let mut indexes: Vec<u16> = Vec::new();
        let mut prev_weight: i16 = 0;
        let mut prev_idx: i64 = -1;
        let all_ones = (1u32 << p.count_bits) - 1;

        let mut remaining_real = n_uniques;
        let mut expect_continuation = false;
        let mut first = true;
        while remaining_real > 0 || expect_continuation {
            // Δ entry.
            let low = self.deltas.read_bit();
            let raw = if low {
                self.deltas.read(p.delta_bits)
            } else {
                self.deltas.read(8)
            };
            // Count field: all-ones = "2^r − 1 repetitions, continuation
            // dummy follows"; otherwise "final chunk of f + 1 repetitions".
            let f = self.counts.read(p.count_bits);
            let count;
            if f == all_ones {
                count = all_ones.max(1);
                expect_continuation = true;
            } else {
                count = f + 1;
                expect_continuation = false;
            }

            let is_dummy;
            let weight: i8;
            if first {
                debug_assert!(!low, "vector-first entry must be absolute");
                weight = raw as u8 as i8;
                is_dummy = false;
                first = false;
            } else if raw == 0 {
                // Dummy: continuation of the previous unique.
                weight = prev_weight as i8;
                is_dummy = true;
            } else {
                weight = (prev_weight + raw as i16) as i8;
                is_dummy = false;
            }
            prev_weight = weight as i16;
            if !is_dummy {
                remaining_real -= 1;
            }

            // Indexes of this entry, appended straight onto the flat
            // buffer — a dummy's indexes directly follow its unique's, so
            // group contiguity is preserved by construction.
            for _ in 0..count {
                let mode = self.indexes.read_bit();
                let idx = if mode {
                    (prev_idx + 1 + self.indexes.read(p.index_bits) as i64) as u32
                } else {
                    self.indexes.read(spec.abs_bits())
                };
                debug_assert!((idx as usize) < vec_len, "decoded index out of range");
                indexes.push(idx as u16);
                prev_idx = idx as i64;
            }

            if is_dummy {
                let last = counts.len() - 1;
                counts[last] += count;
            } else {
                uniques.push(weight);
                counts.push(count);
            }
        }

        self.decoded += 1;
        UcrVector {
            uniques,
            counts,
            indexes,
            len: vec_len,
        }
    }
}

/// Convenience: decode every vector of a layer given their true lengths.
pub fn decode_layer(enc: &EncodedLayer, vec_lens: &[usize]) -> Vec<UcrVector> {
    assert_eq!(vec_lens.len(), enc.n_vectors);
    let mut dec = LayerDecoder::new(enc);
    vec_lens.iter().map(|&l| dec.next_vector(l)).collect()
}

/// Convenience wrapper used in tests: encode + decode one vector.
pub fn decode_vector(enc: &EncodedLayer, vec_len: usize) -> UcrVector {
    LayerDecoder::new(enc).next_vector(vec_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn random_vector(rng: &mut Rng, len: usize, zero_p: f64, spread: u64) -> Vec<i8> {
        (0..len)
            .map(|_| {
                if rng.chance(zero_p) {
                    0
                } else {
                    let v = (rng.below(2 * spread + 1) as i64 - spread as i64).clamp(-127, 127);
                    if v == 0 {
                        1
                    } else {
                        v as i8
                    }
                }
            })
            .collect()
    }

    #[test]
    fn bits_for_edges() {
        assert_eq!(bits_for(1), 0);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(36), 6);
        assert_eq!(bits_for(64), 6);
        assert_eq!(bits_for(65), 7);
        assert_eq!(bits_for(484), 9);
    }

    #[test]
    fn paper_fig4_example_roundtrip() {
        // The Fig 1i / Fig 4 running example: weights manipulated into
        // uniques with Δs and repetitions, encoded with parameter 2.
        let v = [3i8, 0, 1, 3, 0, 1, 1, 4];
        let u = UcrVector::from_weights(&v);
        let spec = CoderSpec::new(8);
        let enc = encode_layer(std::slice::from_ref(&u), spec);
        let dec = decode_vector(&enc, 8);
        assert_eq!(dec, u);
        assert_eq!(dec.reconstruct(), v);
    }

    #[test]
    fn empty_vector_roundtrip() {
        let u = UcrVector::from_weights(&[0i8; 36]);
        let enc = encode_layer(std::slice::from_ref(&u), CoderSpec::new(36));
        let dec = decode_vector(&enc, 36);
        assert_eq!(dec.reconstruct(), vec![0i8; 36]);
    }

    #[test]
    fn single_element_vector() {
        for w in [-128i8, -1, 1, 127] {
            let u = UcrVector::from_weights(&[w]);
            let enc = encode_layer(std::slice::from_ref(&u), CoderSpec::new(1));
            assert_eq!(decode_vector(&enc, 1).reconstruct(), vec![w]);
        }
    }

    #[test]
    fn split_count_scheme() {
        // r=2 → continuation chunks carry 3 (= 2^r − 1), final in [1,3].
        assert_eq!(split_count(3, 2), vec![3]);
        assert_eq!(split_count(4, 2), vec![3, 1]);
        assert_eq!(split_count(6, 2), vec![3, 3]);
        assert_eq!(split_count(7, 2), vec![3, 3, 1]);
        for c in 1..200u32 {
            for r in 1..6 {
                let chunks = split_count(c, r);
                assert_eq!(chunks.iter().sum::<u32>(), c);
                let cap = (1u32 << r) - 1;
                assert!(*chunks.last().unwrap() >= 1);
                assert!(*chunks.last().unwrap() <= cap);
                for &ch in &chunks[..chunks.len() - 1] {
                    assert_eq!(ch, cap);
                }
                assert_eq!(chunks.len() as u64, 1 + (c as u64 - 1) / cap as u64);
            }
        }
    }

    #[test]
    fn count_overflow_inserts_dummies() {
        // 40 repetitions of the same weight in a 64-long vector.
        let v = vec![7i8; 40]
            .into_iter()
            .chain(vec![0i8; 24])
            .collect::<Vec<_>>();
        let u = UcrVector::from_weights(&v);
        let spec = CoderSpec::new(64);
        // Force a small count width to exercise overflow.
        let params = RleParams {
            delta_bits: 2,
            count_bits: 3,
            index_bits: 3,
            header_bits: 2,
        };
        let mut enc = EncodedLayer::new(spec, params);
        encode_vector(&mut enc, &u);
        // Header stores the real unique count (1), not the entry count.
        let mut hdr = enc.header.reader();
        assert_eq!(hdr.read(2), 1);
        let dec = decode_vector(&enc, 64);
        assert_eq!(dec.reconstruct(), v);
        assert_eq!(dec.uniques, vec![7]);
        assert_eq!(dec.counts, vec![40]);
    }

    #[test]
    fn header_escape_roundtrip() {
        // A vector with many uniques forces the header escape path.
        let v: Vec<i8> = (1..=30).map(|x| x as i8).collect();
        let u = UcrVector::from_weights(&v);
        let params = RleParams {
            delta_bits: 2,
            count_bits: 1,
            index_bits: 2,
            header_bits: 2, // escape at 3 — 30 uniques must escape
        };
        let spec = CoderSpec::new(30);
        let mut enc = EncodedLayer::new(spec, params);
        encode_vector(&mut enc, &u);
        let dec = decode_vector(&enc, 30);
        assert_eq!(dec.reconstruct(), v);
    }

    #[test]
    fn histogram_model_matches_emitted_size_exactly() {
        let mut rng = Rng::new(77);
        let vectors: Vec<UcrVector> = (0..50)
            .map(|_| UcrVector::from_weights(&random_vector(&mut rng, 36, 0.5, 20)))
            .collect();
        let spec = CoderSpec::new(36);
        let mut hist = LayerHistograms::new(spec);
        for u in &vectors {
            hist.add_vector(u);
        }
        // Check *all* parameter combinations, not just the chosen one.
        for r in 1..=6 {
            for k in 1..=7 {
                for j in 1..=6 {
                    for h in 1..=6 {
                        let p = RleParams {
                            delta_bits: k,
                            count_bits: r,
                            index_bits: j,
                            header_bits: h,
                        };
                        let mut enc = EncodedLayer::new(spec, p);
                        for u in &vectors {
                            encode_vector(&mut enc, u);
                        }
                        assert_eq!(
                            enc.total_bits() as u64,
                            hist.total_bits(p),
                            "size model mismatch at k={k} r={r} j={j} h={h}"
                        );
                        // The full stats — the stream-by-stream breakdown
                        // the stats-path simulators report — must also be
                        // byte-identical to the emitted streams.
                        assert_eq!(
                            hist.stats(p, 50 * 36),
                            enc.stats(50 * 36),
                            "component mismatch at k={k} r={r} j={j} h={h}"
                        );
                    }
                }
            }
        }
    }

    /// Chunked extraction folds per-chunk histograms with `merge`; any
    /// split must reproduce the sequential accumulation bit for bit
    /// (and hence the same best parameters and priced stats).
    #[test]
    fn merged_chunks_equal_sequential_accumulation() {
        let mut rng = Rng::new(404);
        let spec = CoderSpec::new(36);
        let vectors: Vec<UcrVector> = (0..90)
            .map(|i| {
                UcrVector::from_weights(&random_vector(&mut rng, 36, (i % 10) as f64 / 10.0, 25))
            })
            .collect();
        let mut whole = LayerHistograms::new(spec);
        for u in &vectors {
            whole.add_vector(u);
        }
        for n_chunks in [1usize, 2, 3, 7, 90] {
            let mut merged = LayerHistograms::new(spec);
            for ci in 0..n_chunks {
                let (lo, hi) = (90 * ci / n_chunks, 90 * (ci + 1) / n_chunks);
                let mut part = LayerHistograms::new(spec);
                for u in &vectors[lo..hi] {
                    part.add_vector(u);
                }
                merged.merge(&part);
            }
            assert_eq!(merged, whole, "split into {n_chunks} chunks");
            assert_eq!(merged.best_params(), whole.best_params());
            assert_eq!(
                merged.stats(whole.best_params(), 90 * 36),
                whole.stats(whole.best_params(), 90 * 36)
            );
        }
    }

    /// Degenerate chunks from the tile scheduler: a fresh (all-zero)
    /// part from an empty m-tile range, and a single-m-tile layer whose
    /// reduction sees exactly one populated chunk. `merge` must treat
    /// both as plain sums — identity in either direction.
    #[test]
    fn merge_identity_with_empty_and_single_chunks() {
        let mut rng = Rng::new(31);
        let spec = CoderSpec::new(36);
        let mut filled = LayerHistograms::new(spec);
        for _ in 0..12 {
            filled.add_vector(&UcrVector::from_weights(&random_vector(&mut rng, 36, 0.4, 15)));
        }
        // Folding an empty chunk in changes nothing...
        let mut a = filled.clone();
        a.merge(&LayerHistograms::new(spec));
        assert_eq!(a, filled);
        // ...and a single-m-tile layer — one populated chunk folded into
        // the fresh accumulator — reproduces that chunk exactly.
        let mut b = LayerHistograms::new(spec);
        b.merge(&filled);
        assert_eq!(b, filled);
        // Fresh ⊕ fresh stays fresh.
        let mut c = LayerHistograms::new(spec);
        c.merge(&LayerHistograms::new(spec));
        assert_eq!(c, LayerHistograms::new(spec));
    }

    /// A chunk of all-zero vectors contributes vector counters only:
    /// every stream histogram stays untouched, but the per-vector header
    /// cost still grows.
    #[test]
    fn merge_all_zero_chunk_counts_vectors_only() {
        let spec = CoderSpec::new(36);
        let mut zeros = LayerHistograms::new(spec);
        for _ in 0..5 {
            zeros.add_vector(&UcrVector::from_weights(&[0i8; 36]));
        }
        assert_eq!(zeros.n_vectors, 5);
        assert_eq!(zeros.n_nonempty, 0);
        assert_eq!(zeros.n_uniques, 0);
        assert_eq!(zeros.n_indexes, 0);
        assert_eq!(zeros.vec_unique_hist[0], 5);
        let mut rng = Rng::new(32);
        let mut filled = LayerHistograms::new(spec);
        for _ in 0..4 {
            filled.add_vector(&UcrVector::from_weights(&random_vector(&mut rng, 36, 0.3, 9)));
        }
        let before = filled.clone();
        filled.merge(&zeros);
        assert_eq!(filled.n_vectors, before.n_vectors + 5);
        assert_eq!(filled.vec_unique_hist[0], before.vec_unique_hist[0] + 5);
        assert_eq!(filled.n_nonempty, before.n_nonempty);
        assert_eq!(filled.n_uniques, before.n_uniques);
        assert_eq!(filled.delta_hist, before.delta_hist);
        assert_eq!(filled.count_hist, before.count_hist);
        assert_eq!(filled.idx_delta_hist, before.idx_delta_hist);
        assert_eq!(filled.n_idx_abs, before.n_idx_abs);
        assert_eq!(filled.n_indexes, before.n_indexes);
        // The size model prices the extra per-vector headers.
        let p = before.best_params();
        assert!(filled.total_bits(p) > before.total_bits(p));
    }

    /// The memo fast path (`merge_vector` over cached summaries) must
    /// accumulate exactly what `add_vector` does.
    #[test]
    fn merge_vector_equals_add_vector() {
        let mut rng = Rng::new(2024);
        let spec = CoderSpec::new(48);
        let mut by_add = LayerHistograms::new(spec);
        let mut by_merge = LayerHistograms::new(spec);
        for i in 0..60u64 {
            let zero_p = (i % 10) as f64 / 10.0;
            let v = random_vector(&mut rng, 48, zero_p, 1 + i % 90);
            let u = UcrVector::from_weights(&v);
            by_add.add_vector(&u);
            by_merge.merge_vector(&u, &VectorSizeStats::collect(&u));
        }
        assert_eq!(by_add, by_merge);
    }

    #[test]
    fn best_params_is_argmin() {
        let mut rng = Rng::new(123);
        let vectors: Vec<UcrVector> = (0..30)
            .map(|_| UcrVector::from_weights(&random_vector(&mut rng, 36, 0.6, 10)))
            .collect();
        let spec = CoderSpec::new(36);
        let mut hist = LayerHistograms::new(spec);
        for u in &vectors {
            hist.add_vector(u);
        }
        let best = hist.best_params();
        let best_bits = hist.total_bits(best);
        for r in 1..=6 {
            for k in 1..=7 {
                for j in 1..=6 {
                    for h in 1..=6 {
                        let p = RleParams {
                            delta_bits: k,
                            count_bits: r,
                            index_bits: j,
                            header_bits: h,
                        };
                        assert!(hist.total_bits(p) >= best_bits);
                    }
                }
            }
        }
    }

    #[test]
    fn customization_beats_fixed_parameters() {
        // The headline §V-B mechanism: per-layer-optimal parameters never
        // lose to UCNN's fixed bit-length 5.
        let mut rng = Rng::new(5);
        for &(zero_p, spread) in &[(0.3, 5u64), (0.6, 40), (0.9, 100), (0.1, 2)] {
            let vectors: Vec<UcrVector> = (0..40)
                .map(|_| UcrVector::from_weights(&random_vector(&mut rng, 36, zero_p, spread)))
                .collect();
            let spec = CoderSpec::new(36);
            let mut hist = LayerHistograms::new(spec);
            for u in &vectors {
                hist.add_vector(u);
            }
            let best = hist.total_bits(hist.best_params());
            let fixed = hist.total_bits(RleParams {
                delta_bits: 5,
                count_bits: 5,
                index_bits: 5,
                header_bits: 5,
            });
            assert!(best <= fixed, "zero_p={zero_p} spread={spread}");
        }
    }

    #[test]
    fn prop_roundtrip_losing_nothing() {
        check(
            80,
            |r, size| {
                let len = 4 + size * 4;
                let n_vec = 1 + r.index(6);
                let zero_p = r.f64();
                let spread = 1 + r.below(100);
                let vs: Vec<Vec<i8>> = (0..n_vec)
                    .map(|_| random_vector(r, len, zero_p, spread))
                    .collect();
                (vs, len)
            },
            |(vs, len)| {
                let ucr: Vec<UcrVector> =
                    vs.iter().map(|v| UcrVector::from_weights(v)).collect();
                let enc = encode_layer(&ucr, CoderSpec::new(*len));
                let lens = vec![*len; vs.len()];
                let dec = decode_layer(&enc, &lens);
                dec.iter()
                    .zip(vs)
                    .all(|(d, v)| d.reconstruct() == *v)
            },
        );
    }

    #[test]
    fn prop_sparser_vectors_compress_better_per_weight() {
        // Compression should improve (fewer bits/weight) as sparsity rises,
        // holding the value distribution fixed.
        check(
            20,
            |r, _| r.next_u64(),
            |&seed| {
                let mut rng = Rng::new(seed);
                let spec = CoderSpec::new(64);
                let mut rates = Vec::new();
                for zero_p in [0.2, 0.5, 0.8, 0.95] {
                    let vs: Vec<UcrVector> = (0..40)
                        .map(|_| {
                            UcrVector::from_weights(&random_vector(&mut rng, 64, zero_p, 30))
                        })
                        .collect();
                    let enc = encode_layer(&vs, spec);
                    rates.push(enc.total_bits() as f64 / (40.0 * 64.0));
                }
                rates.windows(2).all(|w| w[1] <= w[0] * 1.05)
            },
        );
    }
}
