//! Bit-granular streams — the substrate for every weight encoding in the
//! repo (CoDR's customized RLE, UCNN's fixed-parameter RLE, SCNN's 4-bit
//! zero-run format). LSB-first within a backing `u64` word vector.

/// Append-only bit vector.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BitWriter {
    words: Vec<u64>,
    /// Total bits written.
    len: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `n` bits of `value` (`n ≤ 32`).
    #[inline]
    pub fn push(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || value < (1u32 << n), "value {value} exceeds {n} bits");
        if n == 0 {
            return;
        }
        let bit_off = self.len & 63;
        let word_idx = self.len >> 6;
        if word_idx == self.words.len() {
            self.words.push(0);
        }
        self.words[word_idx] |= (value as u64) << bit_off;
        let spill = (bit_off + n as usize).saturating_sub(64);
        if spill > 0 {
            self.words.push((value as u64) >> (n as usize - spill));
        }
        self.len += n as usize;
    }

    /// Append a single bit.
    #[inline]
    pub fn push_bit(&mut self, b: bool) {
        self.push(b as u32, 1);
    }

    /// Total bits written.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes occupied when stored to memory (the DRAM-footprint figure).
    pub fn byte_len(&self) -> usize {
        self.len.div_ceil(8)
    }

    /// Freeze into a reader.
    pub fn reader(&self) -> BitReader<'_> {
        BitReader {
            words: &self.words,
            len: self.len,
            pos: 0,
        }
    }
}

/// Sequential reader over a [`BitWriter`]'s contents.
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    words: &'a [u64],
    len: usize,
    pos: usize,
}

impl BitReader<'_> {
    /// Read the next `n` bits (`n ≤ 32`). Panics past the end.
    #[inline]
    pub fn read(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        assert!(
            self.pos + n as usize <= self.len,
            "bitstream underrun: pos {} + {} > len {}",
            self.pos,
            n,
            self.len
        );
        if n == 0 {
            return 0;
        }
        let bit_off = self.pos & 63;
        let word_idx = self.pos >> 6;
        let mut v = self.words[word_idx] >> bit_off;
        let taken = 64 - bit_off;
        if (n as usize) > taken {
            v |= self.words[word_idx + 1] << taken;
        }
        self.pos += n as usize;
        if n == 32 {
            v as u32
        } else {
            (v & ((1u64 << n) - 1)) as u32
        }
    }

    #[inline]
    pub fn read_bit(&mut self) -> bool {
        self.read(1) != 0
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.len - self.pos
    }

    /// Bits consumed so far.
    pub fn position(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;


    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        w.push(0xFF, 8);
        w.push(0, 1);
        w.push(0x1234, 16);
        assert_eq!(w.len(), 28);
        let mut r = w.reader();
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read(8), 0xFF);
        assert_eq!(r.read(1), 0);
        assert_eq!(r.read(16), 0x1234);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn word_boundary_crossing() {
        let mut w = BitWriter::new();
        w.push(0x3FFFFFFF, 30);
        w.push(0x3FFFFFFF, 30);
        w.push(0xABCD, 16); // crosses the 64-bit word boundary
        let mut r = w.reader();
        assert_eq!(r.read(30), 0x3FFFFFFF);
        assert_eq!(r.read(30), 0x3FFFFFFF);
        assert_eq!(r.read(16), 0xABCD);
    }

    #[test]
    fn byte_len_rounds_up() {
        let mut w = BitWriter::new();
        w.push(1, 1);
        assert_eq!(w.byte_len(), 1);
        w.push(0x7F, 7);
        assert_eq!(w.byte_len(), 1);
        w.push_bit(true);
        assert_eq!(w.byte_len(), 2);
    }

    #[test]
    #[should_panic(expected = "underrun")]
    fn read_past_end_panics() {
        let mut w = BitWriter::new();
        w.push(3, 2);
        let mut r = w.reader();
        r.read(3);
    }

    #[test]
    fn full_32bit_values() {
        let mut w = BitWriter::new();
        w.push(u32::MAX, 32);
        w.push(0, 32);
        w.push(u32::MAX, 32);
        let mut r = w.reader();
        assert_eq!(r.read(32), u32::MAX);
        assert_eq!(r.read(32), 0);
        assert_eq!(r.read(32), u32::MAX);
    }

    #[test]
    fn prop_roundtrip_random_fields() {
        check(
            100,
            |r, size| {
                let n = 1 + size * 3;
                (0..n)
                    .map(|_| {
                        let bits = 1 + r.below(32) as u32;
                        let v = if bits == 32 {
                            r.next_u64() as u32
                        } else {
                            r.below(1 << bits) as u32
                        };
                        (v, bits)
                    })
                    .collect::<Vec<(u32, u32)>>()
            },
            |fields| {
                let mut w = BitWriter::new();
                for &(v, n) in fields {
                    w.push(v, n);
                }
                let expected: usize = fields.iter().map(|&(_, n)| n as usize).sum();
                if w.len() != expected {
                    return false;
                }
                let mut rd = w.reader();
                fields.iter().all(|&(v, n)| rd.read(n) == v) && rd.remaining() == 0
            },
        );
    }
}
