//! Energy accounting (paper §V-D, Fig 8).
//!
//! Prices the traffic recorded in [`crate::arch::MemoryStats`] plus the
//! ALU and crossbar activity with the [`crate::arch::CactiLite`] model,
//! yielding the five-way breakdown the paper reports: DRAM, SRAM, RF,
//! ALU, crossbar.

use crate::arch::{CactiLite, MemConfig, MemoryStats};

/// Datapath activity of one simulated layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AluStats {
    /// Full-precision multiplies (8×8).
    pub mults_full: u64,
    /// Low-precision differential multiplies (Δ fits the layer's k bits),
    /// paired with the k they were executed at via `delta_bits`.
    pub mults_low: u64,
    /// The low-precision Δ width in effect (bits).
    pub delta_bits: u32,
    /// 32-bit accumulations.
    pub adds: u64,
    /// Crossbar/interconnect flits (each `xbar_bits` wide).
    pub xbar_transfers: u64,
    /// Crossbar flit width.
    pub xbar_bits: u32,
}

impl AluStats {
    pub fn mults(&self) -> u64 {
        self.mults_full + self.mults_low
    }

    /// Multiplier-array utilization over `cycles`: the fraction of
    /// multiplier-cycles that performed a multiply. One Pareto axis of
    /// the mapping search (`codr map`).
    pub fn utilization(&self, total_mults: usize, cycles: u64) -> f64 {
        if total_mults == 0 || cycles == 0 {
            return 0.0;
        }
        (self.mults() as f64 / (cycles as f64 * total_mults as f64)).min(1.0)
    }

    pub fn add(&mut self, o: &AluStats) {
        self.mults_full += o.mults_full;
        self.mults_low += o.mults_low;
        // Widths are per-layer; keep the max for a conservative aggregate.
        self.delta_bits = self.delta_bits.max(o.delta_bits);
        self.adds += o.adds;
        self.xbar_transfers += o.xbar_transfers;
        self.xbar_bits = self.xbar_bits.max(o.xbar_bits);
        // Aggregate low-mult energy is priced per layer before summing, so
        // the max width here is only used for reporting.
    }
}

/// Energy breakdown in µJ — the Fig 8 bars.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub dram_uj: f64,
    pub sram_uj: f64,
    pub rf_uj: f64,
    pub alu_uj: f64,
    pub xbar_uj: f64,
}

impl EnergyBreakdown {
    pub fn total_uj(&self) -> f64 {
        self.dram_uj + self.sram_uj + self.rf_uj + self.alu_uj + self.xbar_uj
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.dram_uj += o.dram_uj;
        self.sram_uj += o.sram_uj;
        self.rf_uj += o.rf_uj;
        self.alu_uj += o.alu_uj;
        self.xbar_uj += o.xbar_uj;
    }

    /// Fraction of total spent in a component (for §V-D's percentages).
    pub fn fraction(&self, component_uj: f64) -> f64 {
        let t = self.total_uj();
        if t == 0.0 {
            0.0
        } else {
            component_uj / t
        }
    }
}

const PJ_TO_UJ: f64 = 1e-6;

/// Price one layer's activity.
///
/// SRAM accesses are priced at their recorded *access* granularity: each
/// access of `bits/accesses` width pays `CactiLite::sram_access_pj` on its
/// array. RF accesses likewise. This is where the §V-C observation comes
/// from: a 64-bit compressed-weight word access costs little more than an
/// 8-bit feature access but carries ~38 weights.
pub fn price_layer(
    mem: &MemoryStats,
    alu: &AluStats,
    cacti: &CactiLite,
    cfg: &MemConfig,
) -> EnergyBreakdown {
    let price_sram = |c: &crate::arch::AccessCounter, size_kb: f64| -> f64 {
        if c.accesses == 0 {
            return 0.0;
        }
        let width = (c.bits / c.accesses) as u32;
        c.accesses as f64 * cacti.sram_access_pj(size_kb, width)
    };
    // Weight SRAM is *streamed*: accesses are counted per decoded
    // structure element (Fig 7's x-axis), but the array is physically read
    // in full words, so the energy is word-amortized over the stream bits.
    // This is exactly the paper's §V-C observation — a weight access costs
    // 20.61× less than a feature access because it carries ~1.7 bits of a
    // 64-bit word, not a full array activation.
    let price_weight_stream = |c: &crate::arch::AccessCounter, size_kb: f64| -> f64 {
        let words = c.bits as f64 / cfg.sram_word_bits as f64;
        words * cacti.sram_access_pj(size_kb, cfg.sram_word_bits)
    };
    let price_rf = |c: &crate::arch::AccessCounter| -> f64 {
        if c.accesses == 0 {
            return 0.0;
        }
        let width = (c.bits / c.accesses) as u32;
        c.accesses as f64 * cacti.rf_access_pj(width)
    };

    let sram_pj = price_sram(&mem.input_sram, cfg.input_sram_kb)
        + price_sram(&mem.output_sram, cfg.output_sram_kb)
        + price_weight_stream(&mem.weight_sram, cfg.weight_sram_kb);
    let rf_pj = price_rf(&mem.input_rf) + price_rf(&mem.weight_rf) + price_rf(&mem.output_rf);
    let dram_pj = cacti.dram_pj(mem.dram.bits);
    let alu_pj = alu.mults_full as f64 * cacti.mult_pj(8, 8)
        + alu.mults_low as f64 * cacti.mult_pj(alu.delta_bits.max(1), 8)
        + alu.adds as f64 * cacti.add32_pj;
    let xbar_pj = alu.xbar_transfers as f64 * cacti.xbar_pj(alu.xbar_bits);

    EnergyBreakdown {
        dram_uj: dram_pj * PJ_TO_UJ,
        sram_uj: sram_pj * PJ_TO_UJ,
        rf_uj: rf_pj * PJ_TO_UJ,
        alu_uj: alu_pj * PJ_TO_UJ,
        xbar_uj: xbar_pj * PJ_TO_UJ,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MemoryKind;

    fn cacti() -> CactiLite {
        CactiLite::default()
    }

    #[test]
    fn empty_layer_costs_nothing() {
        let e = price_layer(
            &MemoryStats::default(),
            &AluStats::default(),
            &cacti(),
            &MemConfig::default(),
        );
        assert_eq!(e.total_uj(), 0.0);
    }

    #[test]
    fn dram_priced_at_160pj_per_byte() {
        let mut mem = MemoryStats::default();
        mem.record(MemoryKind::Dram, 1, 8 * 1_000_000); // 1 MB
        let e = price_layer(&mem, &AluStats::default(), &cacti(), &MemConfig::default());
        assert!((e.dram_uj - 160.0).abs() < 1e-9, "dram {}", e.dram_uj);
    }

    #[test]
    fn low_precision_mults_cost_less() {
        let full = AluStats {
            mults_full: 1000,
            ..Default::default()
        };
        let low = AluStats {
            mults_low: 1000,
            delta_bits: 2,
            ..Default::default()
        };
        let c = cacti();
        let cfg = MemConfig::default();
        let e_full = price_layer(&MemoryStats::default(), &full, &c, &cfg);
        let e_low = price_layer(&MemoryStats::default(), &low, &c, &cfg);
        assert!(e_low.alu_uj < e_full.alu_uj / 2.0);
    }

    #[test]
    fn wide_sram_access_costs_more_but_sublinearly() {
        let mut narrow = MemoryStats::default();
        narrow.record(MemoryKind::InputSram, 64, 8); // 64 × 8-bit
        let mut wide = MemoryStats::default();
        wide.record(MemoryKind::InputSram, 8, 64); // 8 × 64-bit (same bits)
        let c = cacti();
        let cfg = MemConfig::default();
        let e_n = price_layer(&narrow, &AluStats::default(), &c, &cfg);
        let e_w = price_layer(&wide, &AluStats::default(), &c, &cfg);
        // Same traffic in fewer, wider accesses is cheaper (amortized
        // array cost) — the §V-C weight-streaming advantage.
        assert!(e_w.sram_uj < e_n.sram_uj);
    }

    #[test]
    fn breakdown_adds_and_fractions() {
        let mut a = EnergyBreakdown {
            dram_uj: 1.0,
            sram_uj: 2.0,
            rf_uj: 3.0,
            alu_uj: 4.0,
            xbar_uj: 0.0,
        };
        let b = a;
        a.add(&b);
        assert_eq!(a.total_uj(), 20.0);
        assert!((a.fraction(a.alu_uj) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn alu_stats_merge() {
        let mut a = AluStats {
            mults_full: 10,
            mults_low: 5,
            delta_bits: 2,
            adds: 7,
            xbar_transfers: 3,
            xbar_bits: 32,
        };
        a.add(&AluStats {
            mults_full: 1,
            mults_low: 2,
            delta_bits: 4,
            adds: 3,
            xbar_transfers: 4,
            xbar_bits: 16,
        });
        assert_eq!(a.mults(), 18);
        assert_eq!(a.adds, 10);
        assert_eq!(a.delta_bits, 4);
        assert_eq!(a.xbar_transfers, 7);
    }
}
