//! Central registry of every `CODR_*` environment variable.
//!
//! Two jobs: (1) at runtime, [`var`] is the one sanctioned way to read a
//! `CODR_*` variable — a `debug_assert` catches reads of names that were
//! never registered; (2) at analysis time, [`check_file`] flags `CODR_*`
//! string literals that are missing from [`ENV_VARS`] and direct
//! `std::env::var("CODR_…")` calls outside this module, and
//! [`render_table`] produces the markdown table the README embeds
//! between `<!-- codr-env:begin -->` / `<!-- codr-env:end -->` markers
//! (`analyze` diffs the block against the rendered table, so the doc
//! cannot drift from the code).

use super::lexer::Tok;
use super::Finding;
use std::collections::BTreeSet;

/// One registered variable: its name, effective default, and purpose.
pub struct EnvVar {
    pub name: &'static str,
    pub default: &'static str,
    pub purpose: &'static str,
}

/// The full registry. Adding a `CODR_*` literal anywhere under
/// `rust/src/` without a row here is an `env_registry` finding.
pub const ENV_VARS: &[EnvVar] = &[
    EnvVar {
        name: "CODR_FAULTS",
        default: "(unset)",
        purpose: "Deterministic fault-injection spec, `name[:count][@prob],…,seed=N`; unset disarms every seam",
    },
    EnvVar {
        name: "CODR_MEMO_CAP",
        default: "524288",
        purpose: "Vector-memo capacity (distinct cached vectors) before second-chance eviction",
    },
    EnvVar {
        name: "CODR_MEMO_SNAPSHOT",
        default: "(store)/memo.snapshot",
        purpose: "Memo snapshot path; `off`/`0`/empty disables persistence",
    },
    EnvVar {
        name: "CODR_MEMO_SNAPSHOT_CAP_MB",
        default: "64",
        purpose: "Memo snapshot size cap in MiB; hottest entries are kept when truncating",
    },
    EnvVar {
        name: "CODR_MEMO_SNAPSHOT_SECS",
        default: "300",
        purpose: "Background memo-snapshot period in seconds; `0`/`off` disables the periodic writer",
    },
    EnvVar {
        name: "CODR_PEER_TIMEOUT_MS",
        default: "1000",
        purpose: "Per-peer connect/read/write timeout for ring forwards and health probes, in milliseconds",
    },
    EnvVar {
        name: "CODR_RING",
        default: "(unset)",
        purpose: "Static multi-host ring membership (`host:port,host:port,...`) used when `--ring` is not given; the list must include this node's own address",
    },
    EnvVar {
        name: "CODR_SERVE_EXECUTORS",
        default: "4",
        purpose: "Executor-pool worker threads for `codr serve`; the server's thread count is fixed regardless of connected clients",
    },
    EnvVar {
        name: "CODR_SERVE_MAX_JOBS",
        default: "256",
        purpose: "Finished jobs retained for status polling before pruning to the expired ring",
    },
    EnvVar {
        name: "CODR_STORE",
        default: "results/store",
        purpose: "Result-store directory used when `--store` is not given",
    },
    EnvVar {
        name: "CODR_STORE_WRITE_V1",
        default: "(unset)",
        purpose: "`1`/`true` keeps the store in the legacy v1 single-point layout (no pack migration)",
    },
];

/// Is `name` a registered variable?
pub fn is_registered(name: &str) -> bool {
    ENV_VARS.iter().any(|v| v.name == name)
}

/// Read a registered `CODR_*` variable. The single sanctioned
/// `std::env::var` call site for them — `codr analyze` flags any other.
pub fn var(name: &str) -> Option<String> {
    debug_assert!(
        is_registered(name),
        "env var {name} is not in analysis::env_registry::ENV_VARS"
    );
    std::env::var(name).ok()
}

/// The markdown table the README embeds. Regenerate with
/// `codr analyze --print-env-table` whenever [`ENV_VARS`] changes.
pub fn render_table() -> String {
    let mut s = String::from("| variable | default | purpose |\n|---|---|---|\n");
    for v in ENV_VARS {
        s.push_str(&format!(
            "| `{}` | `{}` | {} |\n",
            v.name, v.default, v.purpose
        ));
    }
    s
}

pub const README_BEGIN: &str = "<!-- codr-env:begin -->";
pub const README_END: &str = "<!-- codr-env:end -->";

/// Token-level check for one file: unregistered `CODR_*` literals, and
/// `std::env::var`/`var_os` reads of them outside this module. Names
/// seen in string literals are collected into `used` so the tree pass
/// can flag dead registry rows.
pub(super) fn check_file(
    rel: &str,
    toks: &[Tok],
    out: &mut Vec<Finding>,
    used: &mut BTreeSet<String>,
) {
    let here = rel.ends_with("analysis/env_registry.rs");
    for (i, t) in toks.iter().enumerate() {
        // Any CODR_* name inside any string literal must be registered.
        if let Some(s) = t.str_lit() {
            for name in codr_names(s) {
                if is_registered(&name) {
                    // Mentions inside this module (the rows themselves)
                    // don't count toward liveness.
                    if !here {
                        used.insert(name);
                    }
                } else if !t.in_test {
                    out.push(Finding {
                        check: "env_registry",
                        file: rel.to_string(),
                        line: t.line,
                        message: format!(
                            "`{name}` is not in analysis::env_registry::ENV_VARS — \
                             register it (name, default, purpose)"
                        ),
                    });
                }
            }
        }
        // Direct std::env reads of CODR_* belong only in this module.
        if here || t.in_test {
            continue;
        }
        let is_read = t
            .ident()
            .is_some_and(|id| id == "var" || id == "var_os")
            && i >= 3
            && toks[i - 1].is_punct(':')
            && toks[i - 2].is_punct(':')
            && toks[i - 3].is_ident("env");
        if is_read {
            if let Some(name) = toks
                .get(i + 2)
                .and_then(|a| a.str_lit())
                .filter(|s| s.starts_with("CODR_"))
            {
                out.push(Finding {
                    check: "env_registry",
                    file: rel.to_string(),
                    line: t.line,
                    message: format!(
                        "reads `{name}` via std::env directly — route through \
                         analysis::env_registry::var"
                    ),
                });
            }
        }
    }
}

/// Extract every maximal `CODR_[A-Z0-9_]*` word from a string literal.
fn codr_names(s: &str) -> Vec<String> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 5 <= b.len() {
        if &b[i..i + 5] == b"CODR_" && (i == 0 || !word_byte(b[i - 1])) {
            let mut j = i + 5;
            while j < b.len() && word_byte(b[j]) {
                j += 1;
            }
            out.push(s[i..j].to_string());
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

fn word_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_uppercase() || c.is_ascii_digit()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_unique_and_prefixed() {
        for w in ENV_VARS.windows(2) {
            assert!(w[0].name < w[1].name, "{} !< {}", w[0].name, w[1].name);
        }
        for v in ENV_VARS {
            assert!(v.name.starts_with("CODR_"));
            assert!(!v.purpose.is_empty() && !v.default.is_empty());
        }
    }

    #[test]
    fn var_reads_registered_names() {
        assert!(is_registered("CODR_STORE"));
        assert!(!is_registered("CODR_BOGUS"));
        // Unset in the test env; the point is the debug_assert passes.
        let _ = var("CODR_STORE");
    }

    #[test]
    fn codr_name_extraction() {
        assert_eq!(
            codr_names("set CODR_STORE or CODR_MEMO_CAP."),
            vec!["CODR_STORE".to_string(), "CODR_MEMO_CAP".to_string()]
        );
        assert_eq!(codr_names("$CODR_FAULTS"), vec!["CODR_FAULTS".to_string()]);
        assert!(codr_names("DECODR_X no, codr_store no").is_empty());
    }

    #[test]
    fn table_renders_every_row() {
        let t = render_table();
        for v in ENV_VARS {
            assert!(t.contains(v.name), "table missing {}", v.name);
        }
        assert_eq!(t.lines().count(), 2 + ENV_VARS.len());
    }
}
