//! `codr analyze` — project-invariant static analysis.
//!
//! A hand-rolled, dependency-free analyzer in the same spirit as
//! [`crate::util::json`]: a comment/string-aware [`lexer`] feeds five
//! token-level checks over `rust/src/**`:
//!
//! * `lock_order` — the declared hierarchy (server jobs → scheduler
//!   inflight → store save lock → pack lock → memo shard → arena) with
//!   nested acquisitions flagged when they invert it;
//! * `atomics` — `Ordering::Relaxed` only on allowlisted striped
//!   counters, never on control flags or generation tags;
//! * `panic_policy` — no `unwrap`/`expect`/`panic!` outside
//!   `#[cfg(test)]` in `serve/`, `coordinator/pool.rs`, `faults/`;
//! * `fault_seams` — every `fs::rename`/`create_new` durability edge
//!   sits in a function with a `faults::` seam, so new edges cannot
//!   ship uninjectable;
//! * `env_registry` — every `CODR_*` literal is registered in
//!   [`env_registry::ENV_VARS`], reads route through
//!   [`env_registry::var`], and the README table matches the registry.
//!
//! Any finding can be silenced at the site with a justified waiver:
//! `// analyze: allow(<check>): <reason>` on the same line or the line
//! above. Waivers without a reason, for unknown checks, or that match
//! nothing are themselves findings — the waiver budget stays honest.
//! The report is deterministic (sorted by file, line, check) so the
//! tier-1 test `rust/tests/static_analysis.rs` can pin the tree clean.

mod checks;
pub mod env_registry;
pub mod lexer;

use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Check identifiers a waiver may name.
pub const CHECKS: &[&str] = &[
    "atomics",
    "env_registry",
    "fault_seams",
    "lock_order",
    "panic_policy",
];

/// One violation at a deterministic `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub check: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// The result of analyzing a tree.
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
    pub waivers_used: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report, one `file:line: [check] message` per
    /// finding, sorted, with a one-line summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            let _ = writeln!(s, "{}:{}: [{}] {}", f.file, f.line, f.check, f.message);
        }
        let _ = write!(
            s,
            "analyze: {} files, {} finding{}, {} waiver{} honored",
            self.files,
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" },
            self.waivers_used,
            if self.waivers_used == 1 { "" } else { "s" },
        );
        s
    }

    /// Machine-readable report for `codr analyze --json`.
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::Obj(vec![
                    ("check".into(), Json::str(f.check)),
                    ("file".into(), Json::str(&f.file)),
                    ("line".into(), Json::u64(u64::from(f.line))),
                    ("message".into(), Json::str(&f.message)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("clean".into(), Json::Bool(self.is_clean())),
            ("files".into(), Json::usize(self.files)),
            ("waivers_used".into(), Json::usize(self.waivers_used)),
            ("findings".into(), Json::Arr(findings)),
        ])
        .to_pretty_string()
    }
}

/// Analyze one source string as the file `rel` (fixture entry point;
/// skips the cross-file registry/README checks). Returns sorted,
/// waiver-filtered findings.
pub fn analyze_source(rel: &str, src: &str) -> Vec<Finding> {
    let mut used_env = BTreeSet::new();
    let (mut findings, _) = analyze_file(rel, src, &mut used_env);
    sort(&mut findings);
    findings
}

/// Analyze every `.rs` file under `src_root` plus the cross-file
/// invariants (dead registry rows, README env table).
pub fn analyze_tree(src_root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)
        .with_context(|| format!("walking {}", src_root.display()))?;
    files.sort();
    if files.is_empty() {
        bail!("no .rs files under {}", src_root.display());
    }

    let mut findings = Vec::new();
    let mut used_env = BTreeSet::new();
    let mut waivers_used = 0usize;
    let mut registry_src = None;
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if rel.ends_with("analysis/env_registry.rs") {
            registry_src = Some(src.clone());
        }
        let (mut fs, used) = analyze_file(&rel, &src, &mut used_env);
        findings.append(&mut fs);
        waivers_used += used;
    }

    // Dead registry rows: registered but never referenced anywhere else.
    for v in env_registry::ENV_VARS {
        if !used_env.contains(v.name) {
            let line = registry_src
                .as_deref()
                .and_then(|s| {
                    s.lines()
                        .position(|l| l.contains(&format!("\"{}\"", v.name)))
                })
                .map_or(1, |p| p as u32 + 1);
            findings.push(Finding {
                check: "env_registry",
                file: "analysis/env_registry.rs".into(),
                line,
                message: format!("`{}` is registered but never read — remove the row", v.name),
            });
        }
    }

    readme_check(src_root, &mut findings);
    sort(&mut findings);
    Ok(Report {
        findings,
        files: files.len(),
        waivers_used,
    })
}

/// `rust/src` resolved from the current directory, falling back to the
/// build-time manifest dir (so `codr analyze` works from a checkout and
/// `cargo test` works from anywhere).
pub fn default_src_root() -> PathBuf {
    let local = Path::new("rust/src");
    if local.is_dir() {
        return local.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
}

fn sort(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.check, a.message.as_str())
            .cmp(&(b.file.as_str(), b.line, b.check, b.message.as_str()))
    });
}

/// Lex, run every check, then apply waivers. Returns the surviving
/// findings (plus waiver-hygiene findings) and the count of honored
/// waivers.
fn analyze_file(
    rel: &str,
    src: &str,
    used_env: &mut BTreeSet<String>,
) -> (Vec<Finding>, usize) {
    let out = lexer::lex(src);
    let mut raw = Vec::new();
    checks::run(rel, &out.tokens, &mut raw);
    env_registry::check_file(rel, &out.tokens, &mut raw, used_env);

    let mut used = vec![false; out.waivers.len()];
    let mut findings: Vec<Finding> = Vec::new();
    for f in raw {
        let waived = out.waivers.iter().enumerate().any(|(i, w)| {
            let hit = w.check == f.check && (w.line == f.line || w.line + 1 == f.line);
            if hit {
                used[i] = true;
            }
            hit
        });
        if !waived {
            findings.push(f);
        }
    }
    for (line, text) in &out.malformed {
        findings.push(Finding {
            check: "waiver",
            file: rel.to_string(),
            line: *line,
            message: format!(
                "malformed waiver `{text}` — syntax is `analyze: allow(<check>): <reason>`"
            ),
        });
    }
    for (i, w) in out.waivers.iter().enumerate() {
        if !CHECKS.contains(&w.check.as_str()) {
            findings.push(Finding {
                check: "waiver",
                file: rel.to_string(),
                line: w.line,
                message: format!("waiver names unknown check `{}`", w.check),
            });
        } else if !used[i] {
            findings.push(Finding {
                check: "waiver",
                file: rel.to_string(),
                line: w.line,
                message: format!(
                    "unused waiver for `{}` — nothing fires here; remove it",
                    w.check
                ),
            });
        }
    }
    let honored = used.iter().filter(|&&u| u).count();
    (findings, honored)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Validate the README env table against the registry. The README lives
/// two levels above `rust/src`; if the layout differs (fixture trees),
/// absence of a README is not a finding, but a README without markers
/// or with a stale table is.
fn readme_check(src_root: &Path, findings: &mut Vec<Finding>) {
    let candidates = [
        src_root.join("../../README.md"),
        src_root.join("../README.md"),
    ];
    let Some(text) = candidates
        .iter()
        .find_map(|p| std::fs::read_to_string(p).ok())
    else {
        return;
    };
    let (b, e) = (env_registry::README_BEGIN, env_registry::README_END);
    let block = text.find(b).and_then(|i| {
        let after = i + b.len();
        text[after..].find(e).map(|j| text[after..after + j].trim())
    });
    match block {
        None => findings.push(Finding {
            check: "env_registry",
            file: "README.md".into(),
            line: 1,
            message: format!("README has no `{b}` … `{e}` block for the env-var table"),
        }),
        Some(got) if got != env_registry::render_table().trim() => {
            let line = text[..text.find(b).unwrap_or(0)].lines().count() as u32 + 1;
            findings.push(Finding {
                check: "env_registry",
                file: "README.md".into(),
                line,
                message: "README env-var table is stale — regenerate with \
                          `codr analyze --print-env-table`"
                    .into(),
            });
        }
        Some(_) => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waiver_same_line_and_line_above() {
        let src = "fn f() { x.unwrap(); // analyze: allow(panic_policy): test helper\n}\n";
        assert!(analyze_source("serve/x.rs", src).is_empty());
        let src2 = "fn f() {\n    // analyze: allow(panic_policy): startup only\n    x.unwrap();\n}\n";
        assert!(analyze_source("serve/x.rs", src2).is_empty());
    }

    #[test]
    fn waiver_wrong_check_does_not_silence() {
        let src = "fn f() {\n    // analyze: allow(atomics): wrong check\n    x.unwrap();\n}\n";
        let fs = analyze_source("serve/x.rs", src);
        // The unwrap still fires and the waiver is reported unused.
        assert_eq!(fs.len(), 2, "{fs:?}");
        assert!(fs.iter().any(|f| f.check == "panic_policy"));
        assert!(fs.iter().any(|f| f.check == "waiver"));
    }

    #[test]
    fn unknown_check_in_waiver_is_flagged() {
        let src = "// analyze: allow(bogus): reason here\nfn f() {}\n";
        let fs = analyze_source("sim/x.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].check, "waiver");
        assert!(fs[0].message.contains("bogus"));
    }

    #[test]
    fn report_renders_deterministically() {
        let r = Report {
            findings: vec![Finding {
                check: "atomics",
                file: "a.rs".into(),
                line: 3,
                message: "m".into(),
            }],
            files: 2,
            waivers_used: 1,
        };
        assert_eq!(
            r.render(),
            "a.rs:3: [atomics] m\nanalyze: 2 files, 1 finding, 1 waiver honored"
        );
        assert!(r.to_json().contains("\"clean\": false"));
    }
}
