//! The four token-level invariant checks: lock order, atomic orderings,
//! panic policy, and fault-seam coverage. (The fifth check — the env-var
//! registry — lives in [`super::env_registry`] beside the table it
//! validates.)
//!
//! Every check pattern-matches the lexed token stream (comments and
//! string literals are already out of band, `#[cfg(test)]` spans are
//! marked), reports deterministic `file:line` findings, and can be
//! silenced per-site by a justified waiver comment. None of them parse
//! Rust for real; each knows exactly the idioms this codebase uses, and
//! the fixture tests in `rust/tests/static_analysis.rs` pin that the
//! known-bad shapes still fire.

use super::lexer::Tok;
use super::Finding;

/// Run every token-level check over one file.
pub(super) fn run(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    panic_policy(rel, toks, out);
    atomics(rel, toks, out);
    lock_order(rel, toks, out);
    fault_seams(rel, toks, out);
}

fn is_path_sep(toks: &[Tok], i: usize) -> bool {
    i >= 2 && toks[i - 1].is_punct(':') && toks[i - 2].is_punct(':')
}

// ---------------------------------------------------------------- panic

/// Directories where a panic is an outage, not a bug report: the serve
/// request paths, the worker pool, and the fault registry itself.
fn in_panic_scope(rel: &str) -> bool {
    rel.starts_with("serve/") || rel == "coordinator/pool.rs" || rel.starts_with("faults/")
}

fn panic_policy(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_panic_scope(rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        let Some(name) = t.ident() else { continue };
        let next_is = |c| toks.get(i + 1).is_some_and(|n: &Tok| n.is_punct(c));
        match name {
            "unwrap" | "expect" if i > 0 && toks[i - 1].is_punct('.') && next_is('(') => {
                out.push(Finding {
                    check: "panic_policy",
                    file: rel.to_string(),
                    line: t.line,
                    message: format!(
                        "`.{name}()` outside #[cfg(test)] in a no-panic zone — \
                         return a structured error (or waive with a reason)"
                    ),
                });
            }
            "panic" | "unreachable" | "todo" | "unimplemented" if next_is('!') => {
                out.push(Finding {
                    check: "panic_policy",
                    file: rel.to_string(),
                    line: t.line,
                    message: format!("`{name}!` outside #[cfg(test)] in a no-panic zone"),
                });
            }
            _ => {}
        }
    }
}

// -------------------------------------------------------------- atomics

/// Sites where `Ordering::Relaxed` is the *point*: independent
/// statistics counters and reference/tombstone bits whose readers
/// tolerate staleness by design. Everything else — stop flags,
/// generation tags, cross-thread handshakes — must use
/// Acquire/Release or stronger. The `why` column is the audit trail.
struct RelaxedAllow {
    file: &'static str,
    atomic: &'static str,
    #[allow(dead_code)]
    why: &'static str,
}

const RELAXED_OK: &[RelaxedAllow] = &[
    RelaxedAllow { file: "reuse/memo.rs", atomic: "next", why: "arena slot counter; publication is the per-segment OnceLock" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "bytes", why: "footprint statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "NEXT_STRIPE", why: "round-robin stripe assignment; any interleaving is fine" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "NEXT_ID", why: "thread-id allocator for L1 slots; uniqueness only" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "lookups", why: "striped statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "l1_hits", why: "striped statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "l2_hits", why: "statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "misses", why: "statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "collision_verifies", why: "statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "double_computes", why: "statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "lock_waits", why: "statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "evictions", why: "statistic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "entries", why: "approximate occupancy gauge; exact bookkeeping is under the shard lock" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "hot", why: "second-chance reference bit; pure eviction heuristic" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "dead", why: "tombstone bit; snapshot walkers tolerate staleness by design" },
    RelaxedAllow { file: "reuse/memo.rs", atomic: "tombstoned", why: "tombstoned-bytes gauge; the swap on `dead` is the only publication edge" },
    RelaxedAllow { file: "util/bench.rs", atomic: "extract_ns", why: "phase-time accumulator" },
    RelaxedAllow { file: "util/bench.rs", atomic: "transform_ns", why: "phase-time accumulator" },
    RelaxedAllow { file: "util/bench.rs", atomic: "price_ns", why: "phase-time accumulator" },
    RelaxedAllow { file: "faults/mod.rs", atomic: "remaining", why: "independent shot budget; the fetch_update claim is atomic on its own" },
    RelaxedAllow { file: "serve/store.rs", atomic: "TMP_SEQ", why: "temp-file name uniquifier; uniqueness only" },
    RelaxedAllow { file: "serve/metrics.rs", atomic: "counter", why: "monotonic per-verb counters funneled through bump()/read(); independent statistics, conservation is checked only at quiescence" },
];

fn atomics(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.in_test || !t.is_ident("Relaxed") || !is_path_sep(toks, i) {
            continue;
        }
        if !toks.get(i.wrapping_sub(3)).is_some_and(|o| o.is_ident("Ordering")) {
            continue;
        }
        let (recv, method) = call_receiver(toks, i)
            .unwrap_or_else(|| ("?".to_string(), "?".to_string()));
        let allowed = RELAXED_OK
            .iter()
            .any(|a| rel.ends_with(a.file) && a.atomic == recv);
        if !allowed {
            out.push(Finding {
                check: "atomics",
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "`{recv}.{method}(Ordering::Relaxed)` is not an allowlisted striped \
                     counter — control flags and tags need Acquire/Release or stronger"
                ),
            });
        }
    }
}

/// For a token inside a call's argument list, walk back to the call's
/// opening paren and name the method and its receiver:
/// `self.l2_hits.fetch_add(1, Ordering::Relaxed)` → (`l2_hits`, `fetch_add`).
fn call_receiver(toks: &[Tok], at: usize) -> Option<(String, String)> {
    let mut depth = 0i32;
    let mut j = at;
    while j > 0 && at - j < 120 {
        j -= 1;
        if toks[j].is_punct(')') {
            depth += 1;
        } else if toks[j].is_punct('(') {
            if depth == 0 {
                let method = toks.get(j.checked_sub(1)?)?.ident()?.to_string();
                let recv = j
                    .checked_sub(3)
                    .filter(|_| toks[j - 2].is_punct('.'))
                    .and_then(|k| toks[k].ident())
                    .unwrap_or("?")
                    .to_string();
                return Some((recv, method));
            }
            depth -= 1;
        }
    }
    None
}

// ----------------------------------------------------------- lock order

/// The declared hierarchy, outermost first. Acquiring a *lower* tier
/// while a higher tier is held is an inversion (the arena is tier 6 and
/// lock-free, so it never appears as an acquisition). Mutexes not named
/// here — job channels, claim lists, journal file, stats, the executor
/// pool's queue/threads, the reactor notifier's inbox — are leaves: they
/// never wrap another acquisition in this codebase and stay out of the
/// ranking rather than encode a false order.
const LOCK_TIERS: &[(&str, u8)] = &[
    ("maintenance", 0), // ring maintenance pass (outermost; wraps store locks)
    ("jobs", 1),        // server job table
    ("inflight", 2),    // scheduler claim set
    ("save_lock", 3),   // store read-modify-write serialization
    ("shard", 5),       // memo shard (via receiver name)
    ("shards", 5),
];

const PACK_LOCK_TIER: u8 = 4; // cross-process advisory pack lock

fn tier_name(t: u8) -> &'static str {
    match t {
        0 => "ring maintenance",
        1 => "server jobs",
        2 => "scheduler inflight",
        3 => "store save_lock",
        4 => "pack lock",
        5 => "memo shard",
        _ => "?",
    }
}

fn lock_order(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    // (tier, brace depth at acquisition, line); cleared per function.
    let mut held: Vec<(u8, i32, u32)> = Vec::new();
    let mut depth = 0i32;
    let mut fn_depth: Option<i32> = None;
    let mut pending_fn = false;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.is_punct('{') {
            depth += 1;
            if pending_fn {
                pending_fn = false;
                fn_depth = Some(depth);
                held.clear();
            }
            continue;
        }
        if t.is_punct('}') {
            depth -= 1;
            held.retain(|&(_, d, _)| d <= depth);
            if fn_depth.is_some_and(|fd| depth < fd) {
                fn_depth = None;
                held.clear();
            }
            continue;
        }
        if t.in_test {
            continue;
        }
        if t.is_ident("fn") {
            pending_fn = true;
            held.clear();
            continue;
        }
        let Some(tier) = acquisition_tier(toks, i) else {
            continue;
        };
        for &(h, _, hline) in &held {
            if h > tier {
                out.push(Finding {
                    check: "lock_order",
                    file: rel.to_string(),
                    line: t.line,
                    message: format!(
                        "lock-order inversion: acquiring {} (tier {tier}) while \
                         holding {} (tier {h}, taken at line {hline})",
                        tier_name(tier),
                        tier_name(h),
                    ),
                });
                break;
            }
        }
        held.push((tier, depth, t.line));
    }
}

/// Does the token at `i` acquire a ranked lock, and at which tier?
fn acquisition_tier(toks: &[Tok], i: usize) -> Option<u8> {
    let t = &toks[i];
    let next_is = |c| toks.get(i + 1).is_some_and(|n: &Tok| n.is_punct(c));
    let name = t.ident()?;
    match name {
        // Method form: `recv.lock()` / `recv.try_lock()`.
        "lock" | "try_lock" if i >= 2 && toks[i - 1].is_punct('.') && next_is('(') => {
            let recv = toks[i - 2].ident()?;
            ranked(recv)
        }
        // Helper form: `sync::lock(&path.to.mutex)` — rank the last
        // path identifier before the closing paren or an index.
        "lock" if next_is('(') && !(i >= 1 && toks[i - 1].is_punct('.')) => {
            let mut last = None;
            let mut j = i + 2;
            while j < toks.len() {
                let t = &toks[j];
                if let Some(id) = t.ident() {
                    last = Some(id);
                } else if !(t.is_punct('&') || t.is_punct('.')) {
                    break; // `)`, `[`, `,`, nested call — stop
                }
                j += 1;
            }
            ranked(last?)
        }
        // Memo shard access helpers.
        "lock_shard" | "shard_of" if next_is('(') => Some(5),
        // Cross-process pack lock: `PackLock::acquire…(…)`.
        "PackLock" => {
            let m = toks.get(i + 3)?;
            if toks.get(i + 1).is_some_and(|a: &Tok| a.is_punct(':'))
                && toks.get(i + 2).is_some_and(|a: &Tok| a.is_punct(':'))
                && m.ident().is_some_and(|s| s.starts_with("acquire"))
            {
                Some(PACK_LOCK_TIER)
            } else {
                None
            }
        }
        _ => None,
    }
}

fn ranked(recv: &str) -> Option<u8> {
    LOCK_TIERS
        .iter()
        .find(|(n, _)| *n == recv)
        .map(|&(_, t)| t)
}

// ---------------------------------------------------------- fault seams

const SEAM_CALLS: &[&str] = &[
    "point",
    "panic_point",
    "sleep_point",
    "torn_point",
    "bitflip_point",
];

/// Durability edges (`fs::rename`, `create_new`) must be injectable: the
/// enclosing function either calls a `faults::…` seam or the edge
/// carries a waiver explaining why a crash there is already covered.
fn fault_seams(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    let spans = fn_spans(toks);
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.in_test {
            continue;
        }
        let next_is = |c| toks.get(i + 1).is_some_and(|n: &Tok| n.is_punct(c));
        let edge = match t.ident() {
            Some("rename") if is_path_sep(toks, i) && next_is('(') => "fs::rename",
            Some("create_new") if i > 0 && toks[i - 1].is_punct('.') && next_is('(') => {
                "create_new"
            }
            _ => continue,
        };
        // Innermost function body containing the edge.
        let span = spans
            .iter()
            .filter(|&&(s, e)| s <= i && i < e)
            .max_by_key(|&&(s, _)| s);
        let covered = span.is_some_and(|&(s, e)| {
            (s..e).any(|k| {
                toks[k]
                    .ident()
                    .is_some_and(|id| SEAM_CALLS.contains(&id))
                    && is_path_sep(toks, k)
                    && toks
                        .get(k.wrapping_sub(3))
                        .is_some_and(|f| f.is_ident("faults"))
            })
        });
        if !covered {
            out.push(Finding {
                check: "fault_seams",
                file: rel.to_string(),
                line: t.line,
                message: format!(
                    "durability edge (`{edge}`) with no faults:: seam in the same \
                     function — crashes here ship uninjectable"
                ),
            });
        }
    }
}

/// Body spans `(start, end)` (token indexes just inside the braces) of
/// every `fn` in the stream. Bodyless signatures are skipped.
fn fn_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is_ident("fn") {
            i += 1;
            continue;
        }
        // Scan the header for the body `{` (or a `;` — no body).
        let mut wrap = 0i32;
        let mut j = i + 1;
        let mut body = None;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('(') || t.is_punct('[') {
                wrap += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                wrap -= 1;
            } else if wrap == 0 && t.is_punct(';') {
                break;
            } else if wrap == 0 && t.is_punct('{') {
                body = Some(j + 1);
                break;
            }
            j += 1;
        }
        let Some(start) = body else {
            i = j + 1;
            continue;
        };
        let mut braces = 1usize;
        let mut k = start;
        while k < toks.len() && braces > 0 {
            if toks[k].is_punct('{') {
                braces += 1;
            } else if toks[k].is_punct('}') {
                braces -= 1;
            }
            k += 1;
        }
        spans.push((start, k.saturating_sub(1)));
        i += 1; // nested fns get their own (inner) spans
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let mut out = Vec::new();
        run(rel, &lex(src).tokens, &mut out);
        out
    }

    #[test]
    fn receiver_extraction_handles_chained_calls() {
        let toks = lex("self.arena.get(h).hot.swap(false, Ordering::Relaxed);").tokens;
        let at = toks.iter().position(|t| t.is_ident("Relaxed")).unwrap();
        assert_eq!(
            call_receiver(&toks, at),
            Some(("hot".into(), "swap".into()))
        );
    }

    #[test]
    fn receiver_extraction_handles_multiple_orderings() {
        let toks =
            lex("r.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));")
                .tokens;
        let last = toks.iter().rposition(|t| t.is_ident("Relaxed")).unwrap();
        assert_eq!(
            call_receiver(&toks, last),
            Some(("r".into(), "fetch_update".into()))
        );
    }

    #[test]
    fn fn_spans_cover_bodies_not_signatures() {
        let toks = lex("trait T { fn sig(&self); }\nfn real() { body(); }").tokens;
        let spans = fn_spans(&toks);
        assert_eq!(spans.len(), 1);
        let (s, e) = spans[0];
        assert!((s..e).any(|i| toks[i].is_ident("body")));
    }

    #[test]
    fn scope_filter_is_exact() {
        let src = "fn f() { x.unwrap(); }";
        assert_eq!(findings("serve/server.rs", src).len(), 1);
        assert_eq!(findings("coordinator/pool.rs", src).len(), 1);
        assert_eq!(findings("faults/mod.rs", src).len(), 1);
        assert!(findings("reuse/memo.rs", src).is_empty());
        assert!(findings("coordinator/mod.rs", src).is_empty());
    }
}
