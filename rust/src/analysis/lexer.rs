//! A comment/string-aware Rust lexer for the invariant checks — the
//! same hand-rolled spirit as [`crate::util::json`]: no regex crate, no
//! syn, just a byte cursor that knows exactly as much Rust surface
//! syntax as the checks need.
//!
//! What it gets right (and the unit tests pin): line comments, nested
//! block comments, string literals with escapes, raw strings
//! (`r#"…"#`, any hash depth, `b`/`br` prefixes), char literals vs
//! lifetimes (`'a'` vs `'a`), numeric literals (so `0..10` does not eat
//! the range dots), and `#[cfg(test)]` / `#[test]` item spans (marked
//! `in_test` so checks skip them). What it deliberately does not do:
//! full expression parsing — the checks pattern-match token windows.
//!
//! The lexer also collects **waiver comments**:
//!
//! ```text
//! // analyze: allow(panic_policy): worker panics are contained by run_isolated
//! ```
//!
//! A waiver names one check and must carry a non-empty reason; it
//! suppresses findings of that check on its own line or the line
//! directly below. Malformed directives are reported, not ignored —
//! a typo must not silently disable a check.

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone, PartialEq)]
pub struct Tok {
    pub line: u32,
    /// Inside a `#[cfg(test)]` / `#[test]` item span.
    pub in_test: bool,
    pub kind: Kind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Kind {
    /// Identifier or keyword.
    Ident(String),
    /// String literal contents (escapes kept raw; prefix/quotes/hashes
    /// stripped). Covers `"…"`, `b"…"`, and raw forms.
    Str(String),
    /// Any single punctuation byte (`::` arrives as two `:`).
    Punct(char),
    /// Numeric literal (value not needed by any check).
    Num,
    /// Char literal such as `'x'` or `'\n'`.
    Char,
    /// Lifetime such as `'a` (kept distinct so it never reads as an
    /// unterminated char literal).
    Lifetime,
}

impl Tok {
    pub fn is_ident(&self, name: &str) -> bool {
        matches!(&self.kind, Kind::Ident(s) if s == name)
    }

    pub fn is_punct(&self, c: char) -> bool {
        matches!(&self.kind, Kind::Punct(p) if *p == c)
    }

    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            Kind::Ident(s) => Some(s),
            _ => None,
        }
    }

    pub fn str_lit(&self) -> Option<&str> {
        match &self.kind {
            Kind::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// A parsed `// analyze: allow(<check>): <reason>` directive.
#[derive(Debug, Clone, PartialEq)]
pub struct Waiver {
    pub line: u32,
    pub check: String,
    pub reason: String,
}

/// Lexer output: the token stream (with test spans marked), the parsed
/// waivers, and any malformed waiver directives (line, complaint).
#[derive(Debug, Default)]
pub struct LexOut {
    pub tokens: Vec<Tok>,
    pub waivers: Vec<Waiver>,
    pub malformed: Vec<(u32, String)>,
}

/// Lex one source file. Never panics on any input: unterminated
/// constructs simply run to end-of-file.
pub fn lex(src: &str) -> LexOut {
    let b = src.as_bytes();
    let mut out = LexOut::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i + 2;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                parse_directive(&src[start..i], line, &mut out);
                // The newline itself is consumed by the next loop turn.
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // Nested block comments, per the Rust grammar.
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let tok_line = line;
                let (s, ni, nl) = scan_string(b, i + 1, line);
                out.tokens.push(Tok {
                    line: tok_line,
                    in_test: false,
                    kind: Kind::Str(s),
                });
                i = ni;
                line = nl;
            }
            b'\'' => {
                let (kind, ni) = scan_quote(b, i);
                out.tokens.push(Tok {
                    line,
                    in_test: false,
                    kind,
                });
                i = ni;
            }
            b'0'..=b'9' => {
                i += 1;
                while i < b.len() {
                    let d = b[i];
                    if d.is_ascii_alphanumeric() || d == b'_' {
                        i += 1;
                    } else if d == b'.'
                        && b.get(i + 1).is_some_and(|n| n.is_ascii_digit())
                    {
                        i += 1; // decimal point, not a `..` range
                    } else {
                        break;
                    }
                }
                out.tokens.push(Tok {
                    line,
                    in_test: false,
                    kind: Kind::Num,
                });
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                // Raw/byte string prefixes first: r"…", r#"…"#, b"…", br#"…"#.
                if let Some((s, ni, nl, tok_line)) = scan_raw_or_byte(b, i, line) {
                    out.tokens.push(Tok {
                        line: tok_line,
                        in_test: false,
                        kind: Kind::Str(s),
                    });
                    i = ni;
                    line = nl;
                    continue;
                }
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.tokens.push(Tok {
                    line,
                    in_test: false,
                    kind: Kind::Ident(src[start..i].to_string()),
                });
            }
            _ => {
                out.tokens.push(Tok {
                    line,
                    in_test: false,
                    kind: Kind::Punct(c as char),
                });
                i += 1;
            }
        }
    }
    mark_test_spans(&mut out.tokens);
    out
}

/// Scan a double-quoted string body starting just past the opening
/// quote. Returns (contents, next index, next line).
fn scan_string(b: &[u8], mut i: usize, mut line: u32) -> (String, usize, u32) {
    let start = i;
    while i < b.len() {
        match b[i] {
            b'\\' => i = (i + 2).min(b.len()), // skip the escaped byte
            b'"' => {
                let s = String::from_utf8_lossy(&b[start..i]).into_owned();
                return (s, i + 1, line);
            }
            b'\n' => {
                line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (String::from_utf8_lossy(&b[start..]).into_owned(), i, line)
}

/// `'` disambiguation: char literal vs lifetime.
fn scan_quote(b: &[u8], i: usize) -> (Kind, usize) {
    match b.get(i + 1) {
        Some(b'\\') => {
            // Escaped char literal: scan to the closing quote.
            let mut j = i + 2;
            while j < b.len() && b[j] != b'\'' {
                if b[j] == b'\\' {
                    j += 1; // e.g. '\\'
                }
                j += 1;
            }
            (Kind::Char, (j + 1).min(b.len()))
        }
        Some(&c) if c.is_ascii_alphanumeric() || c == b'_' => {
            if b.get(i + 2) == Some(&b'\'') {
                // 'x' — a one-character literal.
                (Kind::Char, i + 3)
            } else {
                // 'ident — a lifetime; consume the identifier.
                let mut j = i + 1;
                while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                (Kind::Lifetime, j)
            }
        }
        Some(&c) if c != b'\n' => {
            // Punctuation char literal such as '"' or '{'.
            if b.get(i + 2) == Some(&b'\'') {
                (Kind::Char, i + 3)
            } else {
                (Kind::Punct(c as char), i + 2)
            }
        }
        _ => (Kind::Punct('\''), i + 1),
    }
}

/// Raw and byte strings: `r"…"`, `r#"…"#…`, `b"…"`, `br##"…"##`, `rb` is
/// not Rust. Returns None when the ident at `i` is not a string prefix.
fn scan_raw_or_byte(b: &[u8], i: usize, line: u32) -> Option<(String, usize, u32, u32)> {
    let (raw, mut j) = match (b[i], b.get(i + 1)) {
        (b'b', Some(b'"')) => (false, i + 1),
        (b'b', Some(b'r')) => (true, i + 2),
        (b'r', Some(b'"')) | (b'r', Some(b'#')) => (true, i + 1),
        _ => return None,
    };
    if !raw {
        // b"…" — same body rules as a plain string.
        let (s, ni, nl) = scan_string(b, j + 1, line);
        return Some((s, ni, nl, line));
    }
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) != Some(&b'"') {
        return None; // `r` / `br` was an ordinary identifier after all
    }
    j += 1;
    let start = j;
    let tok_line = line;
    let mut nl = line;
    while j < b.len() {
        if b[j] == b'\n' {
            nl += 1;
            j += 1;
            continue;
        }
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && b.get(k) == Some(&b'#') {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                let s = String::from_utf8_lossy(&b[start..j]).into_owned();
                return Some((s, k, nl, tok_line));
            }
        }
        j += 1;
    }
    Some((
        String::from_utf8_lossy(&b[start..]).into_owned(),
        j,
        nl,
        tok_line,
    ))
}

/// Parse the text of one line comment for an `analyze:` directive.
fn parse_directive(comment: &str, line: u32, out: &mut LexOut) {
    let t = comment.trim_start_matches(['/', '!']).trim();
    let Some(rest) = t.strip_prefix("analyze:") else {
        return;
    };
    let rest = rest.trim();
    let parse = || -> Result<Waiver, String> {
        let body = rest
            .strip_prefix("allow(")
            .ok_or("expected `allow(<check>): <reason>`")?;
        let (check, tail) = body
            .split_once(')')
            .ok_or("unclosed `allow(` — missing `)`")?;
        let reason = tail
            .strip_prefix(':')
            .ok_or("missing `: <reason>` after allow(...)")?
            .trim();
        if check.trim().is_empty() {
            return Err("empty check name".into());
        }
        if reason.is_empty() {
            return Err("empty reason — every waiver must justify itself".into());
        }
        Ok(Waiver {
            line,
            check: check.trim().to_string(),
            reason: reason.to_string(),
        })
    };
    match parse() {
        Ok(w) => out.waivers.push(w),
        Err(e) => out.malformed.push((line, e.to_string())),
    }
}

/// Mark every token inside a `#[cfg(test)]`- or `#[test]`-attributed
/// item span (through the item's closing `}` or terminating `;`).
fn mark_test_spans(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].is_punct('#') && toks.get(i + 1).is_some_and(|t| t.is_punct('['))) {
            i += 1;
            continue;
        }
        // Attribute span: to the matching `]`.
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut saw_cfg = false;
        let mut saw_test = false;
        let mut saw_not = false;
        while j < toks.len() && depth > 0 {
            if toks[j].is_punct('[') {
                depth += 1;
            } else if toks[j].is_punct(']') {
                depth -= 1;
            } else if toks[j].is_ident("cfg") {
                saw_cfg = true;
            } else if toks[j].is_ident("test") {
                saw_test = true;
            } else if toks[j].is_ident("not") {
                // `#[cfg(not(test))]` is production code, not test code.
                saw_not = true;
            }
            j += 1;
        }
        let bare_test_attr = j == i + 4; // exactly `#[test]`
        let is_test_attr = saw_test && !saw_not && (saw_cfg || bare_test_attr);
        if !is_test_attr {
            i = j;
            continue;
        }
        // Mark from the attribute through the item it decorates: skip
        // further attributes, then either a `{ … }` body or a `;`.
        let span_start = i;
        let mut k = j;
        while k + 1 < toks.len() && toks[k].is_punct('#') && toks[k + 1].is_punct('[') {
            let mut d = 1usize;
            k += 2;
            while k < toks.len() && d > 0 {
                if toks[k].is_punct('[') {
                    d += 1;
                } else if toks[k].is_punct(']') {
                    d -= 1;
                }
                k += 1;
            }
        }
        // To the first `{` (then its match) or a top-level `;`.
        let mut wrap = 0i32; // (), [] nesting on the item header
        while k < toks.len() {
            if toks[k].is_punct('(') || toks[k].is_punct('[') {
                wrap += 1;
            } else if toks[k].is_punct(')') || toks[k].is_punct(']') {
                wrap -= 1;
            } else if toks[k].is_punct(';') && wrap == 0 {
                k += 1;
                break;
            } else if toks[k].is_punct('{') && wrap == 0 {
                let mut braces = 1usize;
                k += 1;
                while k < toks.len() && braces > 0 {
                    if toks[k].is_punct('{') {
                        braces += 1;
                    } else if toks[k].is_punct('}') {
                        braces -= 1;
                    }
                    k += 1;
                }
                break;
            }
            k += 1;
        }
        for t in toks[span_start..k].iter_mut() {
            t.in_test = true;
        }
        i = k;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.ident().map(str::to_string))
            .collect()
    }

    #[test]
    fn strings_hide_their_contents_from_the_token_stream() {
        let toks = lex(r#"let s = "a.unwrap() // not a comment"; x"#).tokens;
        assert!(toks.iter().all(|t| !t.is_ident("unwrap")));
        let lit = toks.iter().find_map(|t| t.str_lit()).unwrap();
        assert!(lit.contains("not a comment"));
        assert!(toks.iter().any(|t| t.is_ident("x")), "lexing continues");
    }

    #[test]
    fn escaped_quotes_do_not_terminate_strings() {
        let toks = lex(r#"let s = "say \"hi\" now"; done"#).tokens;
        assert_eq!(toks.iter().filter_map(|t| t.str_lit()).count(), 1);
        assert!(toks.iter().any(|t| t.is_ident("done")));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let s = r#\"quote \" inside\"#; let t = br##\"x\"# y\"##; tail";
        let toks = lex(src).tokens;
        let lits: Vec<_> = toks.iter().filter_map(|t| t.str_lit()).collect();
        assert_eq!(lits, vec!["quote \" inside", "x\"# y"]);
        assert!(toks.iter().any(|t| t.is_ident("tail")));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner */ still comment */ b";
        assert_eq!(idents(src), vec!["a", "b"]);
    }

    #[test]
    fn line_comments_end_at_newline() {
        let src = "a // unwrap() panic!\nb";
        let toks = lex(src).tokens;
        assert_eq!(idents(src), vec!["a", "b"]);
        assert_eq!(toks[1].line, 2);
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { m.insert('x', '\\n'); let q = '\"'; }";
        let toks = lex(src).tokens;
        let chars = toks.iter().filter(|t| t.kind == Kind::Char).count();
        let lifetimes = toks.iter().filter(|t| t.kind == Kind::Lifetime).count();
        assert_eq!(chars, 3, "{toks:?}");
        assert_eq!(lifetimes, 2);
    }

    #[test]
    fn generics_vs_shift_do_not_confuse_the_stream() {
        let src = "let v: Vec<Vec<u8>> = x(); let y = 1u64 << 20; let r = 0..10;";
        let toks = lex(src).tokens;
        assert!(toks.iter().any(|t| t.is_ident("Vec")));
        // `>>` arrives as two '>' puncts, `<<` as two '<': nothing is lost.
        assert_eq!(toks.iter().filter(|t| t.is_punct('<')).count(), 4);
        // `0..10` keeps its range dots.
        assert_eq!(toks.iter().filter(|t| t.is_punct('.')).count(), 2);
    }

    #[test]
    fn numeric_literals_swallow_suffixes_and_floats() {
        let toks = lex("let a = 1_000u64; let b = 2.5e3; let c = 0xFFu8;").tokens;
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Num).count(), 3);
    }

    #[test]
    fn cfg_test_items_are_marked() {
        let src = "fn live() { a.unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n fn t() { b.unwrap(); }\n}\n\
                   fn live2() { c.expect(\"x\"); }";
        let toks = lex(src).tokens;
        let unwraps: Vec<bool> = toks
            .iter()
            .filter(|t| t.is_ident("unwrap") || t.is_ident("expect"))
            .map(|t| t.in_test)
            .collect();
        assert_eq!(unwraps, vec![false, true, false]);
    }

    #[test]
    fn test_attr_marks_single_fn() {
        let src = "#[test]\nfn check_it() { x.unwrap(); }\nfn live() { y.unwrap(); }";
        let toks = lex(src).tokens;
        let unwraps: Vec<bool> = toks
            .iter()
            .filter(|t| t.is_ident("unwrap"))
            .map(|t| t.in_test)
            .collect();
        assert_eq!(unwraps, vec![true, false]);
    }

    #[test]
    fn waiver_parsing() {
        let out = lex("// analyze: allow(panic_policy): poisoning is survived upstream\nx();");
        assert_eq!(out.waivers.len(), 1);
        let w = &out.waivers[0];
        assert_eq!((w.line, w.check.as_str()), (1, "panic_policy"));
        assert!(w.reason.contains("survived"));
        assert!(out.malformed.is_empty());
    }

    #[test]
    fn malformed_waivers_are_reported_not_dropped() {
        for bad in [
            "// analyze: allow(panic_policy)",       // no reason
            "// analyze: allow(panic_policy):    ",  // empty reason
            "// analyze: allow panic_policy: why",   // missing parens
            "// analyze: allow(): why",              // empty check
        ] {
            let out = lex(bad);
            assert!(out.waivers.is_empty(), "{bad}");
            assert_eq!(out.malformed.len(), 1, "{bad}");
        }
        // Unrelated comments are not directives at all.
        assert!(lex("// analyzer of things").malformed.is_empty());
    }

    #[test]
    fn never_panics_on_hostile_input() {
        for src in [
            "\"unterminated",
            "r#\"unterminated",
            "/* unterminated",
            "'",
            "'\\",
            "b\"",
            "r###",
            "#[cfg(test)]",
            "#[",
        ] {
            let _ = lex(src);
        }
    }
}
