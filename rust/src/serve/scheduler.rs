//! Incremental grid scheduler: diff a requested (model × group × arch)
//! grid against the result store and simulate only what is missing.
//!
//! Five properties matter here:
//!
//! 1. **Incrementality** — points already in the store are loaded, not
//!    simulated; corrupt entries are recomputed and overwritten. The
//!    diff reads one *pack* per (model, group) ([`ResultStore::load_group`]),
//!    not one file per point.
//! 2. **Workload batching** — missing points that share a (model, group)
//!    pair are dispatched as one batch so the synthetic weights are
//!    generated once and reused by every design, mirroring the
//!    coordinator's storeless fan-out.
//! 3. **In-flight dedup** — when two requests (e.g. two `codr serve`
//!    clients) need the same point concurrently, the second waits for the
//!    first instead of burning a second simulation; claims are released
//!    on unwind, so a failed claimant degrades to the waiter computing
//!    the point itself, never to a hung server.
//! 4. **Streaming claim release** — the per-(arch, layer, tile-chunk)
//!    fan-out keeps two levels of completion counters: the worker
//!    finishing a layer's last chunk merges and prices that layer, and
//!    the worker finishing a point's *last* layer assembles it,
//!    persists it, and releases its claim right there. A concurrent
//!    request waiting on one point wakes as soon as that point is done,
//!    not after the claimant's whole grid — and a point's giant conv
//!    layers no longer serialize its tail on one worker.
//! 5. **Panic isolation** — every chunk/finalize/assemble computation
//!    runs under [`pool::run_isolated`]; a panicking task dooms only its
//!    own point (reported with the panic message via [`PointDone::error`]
//!    and counted in [`SweepStats::failed`]), while its completion
//!    counters, claim release, and waiter wakeups all still run. One
//!    crashing chunk can no longer hang the server or strand a grid.
//!
//! Results are returned in (model × group) then arch order — identical to
//! the storeless sweep, so figure output is byte-for-byte the same
//! whether it came from silicon^W simulation or from disk.
//!
//! Completed points can additionally be *observed*:
//! [`Scheduler::run_grid_observed`] fires a [`Progress`] callback the
//! moment each point resolves (store hit, streaming per-point assembly,
//! or dedup) — the serve `submit` path publishes these into per-job
//! broadcast channels. Each publish pokes the reactor's wake pipe, and
//! the event loop fans the new events out to every watching connection
//! as nonblocking writes; no thread ever parks on a job channel.

use super::store::{CacheKey, LoadOutcome, ResultStore};
use crate::arch::MemConfig;
use crate::codr::Codr;
use crate::coordinator::{
    finalize_layer, layer_chunks, pool, simulate_layer_chunk, Arch, LayerPartial, SweepResults,
    SweepStats,
};
use crate::mapping::search::{search_layer, SearchConfig, SearchReport};
use crate::mapping::CandidateResult;
use crate::models::{Model, SweepGroup, Workload};
use crate::reuse::memo;
use crate::sim::{simulate_model, Accelerator, LayerResult, ModelResult};
use crate::util::sync;
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One grid point, addressed by indices into the request plus its store
/// key.
struct Point {
    mi: usize,
    gi: usize,
    ai: usize,
    key: CacheKey,
}

/// One completed grid point, as reported to a [`Progress`] observer the
/// moment the point resolves (store hit, streaming per-point assembly,
/// or dedup against another request).
pub struct PointDone<'a> {
    pub model: &'a str,
    pub group: String,
    pub arch: &'a str,
    /// The point came from the store (or another request's computation)
    /// rather than being simulated by this grid run.
    pub cache_hit: bool,
    /// Set when the point's computation panicked (contained by
    /// [`pool::run_isolated`]): the panic message. The point produced no
    /// result, nothing was persisted, and the grid completes with
    /// `stats.failed > 0` (`state:"partial"` over the wire).
    pub error: Option<&'a str>,
}

/// Per-point completion observer. `Sync` because computed points report
/// from inside the worker pool — the thread finishing a point's last
/// layer fires the callback right after releasing the point's claim, so
/// observers see progress as it streams, not after the grid.
pub type Progress<'a> = &'a (dyn Fn(&PointDone<'_>) + Sync);

/// Missing points sharing one (model, group) — one workload synthesis.
struct Batch<'a> {
    model: &'a Model,
    group: SweepGroup,
}

/// Per-layer chunk fan-in: tile-chunk tasks drop their partials here,
/// and whoever decrements `remaining` to zero merges and prices the
/// layer right there in the pool.
struct LayerFan {
    parts: Vec<Mutex<Option<LayerPartial>>>,
    remaining: AtomicUsize,
}

/// Per-point assembly state for the two-level fan-out (layers →
/// tile chunks): chunk finishers reduce their layer into
/// `layer_results`, and whoever decrements `layers_remaining` to zero
/// assembles/persists the point and releases its claim.
struct PointSlot {
    bi: usize,
    point: Point,
    fans: Vec<LayerFan>,
    layer_results: Vec<Mutex<Option<LayerResult>>>,
    layers_remaining: AtomicUsize,
    result: Mutex<Option<ModelResult>>,
    /// First panic message from any of this point's tasks. Once set the
    /// point is doomed: remaining chunks still run (and decrement the
    /// counters — waiters depend on that), but finalize/assemble/save
    /// are skipped and the point reports as failed instead of done.
    error: Mutex<Option<String>>,
}

impl PointSlot {
    /// Record the first failure; later ones lose (one message per point
    /// is enough, and the first is usually the root cause).
    fn fail(&self, msg: String) {
        let mut e = sync::lock(&self.error);
        if e.is_none() {
            *e = Some(msg);
        }
    }

    /// Visibility contract: a chunk stores its error *before* its
    /// counter decrement (AcqRel), so whoever observes the final
    /// decrement of a fan — or of `layers_remaining` — sees every error
    /// recorded by the tasks that decrement fed into it.
    fn failure(&self) -> Option<String> {
        sync::lock(&self.error).clone()
    }
}

/// Long-lived scheduler over one result store. `codr serve` keeps a
/// single instance so in-flight dedup spans connections; one-shot CLI
/// paths build a transient one per sweep.
pub struct Scheduler {
    store: ResultStore,
    inflight: Mutex<HashSet<u64>>,
    released: Condvar,
}

/// Tracks claimed fingerprints; releases the remainder even if the
/// claimant unwinds. [`Self::release_one`] streams individual claims
/// back mid-flight (and is safe to race with the final drop — a
/// fingerprint leaves the list exactly once).
struct ClaimGuard<'a> {
    sched: &'a Scheduler,
    claims: Mutex<Vec<u64>>,
}

impl ClaimGuard<'_> {
    /// Release one claim now (point finished or turned out to be a hit),
    /// waking every waiter.
    fn release_one(&self, fp: u64) {
        {
            let mut claims = sync::lock(&self.claims);
            let Some(i) = claims.iter().position(|&c| c == fp) else {
                return; // already released
            };
            claims.swap_remove(i);
        }
        sync::lock(&self.sched.inflight).remove(&fp);
        self.sched.released.notify_all();
    }
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        let claims: Vec<u64> = std::mem::take(sync::get_mut(&mut self.claims));
        if claims.is_empty() {
            return;
        }
        let mut inflight = sync::lock(&self.sched.inflight);
        for c in &claims {
            inflight.remove(c);
        }
        drop(inflight);
        self.sched.released.notify_all();
    }
}

impl Scheduler {
    pub fn new(store: ResultStore) -> Scheduler {
        Scheduler {
            store,
            inflight: Mutex::new(HashSet::new()),
            released: Condvar::new(),
        }
    }

    pub fn store(&self) -> &ResultStore {
        &self.store
    }

    /// Run one grid request through the store. See the module docs for
    /// the hit/miss/dedup semantics.
    pub fn run_grid(
        &self,
        models: &[Model],
        groups: &[SweepGroup],
        archs: &[Arch],
        seed: u64,
    ) -> SweepResults {
        self.run_grid_observed(models, groups, archs, seed, None)
    }

    /// [`Self::run_grid`] with a per-point completion observer. The serve
    /// `submit` path publishes each callback into the job's broadcast
    /// channel, which is what the `watch` verb streams to clients.
    pub fn run_grid_observed(
        &self,
        models: &[Model],
        groups: &[SweepGroup],
        archs: &[Arch],
        seed: u64,
        progress: Option<Progress<'_>>,
    ) -> SweepResults {
        let emit = |mi: usize, gi: usize, ai: usize, cache_hit: bool, error: Option<&str>| {
            if let Some(f) = progress {
                f(&PointDone {
                    model: models[mi].name,
                    group: groups[gi].label(),
                    arch: archs[ai].name(),
                    cache_hit,
                    error,
                });
            }
        };
        let t0 = Instant::now();
        let memo0 = memo::global().breakdown();
        let mem = MemConfig::default();
        let mut stats = SweepStats::default();
        let mut found: HashMap<(usize, usize, usize), ModelResult> = HashMap::new();
        let mut misses: Vec<Point> = Vec::new();

        // Phase 1: diff the grid against the store — one packed-file read
        // per (model, group) covers every arch of that point.
        for (mi, model) in models.iter().enumerate() {
            for (gi, group) in groups.iter().enumerate() {
                let keys: Vec<CacheKey> = archs
                    .iter()
                    .map(|arch| {
                        CacheKey::for_point(
                            model.name,
                            group,
                            arch.name(),
                            &arch.build().tile_config(),
                            &mem,
                            seed,
                        )
                    })
                    .collect();
                let outcomes = self.store.load_group(&keys);
                for (ai, (key, outcome)) in keys.into_iter().zip(outcomes).enumerate() {
                    stats.requested += 1;
                    let point = Point { mi, gi, ai, key };
                    match outcome {
                        LoadOutcome::Hit(r) => {
                            stats.cache_hits += 1;
                            emit(mi, gi, ai, true, None);
                            found.insert((mi, gi, ai), *r);
                        }
                        LoadOutcome::Corrupt => {
                            stats.corrupt += 1;
                            misses.push(point);
                        }
                        LoadOutcome::Miss => misses.push(point),
                    }
                }
            }
        }

        // Phase 2: claim what no other request is already computing. The
        // guard releases claims even if a later phase unwinds.
        let guard = ClaimGuard {
            sched: self,
            claims: Mutex::new(Vec::new()),
        };
        let mut claimed: Vec<Point> = Vec::new();
        let mut waited: Vec<Point> = Vec::new();
        {
            let mut inflight = sync::lock(&self.inflight);
            let mut claims = sync::lock(&guard.claims);
            for p in misses {
                if inflight.insert(p.key.fingerprint) {
                    claims.push(p.key.fingerprint);
                    claimed.push(p);
                } else {
                    waited.push(p);
                }
            }
        }

        // Double-checked locking: another request may have computed and
        // saved a point between our phase-1 miss and the claim. Now that
        // we hold the claim nobody else is writing it, so one re-read
        // settles it: a hit here releases the claim and skips the
        // simulation.
        let mut to_compute: Vec<Point> = Vec::new();
        for p in claimed {
            match self.store.load(&p.key) {
                LoadOutcome::Hit(r) => {
                    stats.cache_hits += 1;
                    guard.release_one(p.key.fingerprint);
                    emit(p.mi, p.gi, p.ai, true, None);
                    found.insert((p.mi, p.gi, p.ai), *r);
                }
                _ => to_compute.push(p),
            }
        }

        // Phase 3: batch claimed points by (model, group) so each
        // workload is synthesized once, then fan the layers out as
        // *tile-chunk* tasks — one pool task per (point, layer, chunk).
        // This is what lets a narrow grid (e.g. a single-model `warm`
        // with three archs) use every worker, and the chunking keeps a
        // point's giant conv layers from serializing its tail. Two
        // completion levels stream the work out: the worker finishing a
        // layer's last chunk merges and prices that layer; the worker
        // finishing a point's last layer assembles it, persists it, and
        // releases its claim immediately, so concurrent requests
        // waiting on one of our points wake per point, not after this
        // whole grid (ROADMAP "Streaming claim release" — closed).
        if !to_compute.is_empty() {
            let mut batches: Vec<Batch> = Vec::new();
            let mut by_pair: HashMap<(usize, usize), usize> = HashMap::new();
            let mut pending: Vec<(usize, Point)> = Vec::new();
            for p in to_compute {
                let bi = *by_pair.entry((p.mi, p.gi)).or_insert_with(|| {
                    batches.push(Batch {
                        model: &models[p.mi],
                        group: groups[p.gi],
                    });
                    batches.len() - 1
                });
                pending.push((bi, p));
            }
            let workloads = pool::parallel_map(&batches, |batch| {
                let (unique, density) = batch.group.knobs();
                Workload::generate(batch.model, unique, density, seed)
            });
            let slots: Vec<PointSlot> = pending
                .into_iter()
                .map(|(bi, point)| {
                    let arch = archs[point.ai];
                    let fans: Vec<LayerFan> = workloads[bi]
                        .conv_layers()
                        .map(|(spec, _)| {
                            let n_chunks = layer_chunks(arch, spec);
                            LayerFan {
                                parts: (0..n_chunks).map(|_| Mutex::new(None)).collect(),
                                remaining: AtomicUsize::new(n_chunks),
                            }
                        })
                        .collect();
                    let n_layers = fans.len();
                    PointSlot {
                        bi,
                        point,
                        fans,
                        layer_results: (0..n_layers).map(|_| Mutex::new(None)).collect(),
                        layers_remaining: AtomicUsize::new(n_layers),
                        result: Mutex::new(None),
                        error: Mutex::new(None),
                    }
                })
                .collect();
            let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
            for (si, slot) in slots.iter().enumerate() {
                for (li, fan) in slot.fans.iter().enumerate() {
                    for ci in 0..fan.parts.len() {
                        tasks.push((si, li, ci));
                    }
                }
            }
            pool::parallel_map(&tasks, |&(si, li, ci)| {
                let slot = &slots[si];
                let arch = archs[slot.point.ai];
                let (spec, w) = workloads[slot.bi]
                    .conv_layers()
                    .nth(li)
                    // analyze: allow(panic_policy): li comes from the task enumeration over these same workloads
                    .expect("task layer index");
                let fan = &slot.fans[li];
                // Each computation runs isolated: a panic (organic, or
                // injected at `pool.worker.panic`) dooms this point but
                // the bookkeeping below ALWAYS runs — counters
                // decrement, the claim releases, waiters wake. The
                // `sched.point.slow` seam stretches the compute window
                // so crash tests can kill the process mid-grid.
                crate::faults::sleep_point(
                    "sched.point.slow",
                    std::time::Duration::from_millis(250),
                );
                match pool::run_isolated(|| {
                    simulate_layer_chunk(arch, spec, w, ci, fan.parts.len())
                }) {
                    Ok(part) => *sync::lock(&fan.parts[ci]) = Some(part),
                    Err(msg) => slot.fail(msg),
                }
                if fan.remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
                    return;
                }
                // Last chunk of this layer: merge (chunk order) + price —
                // unless a chunk of THIS fan panicked (its error is
                // visible here, per the PointSlot::failure contract) and
                // left a hole in the partials.
                if slot.failure().is_none() {
                    match pool::run_isolated(|| {
                        let parts: Vec<LayerPartial> = fan
                            .parts
                            .iter()
                            // analyze: allow(panic_policy): inside run_isolated; a hole only exists if a chunk panicked, checked above
                            .map(|p| sync::lock(p).take().expect("chunk partial"))
                            .collect();
                        finalize_layer(arch, spec, &parts)
                    }) {
                        Ok(lr) => *sync::lock(&slot.layer_results[li]) = Some(lr),
                        Err(msg) => slot.fail(msg),
                    }
                }
                if slot.layers_remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
                    return;
                }
                // Last layer of the point: assemble, persist, release.
                // A failed point skips assembly and persistence but still
                // releases its claim (waiters recompute it themselves,
                // exactly as if the claimant process had died) and still
                // reports — with the error — so watchers see it resolve.
                if let Some(msg) = slot.failure() {
                    guard.release_one(slot.point.key.fingerprint);
                    emit(
                        slot.point.mi,
                        slot.point.gi,
                        slot.point.ai,
                        false,
                        Some(&msg),
                    );
                    return;
                }
                match pool::run_isolated(|| assemble(slot, &batches, archs)) {
                    Ok(result) => {
                        if let Err(e) = self.store.save(&slot.point.key, &result) {
                            eprintln!(
                                "warn: failed to persist {}: {e:#}",
                                slot.point.key.file_stem()
                            );
                        }
                        // Save attempt done (either way): waiters may now
                        // read the store or take the point over themselves.
                        guard.release_one(slot.point.key.fingerprint);
                        emit(slot.point.mi, slot.point.gi, slot.point.ai, false, None);
                        *sync::lock(&slot.result) = Some(result);
                    }
                    Err(msg) => {
                        slot.fail(msg);
                        // analyze: allow(panic_policy): fail() one line up guarantees Some
                        let msg = slot.failure().expect("just failed");
                        guard.release_one(slot.point.key.fingerprint);
                        emit(
                            slot.point.mi,
                            slot.point.gi,
                            slot.point.ai,
                            false,
                            Some(&msg),
                        );
                    }
                }
            });
            for slot in &slots {
                if let Some(msg) = slot.failure() {
                    stats.failed += 1;
                    eprintln!(
                        "warn: point {} failed: {msg}",
                        slot.point.key.file_stem()
                    );
                    continue; // nothing to insert — the job is partial
                }
                let assembled = sync::lock(&slot.result).take();
                let result = assembled.unwrap_or_else(|| {
                    // A zero-conv-layer model fans out no tasks; its
                    // (empty) result is assembled here and persisted for
                    // parity with the seed behavior.
                    let result = assemble(slot, &batches, archs);
                    if let Err(e) = self.store.save(&slot.point.key, &result) {
                        eprintln!(
                            "warn: failed to persist {}: {e:#}",
                            slot.point.key.file_stem()
                        );
                    }
                    guard.release_one(slot.point.key.fingerprint);
                    emit(slot.point.mi, slot.point.gi, slot.point.ai, false, None);
                    result
                });
                stats.computed += 1;
                stats.simulated_layers += result.layers.len();
                found.insert((slot.point.mi, slot.point.gi, slot.point.ai), result);
            }
        }
        drop(guard); // release any remaining claims, wake waiters

        // Phase 4: points another request was already computing — wait for
        // the claim to clear, then read the store. If the claimant failed
        // (no entry appeared), claim and compute the point ourselves.
        for p in waited {
            let (result, deduped) = self.wait_for_point(&p, models, groups, archs, seed, &mut stats);
            emit(p.mi, p.gi, p.ai, deduped, None);
            found.insert((p.mi, p.gi, p.ai), result);
        }

        // Assemble in the storeless sweep's order.
        let mut results = Vec::with_capacity(stats.requested);
        for mi in 0..models.len() {
            for gi in 0..groups.len() {
                for ai in 0..archs.len() {
                    if let Some(r) = found.remove(&(mi, gi, ai)) {
                        results.push(r);
                    }
                }
            }
        }
        let memo = memo::global().breakdown().since(&memo0);
        stats.memo_hits = memo.hits() as usize;
        stats.memo_misses = memo.misses as usize;
        stats.l1_hits = memo.l1_hits as usize;
        stats.l2_hits = memo.l2_hits as usize;
        stats.collision_verifies = memo.collision_verifies as usize;
        stats.lock_waits = memo.lock_waits as usize;
        stats.wall_ms = t0.elapsed().as_millis() as u64;
        SweepResults { results, stats }
    }

    /// Run a mapping-space search for one layer of `model` through this
    /// scheduler's store (every candidate is content-addressed by its
    /// derived tile configuration, so repeated searches warm from disk).
    /// `layer = None` searches the model's first conv layer. `progress`
    /// fires once per evaluated candidate, from pool threads.
    pub fn run_map(
        &self,
        model: &Model,
        layer: Option<&str>,
        group: SweepGroup,
        seed: u64,
        cfg: &SearchConfig,
        progress: Option<&(dyn Fn(&CandidateResult) + Sync)>,
    ) -> Result<SearchReport> {
        let (unique, density) = group.knobs();
        let workload = Workload::generate(model, unique, density, seed);
        let Some((spec, weights)) = workload.conv_layers().find(|(s, _)| match layer {
            Some(name) => s.name == name,
            None => true,
        }) else {
            match layer {
                Some(name) => bail!("model {} has no conv layer named `{name}`", model.name),
                None => bail!("model {} has no conv layers", model.name),
            }
        };
        Ok(search_layer(
            &Codr::default(),
            model.name,
            &group,
            seed,
            spec,
            weights,
            cfg,
            Some(&self.store),
            progress,
        ))
    }

    /// Returns the point's result plus whether it arrived by dedup (the
    /// claimant persisted it; `true`) or by this request taking the
    /// computation over (`false`).
    fn wait_for_point(
        &self,
        p: &Point,
        models: &[Model],
        groups: &[SweepGroup],
        archs: &[Arch],
        seed: u64,
        stats: &mut SweepStats,
    ) -> (ModelResult, bool) {
        loop {
            // Wait until no request holds a claim on this point.
            {
                let mut inflight = sync::lock(&self.inflight);
                while inflight.contains(&p.key.fingerprint) {
                    inflight = sync::wait(&self.released, inflight);
                }
            }
            match self.store.load(&p.key) {
                LoadOutcome::Hit(r) => {
                    stats.deduped += 1;
                    return (*r, true);
                }
                _ => {
                    // Claimant died or failed to persist: try to take over.
                    let claimed = sync::lock(&self.inflight).insert(p.key.fingerprint);
                    if !claimed {
                        continue; // someone else took over; wait again
                    }
                    let guard = ClaimGuard {
                        sched: self,
                        claims: Mutex::new(vec![p.key.fingerprint]),
                    };
                    let group = groups[p.gi];
                    let (unique, density) = group.knobs();
                    let workload = Workload::generate(&models[p.mi], unique, density, seed);
                    let acc = archs[p.ai].build();
                    let result = simulate_model(acc.as_ref(), &workload, &group.label());
                    if let Err(e) = self.store.save(&p.key, &result) {
                        eprintln!("warn: failed to persist {}: {e:#}", p.key.file_stem());
                    }
                    stats.computed += 1;
                    stats.simulated_layers += result.layers.len();
                    drop(guard);
                    return (result, false);
                }
            }
        }
    }
}

/// Build a point's [`ModelResult`] from its reduced layer slots.
fn assemble(slot: &PointSlot, batches: &[Batch], archs: &[Arch]) -> ModelResult {
    let layers: Vec<LayerResult> = slot
        .layer_results
        .iter()
        // analyze: allow(panic_policy): called only after layers_remaining hit zero with no failure recorded
        .map(|m| sync::lock(m).take().expect("assembled layer"))
        .collect();
    ModelResult {
        arch: archs[slot.point.ai].name().to_string(),
        model: batches[slot.bi].model.name.to_string(),
        group: batches[slot.bi].group.label(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_sweep;
    use crate::models::tiny_cnn;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn temp_store(tag: &str) -> ResultStore {
        let dir = std::env::temp_dir().join(format!(
            "codr-sched-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        ResultStore::open(dir).unwrap()
    }

    #[test]
    fn second_run_is_all_hits_with_zero_simulated_layers() {
        let store = temp_store("rerun");
        let sched = Scheduler::new(store.clone());
        let models = [tiny_cnn()];
        let groups = [SweepGroup::Original, SweepGroup::Density(50)];
        let archs = Arch::all();

        let cold = sched.run_grid(&models, &groups, &archs, 11);
        assert_eq!(cold.stats.requested, 6);
        assert_eq!(cold.stats.computed, 6);
        assert_eq!(cold.stats.cache_hits, 0);
        assert!(cold.stats.simulated_layers > 0);
        assert!(
            sched.inflight.lock().unwrap().is_empty(),
            "every claim must be released by the end of the grid"
        );

        let warm = sched.run_grid(&models, &groups, &archs, 11);
        assert_eq!(warm.stats.cache_hits, 6);
        assert_eq!(warm.stats.computed, 0);
        assert_eq!(warm.stats.simulated_layers, 0, "warm run must not simulate");
        // Same results, same order.
        assert_eq!(cold.results, warm.results);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn partial_store_computes_only_the_diff() {
        let store = temp_store("diff");
        let sched = Scheduler::new(store.clone());
        let models = [tiny_cnn()];
        let archs = Arch::all();
        // Warm only the Orig group.
        sched.run_grid(&models, &[SweepGroup::Original], &archs, 5);
        // Request Orig + D=25%: only the new group simulates.
        let r = sched.run_grid(
            &models,
            &[SweepGroup::Original, SweepGroup::Density(25)],
            &archs,
            5,
        );
        assert_eq!(r.stats.requested, 6);
        assert_eq!(r.stats.cache_hits, 3);
        assert_eq!(r.stats.computed, 3);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn different_seed_is_a_different_point() {
        let store = temp_store("seed");
        let sched = Scheduler::new(store.clone());
        let models = [tiny_cnn()];
        sched.run_grid(&models, &[SweepGroup::Original], &[Arch::Codr], 1);
        let r = sched.run_grid(&models, &[SweepGroup::Original], &[Arch::Codr], 2);
        assert_eq!(r.stats.cache_hits, 0);
        assert_eq!(r.stats.computed, 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn concurrent_identical_requests_dedupe() {
        let store = temp_store("dedupe");
        let sched = Arc::new(Scheduler::new(store.clone()));
        let models = Arc::new([tiny_cnn()]);
        let total_computed = Arc::new(AtomicUsize::new(0));
        let total_deduped = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let sched = Arc::clone(&sched);
            let models = Arc::clone(&models);
            let computed = Arc::clone(&total_computed);
            let deduped = Arc::clone(&total_deduped);
            handles.push(std::thread::spawn(move || {
                let r = sched.run_grid(&models[..], &[SweepGroup::Original], &Arch::all(), 3);
                computed.fetch_add(r.stats.computed, Ordering::Relaxed);
                deduped.fetch_add(r.stats.deduped, Ordering::Relaxed);
                assert_eq!(r.results.len(), 3);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Every point was computed exactly once across all four requests
        // (the rest were cache hits or waited on the in-flight claimant).
        assert_eq!(total_computed.load(Ordering::Relaxed), 3);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    /// Two concurrent requests sharing one point (one a wide grid, one a
    /// single point): the shared point is computed exactly once, the
    /// narrow request always completes with the right result, and the
    /// streaming release means it never has to outlive the wide grid's
    /// barrier to do so (the old code woke it only after the whole
    /// batch-set's parallel map).
    #[test]
    fn narrow_request_sharing_a_point_with_a_wide_grid() {
        let store = temp_store("stream");
        let sched = Arc::new(Scheduler::new(store.clone()));
        let models = Arc::new([tiny_cnn()]);
        let groups = [
            SweepGroup::Original,
            SweepGroup::Density(75),
            SweepGroup::Density(50),
            SweepGroup::Density(25),
        ];

        let wide = {
            let sched = Arc::clone(&sched);
            let models = Arc::clone(&models);
            std::thread::spawn(move || sched.run_grid(&models[..], &groups, &Arch::all(), 21))
        };
        let narrow = {
            let sched = Arc::clone(&sched);
            let models = Arc::clone(&models);
            std::thread::spawn(move || {
                sched.run_grid(&models[..], &[SweepGroup::Original], &[Arch::Codr], 21)
            })
        };
        let wide = wide.join().unwrap();
        let narrow = narrow.join().unwrap();
        assert_eq!(wide.results.len(), 12);
        assert_eq!(narrow.results.len(), 1);
        // Exactly-once across both requests, however the race fell.
        assert_eq!(wide.stats.computed + narrow.stats.computed, 12);
        // The shared point is identical from both vantage points and
        // equal to the storeless truth.
        let shared = wide
            .get("tiny", SweepGroup::Original, Arch::Codr)
            .expect("wide grid covers the shared point");
        assert_eq!(&narrow.results[0], shared);
        let fresh = run_sweep(&models[..], &[SweepGroup::Original], &[Arch::Codr], 21);
        assert_eq!(narrow.results[0], fresh.results[0]);
        assert!(sched.inflight.lock().unwrap().is_empty());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    /// A claimant that cannot persist anything (its pack path is blocked,
    /// so every save fails) must leave waiters able to claim and compute
    /// the point themselves — never a hung server, never a corrupt hit.
    #[test]
    fn waiters_recover_when_claimant_cannot_persist() {
        let store = temp_store("nopersist");
        let models = Arc::new([tiny_cnn()]);
        let key = CacheKey::for_point(
            "tiny",
            &SweepGroup::Original,
            Arch::Codr.name(),
            &Arch::Codr.build().tile_config(),
            &MemConfig::default(),
            13,
        );
        // A non-empty directory at the pack path makes the atomic rename
        // fail for every save of this point.
        std::fs::create_dir_all(store.pack_path_for(&key).join("blocker")).unwrap();

        let sched = Arc::new(Scheduler::new(store.clone()));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let sched = Arc::clone(&sched);
            let models = Arc::clone(&models);
            handles.push(std::thread::spawn(move || {
                sched.run_grid(&models[..], &[SweepGroup::Original], &[Arch::Codr], 13)
            }));
        }
        let results: Vec<SweepResults> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let fresh = run_sweep(&models[..], &[SweepGroup::Original], &[Arch::Codr], 13);
        for r in &results {
            assert_eq!(r.results.len(), 1);
            assert_eq!(r.results[0], fresh.results[0], "never a corrupt or empty hit");
        }
        // Nothing could persist, so each request simulated the point
        // itself (a waiter that found no store entry after the claim
        // cleared took the computation over).
        let total: usize = results.iter().map(|r| r.stats.computed).sum();
        assert_eq!(total, 2);
        assert!(sched.inflight.lock().unwrap().is_empty(), "no leaked claims");
        // And the failed saves left no temp files behind.
        let leftovers: Vec<String> = std::fs::read_dir(store.dir())
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(store.dir());
    }
}
