//! Incremental grid scheduler: diff a requested (model × group × arch)
//! grid against the result store and simulate only what is missing.
//!
//! Three properties matter here:
//!
//! 1. **Incrementality** — points already in the store are loaded, not
//!    simulated; corrupt entries are recomputed and overwritten.
//! 2. **Workload batching** — missing points that share a (model, group)
//!    pair are dispatched as one batch so the synthetic weights are
//!    generated once and reused by every design, mirroring the
//!    coordinator's storeless fan-out.
//! 3. **In-flight dedup** — when two requests (e.g. two `codr serve`
//!    clients) need the same point concurrently, the second waits for the
//!    first instead of burning a second simulation; claims are released
//!    on unwind, so a failed claimant degrades to the waiter computing
//!    the point itself, never to a hung server.
//!
//! Results are returned in (model × group) then arch order — identical to
//! the storeless sweep, so figure output is byte-for-byte the same
//! whether it came from silicon^W simulation or from disk.

use super::store::{CacheKey, LoadOutcome, ResultStore};
use crate::arch::MemConfig;
use crate::coordinator::{pool, Arch, SweepResults, SweepStats};
use crate::models::{Model, SweepGroup, Workload};
use crate::reuse::memo;
use crate::sim::{simulate_model, Accelerator, LayerResult, ModelResult};
use std::collections::{HashMap, HashSet};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One grid point, addressed by indices into the request plus its store
/// key.
struct Point {
    mi: usize,
    gi: usize,
    ai: usize,
    key: CacheKey,
}

/// Missing points sharing one (model, group) — one workload synthesis.
struct Batch<'a> {
    model: &'a Model,
    group: SweepGroup,
    points: Vec<Point>,
}

/// Long-lived scheduler over one result store. `codr serve` keeps a
/// single instance so in-flight dedup spans connections; one-shot CLI
/// paths build a transient one per sweep.
pub struct Scheduler {
    store: ResultStore,
    inflight: Mutex<HashSet<u64>>,
    released: Condvar,
}

/// Releases claimed fingerprints even if the claimant unwinds.
struct ClaimGuard<'a> {
    sched: &'a Scheduler,
    claims: Vec<u64>,
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        let mut inflight = self.sched.inflight.lock().unwrap();
        for c in &self.claims {
            inflight.remove(c);
        }
        drop(inflight);
        self.sched.released.notify_all();
    }
}

impl Scheduler {
    pub fn new(store: ResultStore) -> Scheduler {
        Scheduler {
            store,
            inflight: Mutex::new(HashSet::new()),
            released: Condvar::new(),
        }
    }

    pub fn store(&self) -> &ResultStore {
        &self.store
    }

    /// Run one grid request through the store. See the module docs for
    /// the hit/miss/dedup semantics.
    pub fn run_grid(
        &self,
        models: &[Model],
        groups: &[SweepGroup],
        archs: &[Arch],
        seed: u64,
    ) -> SweepResults {
        let t0 = Instant::now();
        let (memo_h0, memo_m0) = memo::global().counters();
        let mem = MemConfig::default();
        let mut stats = SweepStats::default();
        let mut found: HashMap<(usize, usize, usize), ModelResult> = HashMap::new();
        let mut misses: Vec<Point> = Vec::new();

        // Phase 1: diff the grid against the store.
        for (mi, model) in models.iter().enumerate() {
            for (gi, group) in groups.iter().enumerate() {
                for (ai, arch) in archs.iter().enumerate() {
                    stats.requested += 1;
                    let key = CacheKey::for_point(
                        model.name,
                        group,
                        arch.name(),
                        &arch.build().tile_config(),
                        &mem,
                        seed,
                    );
                    let point = Point { mi, gi, ai, key };
                    match self.store.load(&point.key) {
                        LoadOutcome::Hit(r) => {
                            stats.cache_hits += 1;
                            found.insert((mi, gi, ai), *r);
                        }
                        LoadOutcome::Corrupt => {
                            stats.corrupt += 1;
                            misses.push(point);
                        }
                        LoadOutcome::Miss => misses.push(point),
                    }
                }
            }
        }

        // Phase 2: claim what no other request is already computing. The
        // guard releases claims even if a later phase unwinds.
        let mut guard = ClaimGuard {
            sched: self,
            claims: Vec::new(),
        };
        let mut claimed: Vec<Point> = Vec::new();
        let mut waited: Vec<Point> = Vec::new();
        {
            let mut inflight = self.inflight.lock().unwrap();
            for p in misses {
                if inflight.insert(p.key.fingerprint) {
                    guard.claims.push(p.key.fingerprint);
                    claimed.push(p);
                } else {
                    waited.push(p);
                }
            }
        }

        // Double-checked locking: another request may have computed and
        // saved a point between our phase-1 miss and the claim. Now that
        // we hold the claim nobody else is writing it, so one re-read
        // settles it: a hit here releases the claim and skips the
        // simulation.
        let mut to_compute: Vec<Point> = Vec::new();
        for p in claimed {
            match self.store.load(&p.key) {
                LoadOutcome::Hit(r) => {
                    stats.cache_hits += 1;
                    self.inflight.lock().unwrap().remove(&p.key.fingerprint);
                    self.released.notify_all();
                    guard.claims.retain(|&f| f != p.key.fingerprint);
                    found.insert((p.mi, p.gi, p.ai), *r);
                }
                _ => to_compute.push(p),
            }
        }

        // Phase 3: batch claimed points by (model, group) so each
        // workload is synthesized once, then fan the *layers* out — one
        // pool task per (point, layer). This is what lets a narrow grid
        // (e.g. a single-model `warm` with three archs) use every worker
        // instead of running the designs serially on one.
        if !to_compute.is_empty() {
            let mut batches: Vec<Batch> = Vec::new();
            let mut by_pair: HashMap<(usize, usize), usize> = HashMap::new();
            for p in to_compute {
                let slot = *by_pair.entry((p.mi, p.gi)).or_insert_with(|| {
                    batches.push(Batch {
                        model: &models[p.mi],
                        group: groups[p.gi],
                        points: Vec::new(),
                    });
                    batches.len() - 1
                });
                batches[slot].points.push(p);
            }
            let workloads = pool::parallel_map(&batches, |batch| {
                let (unique, density) = batch.group.knobs();
                Workload::generate(batch.model, unique, density, seed)
            });
            let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
            for (bi, batch) in batches.iter().enumerate() {
                let n_layers = workloads[bi].conv_layers().count();
                for pi in 0..batch.points.len() {
                    for li in 0..n_layers {
                        tasks.push((bi, pi, li));
                    }
                }
            }
            let layer_results = pool::parallel_map(&tasks, |&(bi, pi, li)| {
                let acc = archs[batches[bi].points[pi].ai].build();
                let (spec, w) = workloads[bi]
                    .conv_layers()
                    .nth(li)
                    .expect("task layer index");
                acc.simulate_layer(spec, w)
            });
            // Reassemble per point (tasks are in (batch, point, layer)
            // order and parallel_map preserves it), persist, and release
            // each claim as its point is saved. Note the trade against
            // the pre-fan-out code: claims release after the whole
            // parallel_map barrier rather than per point mid-flight, so
            // a concurrent request waiting on one of our points waits
            // for this grid's compute to finish — in exchange the grid
            // itself finishes far sooner (per-layer parallelism). See
            // ROADMAP "Streaming claim release".
            let mut remaining = layer_results.into_iter();
            for (bi, batch) in batches.iter().enumerate() {
                let n_layers = workloads[bi].conv_layers().count();
                for p in &batch.points {
                    let layers: Vec<LayerResult> = remaining.by_ref().take(n_layers).collect();
                    let result = ModelResult {
                        arch: archs[p.ai].name().to_string(),
                        model: batch.model.name.to_string(),
                        group: batch.group.label(),
                        layers,
                    };
                    if let Err(e) = self.store.save(&p.key, &result) {
                        eprintln!("warn: failed to persist {}: {e:#}", p.key.file_stem());
                    }
                    self.inflight.lock().unwrap().remove(&p.key.fingerprint);
                    self.released.notify_all();
                    stats.computed += 1;
                    stats.simulated_layers += result.layers.len();
                    found.insert((p.mi, p.gi, p.ai), result);
                }
            }
        }
        drop(guard); // release remaining claims, wake waiters

        // Phase 4: points another request was already computing — wait for
        // the claim to clear, then read the store. If the claimant failed
        // (no entry appeared), claim and compute the point ourselves.
        for p in waited {
            let result = self.wait_for_point(&p, models, groups, archs, seed, &mut stats);
            found.insert((p.mi, p.gi, p.ai), result);
        }

        // Assemble in the storeless sweep's order.
        let mut results = Vec::with_capacity(stats.requested);
        for mi in 0..models.len() {
            for gi in 0..groups.len() {
                for ai in 0..archs.len() {
                    if let Some(r) = found.remove(&(mi, gi, ai)) {
                        results.push(r);
                    }
                }
            }
        }
        let (memo_h1, memo_m1) = memo::global().counters();
        stats.memo_hits = (memo_h1 - memo_h0) as usize;
        stats.memo_misses = (memo_m1 - memo_m0) as usize;
        stats.wall_ms = t0.elapsed().as_millis() as u64;
        SweepResults { results, stats }
    }

    fn wait_for_point(
        &self,
        p: &Point,
        models: &[Model],
        groups: &[SweepGroup],
        archs: &[Arch],
        seed: u64,
        stats: &mut SweepStats,
    ) -> ModelResult {
        loop {
            // Wait until no request holds a claim on this point.
            {
                let mut inflight = self.inflight.lock().unwrap();
                while inflight.contains(&p.key.fingerprint) {
                    inflight = self.released.wait(inflight).unwrap();
                }
            }
            match self.store.load(&p.key) {
                LoadOutcome::Hit(r) => {
                    stats.deduped += 1;
                    return *r;
                }
                _ => {
                    // Claimant died or failed to persist: try to take over.
                    let claimed = self.inflight.lock().unwrap().insert(p.key.fingerprint);
                    if !claimed {
                        continue; // someone else took over; wait again
                    }
                    let guard = ClaimGuard {
                        sched: self,
                        claims: vec![p.key.fingerprint],
                    };
                    let group = groups[p.gi];
                    let (unique, density) = group.knobs();
                    let workload = Workload::generate(&models[p.mi], unique, density, seed);
                    let acc = archs[p.ai].build();
                    let result = simulate_model(acc.as_ref(), &workload, &group.label());
                    if let Err(e) = self.store.save(&p.key, &result) {
                        eprintln!("warn: failed to persist {}: {e:#}", p.key.file_stem());
                    }
                    stats.computed += 1;
                    stats.simulated_layers += result.layers.len();
                    drop(guard);
                    return result;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::tiny_cnn;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn temp_store(tag: &str) -> ResultStore {
        let dir = std::env::temp_dir().join(format!(
            "codr-sched-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        ResultStore::open(dir).unwrap()
    }

    #[test]
    fn second_run_is_all_hits_with_zero_simulated_layers() {
        let store = temp_store("rerun");
        let sched = Scheduler::new(store.clone());
        let models = [tiny_cnn()];
        let groups = [SweepGroup::Original, SweepGroup::Density(50)];
        let archs = Arch::all();

        let cold = sched.run_grid(&models, &groups, &archs, 11);
        assert_eq!(cold.stats.requested, 6);
        assert_eq!(cold.stats.computed, 6);
        assert_eq!(cold.stats.cache_hits, 0);
        assert!(cold.stats.simulated_layers > 0);

        let warm = sched.run_grid(&models, &groups, &archs, 11);
        assert_eq!(warm.stats.cache_hits, 6);
        assert_eq!(warm.stats.computed, 0);
        assert_eq!(warm.stats.simulated_layers, 0, "warm run must not simulate");
        // Same results, same order.
        assert_eq!(cold.results, warm.results);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn partial_store_computes_only_the_diff() {
        let store = temp_store("diff");
        let sched = Scheduler::new(store.clone());
        let models = [tiny_cnn()];
        let archs = Arch::all();
        // Warm only the Orig group.
        sched.run_grid(&models, &[SweepGroup::Original], &archs, 5);
        // Request Orig + D=25%: only the new group simulates.
        let r = sched.run_grid(
            &models,
            &[SweepGroup::Original, SweepGroup::Density(25)],
            &archs,
            5,
        );
        assert_eq!(r.stats.requested, 6);
        assert_eq!(r.stats.cache_hits, 3);
        assert_eq!(r.stats.computed, 3);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn different_seed_is_a_different_point() {
        let store = temp_store("seed");
        let sched = Scheduler::new(store.clone());
        let models = [tiny_cnn()];
        sched.run_grid(&models, &[SweepGroup::Original], &[Arch::Codr], 1);
        let r = sched.run_grid(&models, &[SweepGroup::Original], &[Arch::Codr], 2);
        assert_eq!(r.stats.cache_hits, 0);
        assert_eq!(r.stats.computed, 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn concurrent_identical_requests_dedupe() {
        let store = temp_store("dedupe");
        let sched = Arc::new(Scheduler::new(store.clone()));
        let models = Arc::new([tiny_cnn()]);
        let total_computed = Arc::new(AtomicUsize::new(0));
        let total_deduped = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let sched = Arc::clone(&sched);
            let models = Arc::clone(&models);
            let computed = Arc::clone(&total_computed);
            let deduped = Arc::clone(&total_deduped);
            handles.push(std::thread::spawn(move || {
                let r = sched.run_grid(&models[..], &[SweepGroup::Original], &Arch::all(), 3);
                computed.fetch_add(r.stats.computed, Ordering::Relaxed);
                deduped.fetch_add(r.stats.deduped, Ordering::Relaxed);
                assert_eq!(r.results.len(), 3);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Every point was computed exactly once across all four requests
        // (the rest were cache hits or waited on the in-flight claimant).
        assert_eq!(total_computed.load(Ordering::Relaxed), 3);
        let _ = std::fs::remove_dir_all(store.dir());
    }
}
