//! The `serve` subsystem: a persistent sweep service.
//!
//! Every sweep point is deterministic given `(model, sweep group, arch,
//! seed, accelerator config)`, so results computed once can serve every
//! later figure. This module turns that determinism into a system:
//!
//! * [`store`] — content-addressed, corruption-tolerant on-disk cache of
//!   [`crate::sim::ModelResult`]s (packed per-(model, group, seed) group
//!   files, per-entry integrity checks, atomic writes, read-through v1
//!   migration, optional size cap with oldest-first eviction);
//! * [`scheduler`] — diffs a requested grid against the store (one pack
//!   read per (model, group)), batches missing points that share a
//!   workload, dedups identical in-flight requests with per-point
//!   streaming claim release (observable per point via
//!   [`scheduler::Progress`]), and fans out over
//!   [`crate::coordinator::pool`];
//! * [`server`] / [`proto`] — `codr serve`, a long-running TCP service
//!   speaking line-delimited JSON (`submit` / `watch` / `status` /
//!   `result` / `warm`), with `codr submit` / `codr watch` /
//!   `codr warm` as clients; `shutdown` drains in-flight jobs and open
//!   watchers (bounded by `--drain-secs`) before snapshotting the memo;
//! * [`reactor`] / [`exec`] / [`metrics`] — the event-driven core: a
//!   nonblocking readiness loop (epoll on Linux, portable `poll(2)`
//!   fallback) owns every connection and streams watch events as
//!   event-loop writes; CPU-heavy verbs run on a fixed executor pool
//!   behind a bounded admission queue (`--max-queued`, refusals answer
//!   `state:"queued-full"`), so the thread count is independent of the
//!   number of clients; per-verb request/answer/error counters with
//!   latency histograms surface in `status`;
//! * [`journal`] — append-only, checksummed record of accepted sweep
//!   jobs; on restart after a crash, journaled jobs that never reached a
//!   terminal state are re-queued (the store diff turns the dead
//!   process's persisted points into hits);
//! * [`ring`] / [`peer`] — optional multi-host mode (`--ring` /
//!   `CODR_RING`): a static consistent-hash ring places packs on nodes,
//!   any node forwards non-owned submits to the pack owner through a
//!   health-checked peer client (Up → Suspect → Down, periodic probes),
//!   computes locally in degraded mode when the owner is down
//!   (`state:"done-degraded"`, origin-tagged entries), and an
//!   anti-entropy repair pass pushes misplaced packs back to recovered
//!   owners.
//!
//! The CLI figure path reads through the same store, so
//! `codr warm --models tiny` followed by `codr figure headline --models
//! tiny` renders the figure without a single `simulate_layer` call.

pub(crate) mod exec;
pub mod journal;
pub(crate) mod metrics;
pub(crate) mod peer;
pub mod proto;
pub(crate) mod reactor;
pub(crate) mod ring;
pub mod scheduler;
pub mod server;
pub mod store;

pub use journal::Journal;
pub use proto::{GridRequest, DEFAULT_ADDR};
pub use scheduler::Scheduler;
pub use server::{memo_snapshot_path, Server, DEFAULT_DRAIN_SECS};
pub use store::{CacheKey, LoadOutcome, ResultStore, StoreStats, STORE_FORMAT_VERSION};

use std::path::PathBuf;

/// Default on-disk store location: `$CODR_STORE` if set, else
/// `results/store` under the working directory (next to the `--save`
/// report artifacts).
pub fn default_store_dir() -> PathBuf {
    match crate::analysis::env_registry::var("CODR_STORE") {
        Some(dir) if !dir.is_empty() => PathBuf::from(dir),
        _ => PathBuf::from("results").join("store"),
    }
}
