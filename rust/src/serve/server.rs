//! The long-running sweep service behind `codr serve`.
//!
//! **Event-driven core.** One reactor thread (see [`super::reactor`]) owns
//! every client socket behind an epoll/poll readiness loop: nonblocking
//! line-JSON framing in per-connection read buffers, answers staged in
//! write buffers that flush on writability. CPU-heavy work — `submit` and
//! `map` jobs, `warm` grids — runs on a fixed executor pool
//! ([`super::exec`], `CODR_SERVE_EXECUTORS` workers), so the server's
//! thread count is independent of the number of connected clients. All
//! work shares one [`Scheduler`], so the in-flight dedup spans clients —
//! two clients warming the same grid simulate it once.
//!
//! Verbs: `ping`, `warm` (pooled sweep, answered when it finishes),
//! `submit` (async job), `map` (async mapping-space search job), `watch`
//! (stream a job's per-point progress), `status` (job or server, including
//! per-verb latency counters), `result` (store lookup), `shutdown`.
//!
//! **Admission is bounded.** At most `--max-queued` tasks may wait for an
//! executor; past that, `submit`/`map`/`warm` answer
//! `ok:false, state:"queued-full"` instead of stalling intake. Refused
//! submits are never journaled and burn no job ids — the client retries
//! under its own `--retries` backoff.
//!
//! **Job progress is a broadcast, not a poll.** Every submitted job owns
//! a [`JobChannel`]: the scheduler's per-point completion path publishes
//! one `point` event into it and rings the reactor's self-pipe; the
//! reactor copies fresh events into every watching connection's write
//! buffer. A watcher that attaches late — even after the job finished —
//! sees the identical sequence; a watcher whose socket dies mid-stream is
//! deregistered on the next write.
//!
//! **Shutdown drains.** A `shutdown` request stops intake (new `submit`
//! and `warm` requests are refused, the listener is deregistered), then
//! waits — bounded by `--drain-secs` — for running jobs and warms to
//! finish, stops the executor pool, force-closes the channels of anything
//! still running so watchers terminate, and only then snapshots the memo.
//! Results of in-flight work are persisted, workers are never orphaned
//! mid-sweep, and the snapshot is written once, after the memo stopped
//! changing.
//!
//! **Crash restart is journaled.** Accepted sweep jobs are recorded in
//! an append-only, checksummed journal (`<store>/jobs.journal`, see
//! [`super::journal`]); at startup, jobs the previous process never
//! finished are re-queued under fresh ids (bypassing the admission cap —
//! an acked job is never refused), and the store diff turns whatever the
//! dead process persisted into cache hits. A sweep whose points partially
//! panicked (contained per point by the scheduler) finishes as
//! `state:"partial"`. `--conn-timeout-secs` reaps idle connections via
//! the reactor's deadline heap so a stalled client cannot hold its slot
//! forever.

use super::exec::Exec;
use super::journal::Journal;
use super::metrics::Metrics;
use super::peer::{self, Health};
use super::proto::{
    error_response, ok_response, queued_full_response, stats_to_json, GridRequest, Retry,
};
use super::reactor::{self, Completion, Notifier, WakeRx};
use super::ring::RingState;
use super::scheduler::{PointDone, Scheduler};
use super::store::{pack_stem_for, CacheKey, LoadOutcome, OriginTag, ResultStore};
use crate::arch::MemConfig;
use crate::codr::Codr;
use crate::coordinator::{Arch, SweepStats};
use crate::mapping::search::{enumerate_mappings, SearchConfig};
use crate::models::{parse_group_list, LayerKind, SweepGroup};
use crate::analysis::env_registry;
use crate::reuse::memo;
use crate::util::json::Json;
use crate::util::sync;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub use super::exec::DEFAULT_MAX_QUEUED;

/// Default bound on how long `shutdown` waits for in-flight jobs and
/// open watchers before abandoning them (`--drain-secs` overrides; 0
/// skips the wait entirely).
pub const DEFAULT_DRAIN_SECS: u64 = 30;

/// Progress of one submitted job.
#[derive(Clone, Debug)]
enum JobState {
    Running,
    Done(SweepStats),
    Failed(String),
}

/// Per-job broadcast channel: the submit worker publishes one `point`
/// event per completed sweep point and a terminal `end` event. Watchers
/// never block on it — each watching connection keeps a cursor and the
/// reactor copies `events_from(cursor)` into its write buffer whenever
/// the self-pipe rings. Events are buffered for the job's lifetime (a
/// job is at most the paper grid — tens of points — so the history is
/// small), which is what makes a late `watch` identical to an early one.
pub(crate) struct JobChannel {
    total: usize,
    inner: Mutex<ChannelInner>,
    /// Rings the reactor after every publish so watcher buffers fill
    /// promptly. A leaf lock/fd pair: never wraps another acquisition.
    notify: Arc<Notifier>,
}

struct ChannelInner {
    events: Vec<Json>,
    /// Completed points so far — assigned under the lock, so `done` in
    /// the event stream is strictly increasing even when pool workers
    /// finish points concurrently.
    points: usize,
    closed: bool,
}

impl JobChannel {
    fn new(total: usize, notify: Arc<Notifier>) -> JobChannel {
        JobChannel {
            total,
            inner: Mutex::new(ChannelInner {
                events: Vec::new(),
                points: 0,
                closed: false,
            }),
            notify,
        }
    }

    /// Publish one completed point.
    fn publish_point(&self, job: u64, p: &PointDone<'_>) {
        {
            let mut inner = sync::lock(&self.inner);
            if inner.closed {
                return;
            }
            inner.points += 1;
            let mut fields = vec![
                ("event".into(), Json::str("point")),
                ("job".into(), Json::u64(job)),
                ("done".into(), Json::usize(inner.points)),
                ("total".into(), Json::usize(self.total)),
                ("model".into(), Json::str(p.model)),
                ("group".into(), Json::str(p.group.as_str())),
                ("arch".into(), Json::str(p.arch)),
                ("cache_hit".into(), Json::Bool(p.cache_hit)),
            ];
            // A point whose computation panicked still resolves — with the
            // panic message — so watchers see it counted, not hung.
            if let Some(err) = p.error {
                fields.push(("error".into(), Json::str(err)));
            }
            inner.events.push(Json::Obj(fields));
        }
        self.notify.wake();
    }

    /// Append the terminal event and close the channel. Idempotent: the
    /// first close wins (the drain's force-close never clobbers a real
    /// `end` that already landed).
    fn close(&self, end: Json) {
        {
            let mut inner = sync::lock(&self.inner);
            if inner.closed {
                return;
            }
            inner.events.push(end);
            inner.closed = true;
        }
        self.notify.wake();
    }

    /// Events from `cursor` on, plus whether the channel is closed (the
    /// last event of a closed channel is always the terminal `end`).
    /// Never blocks — this is the reactor's pump primitive.
    pub(crate) fn events_from(&self, cursor: usize) -> (Vec<Json>, bool) {
        let inner = sync::lock(&self.inner);
        let events = if cursor < inner.events.len() {
            inner.events[cursor..].to_vec()
        } else {
            Vec::new()
        };
        (events, inner.closed)
    }
}

/// One submitted job: its state for `status`, its channel for `watch`.
struct Job {
    state: JobState,
    chan: Arc<JobChannel>,
}

/// Shared server state: the scheduler (store + in-flight claims), the job
/// table, the executor pool, and the reactor's metrics/wake plumbing.
pub(crate) struct Shared {
    sched: Scheduler,
    jobs: Mutex<HashMap<u64, Job>>,
    /// Recently pruned terminal job ids — `status` answers `expired` for
    /// these instead of `unknown job N`, so a slow poller stops retrying.
    expired: Mutex<VecDeque<u64>>,
    /// Fixed worker pool running submit/map/warm work.
    pub(crate) exec: Arc<Exec>,
    /// Write half of the reactor's self-pipe + completion mailbox.
    pub(crate) notify: Arc<Notifier>,
    /// Per-verb request/answer/latency counters, reported by `status`.
    pub(crate) metrics: Metrics,
    /// `warm` grids currently queued or running on the pool; the drain
    /// waits for these exactly like jobs (they simulate and mutate the
    /// memo just the same).
    pub(crate) warms: AtomicUsize,
    /// Open `watch` streams; the drain flush window waits for them.
    pub(crate) watchers: AtomicUsize,
    /// Open client connections (reactor-owned gauge, for `status`).
    pub(crate) conns: AtomicUsize,
    /// Multi-host mode (`--ring` / `CODR_RING`): the consistent-hash
    /// ring plus per-peer health and gauges. Empty on single-node
    /// servers — every ring code path starts with a cheap `get()` check.
    pub(crate) ring: std::sync::OnceLock<Arc<RingState>>,
    next_job: AtomicU64,
    pub(crate) stop: AtomicBool,
    /// Crash-restart job journal (`None` when the store dir cannot host
    /// one — serving continues, jobs just do not survive a crash).
    /// Sweep jobs are journaled; `map` jobs are not (their report lives
    /// only in the channel — a crashed search is simply re-run by the
    /// client, and its candidates replay as store hits).
    journal: Option<Journal>,
}

/// A bound, not-yet-running sweep service.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    /// Read half of the reactor's self-pipe.
    wake_rx: WakeRx,
    drain: Duration,
    conn_timeout: Option<Duration>,
    /// Journaled jobs the previous process never finished; re-queued at
    /// the top of [`Server::run`].
    recovered: Vec<super::journal::Recovered>,
}

/// Where the persistent memo snapshot for a store lives, honoring
/// `CODR_MEMO_SNAPSHOT` (`off`/`0`/empty disables, any other value is a
/// path override; unset defaults to `<store>/memo.snapshot`).
pub fn memo_snapshot_path(store_dir: &Path) -> Option<std::path::PathBuf> {
    match env_registry::var("CODR_MEMO_SNAPSHOT") {
        Some(v) if v.is_empty() || v == "off" || v == "0" => None,
        Some(v) => Some(std::path::PathBuf::from(v)),
        None => Some(store_dir.join("memo.snapshot")),
    }
}

/// Interval between periodic background memo snapshots, honoring
/// `CODR_MEMO_SNAPSHOT_SECS` (default 300; `0`/`off` disables the
/// periodic writer — the clean-shutdown snapshot still happens).
fn memo_snapshot_period() -> Option<Duration> {
    match env_registry::var("CODR_MEMO_SNAPSHOT_SECS") {
        Some(v) if v == "0" || v == "off" => None,
        Some(v) => v.parse::<u64>().ok().map(Duration::from_secs),
        None => Some(Duration::from_secs(300)),
    }
}

/// Finished jobs retained for `status` polling; beyond this the oldest
/// terminal entries are pruned (their ids move to the expired ring).
/// `CODR_SERVE_MAX_JOBS` overrides for tests.
fn max_retained_jobs() -> usize {
    env_registry::var("CODR_SERVE_MAX_JOBS")
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 2)
        .unwrap_or(256)
}

/// Pruned terminal ids remembered for `status`/`watch` answers.
const EXPIRED_RING: usize = 256;

impl Server {
    /// Bind the service. `addr` may use port 0 to pick a free port (the
    /// tests do); `store_dir` is created if missing.
    pub fn bind(addr: &str, store_dir: &Path) -> Result<Server> {
        Self::bind_with(addr, ResultStore::open(store_dir)?)
    }

    /// Bind the service over an already-opened store (the CLI uses this
    /// to apply `--store-cap-mb`).
    pub fn bind_with(addr: &str, store: ResultStore) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding codr serve to {addr}"))?;
        let (journal, recovered) = match Journal::open(store.dir()) {
            Ok((j, r)) => (Some(j), r),
            Err(e) => {
                eprintln!(
                    "warn: job journal unavailable ({e:#}); jobs will not survive a restart"
                );
                (None, Vec::new())
            }
        };
        let (wake_rx, notifier) =
            reactor::wake_pair().context("creating the reactor wake pipe")?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                sched: Scheduler::new(store),
                jobs: Mutex::new(HashMap::new()),
                expired: Mutex::new(VecDeque::new()),
                exec: Arc::new(Exec::new()),
                notify: Arc::new(notifier),
                metrics: Metrics::new(),
                warms: AtomicUsize::new(0),
                watchers: AtomicUsize::new(0),
                conns: AtomicUsize::new(0),
                ring: std::sync::OnceLock::new(),
                next_job: AtomicU64::new(1),
                stop: AtomicBool::new(false),
                journal,
            }),
            wake_rx,
            drain: Duration::from_secs(DEFAULT_DRAIN_SECS),
            conn_timeout: None,
            recovered,
        })
    }

    /// Bound on how long `shutdown` drains in-flight jobs and watchers
    /// (`--drain-secs`; 0 abandons them immediately).
    pub fn set_drain_secs(&mut self, secs: u64) {
        self.drain = Duration::from_secs(secs);
    }

    /// Idle-connection bound (`--conn-timeout-secs`; 0 leaves connections
    /// unbounded). A client that parks an idle connection past the bound
    /// is reaped by the reactor's deadline heap instead of holding a slot
    /// forever; connections mid-warm or mid-watch are never reaped.
    pub fn set_conn_timeout_secs(&mut self, secs: u64) {
        self.conn_timeout = (secs > 0).then(|| Duration::from_secs(secs));
    }

    /// Bound on tasks waiting for an executor (`--max-queued`); past it,
    /// `submit`/`map`/`warm` answer `state:"queued-full"`.
    pub fn set_max_queued(&mut self, cap: usize) {
        self.shared.exec.set_cap(cap);
    }

    /// Install the multi-host ring (internal; the CLI builds the
    /// [`RingState`] from `--ring` / `CODR_RING`). Also arms the store's
    /// origin tagging: from here on, saves into packs this node does not
    /// own carry an `origin` marker so the anti-entropy repair pass can
    /// find and push them.
    pub(crate) fn set_ring(&mut self, state: Arc<RingState>) {
        let owned_state = Arc::clone(&state);
        self.shared.sched.store().set_origin(OriginTag {
            addr: state.self_addr().to_string(),
            owned: Box::new(move |stem| owned_state.owns(stem)),
        });
        let _ = self.shared.ring.set(state);
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Serve until a `shutdown` request arrives, then drain and snapshot.
    /// Consumes the server. One reactor thread (this one) owns every
    /// socket; `CODR_SERVE_EXECUTORS` pool workers run the sweeps.
    ///
    /// The persistent vector memo brackets the loop: a snapshot from a
    /// previous process is restored lazily (on a background thread —
    /// binding and first requests never wait on it; until it lands,
    /// lookups simply miss and recompute), a periodic writer re-snapshots
    /// every `CODR_MEMO_SNAPSHOT_SECS` so a crash loses at most one
    /// interval of warm state, and a final snapshot lands on clean
    /// shutdown *after* the drain (so it includes everything the drained
    /// jobs computed). The restore thread is joined before any save, and
    /// an empty memo is never saved — a fast shutdown cannot clobber a
    /// warm on-disk snapshot with a cold one.
    pub fn run(self) -> Result<()> {
        let snapshot = memo_snapshot_path(self.shared.sched.store().dir());
        let restore_done = Arc::new(AtomicBool::new(snapshot.is_none()));
        let restore = snapshot.clone().map(|path| {
            let done = Arc::clone(&restore_done);
            std::thread::spawn(move || {
                match memo::global().load_snapshot(&path) {
                    Ok(n) if n > 0 => {
                        eprintln!("memo: restored {n} vectors from {}", path.display())
                    }
                    Ok(_) => {}
                    Err(e) => eprintln!("warn: memo snapshot unusable ({e:#}); starting cold"),
                }
                done.store(true, Ordering::SeqCst);
            })
        });
        let periodic = match (&snapshot, memo_snapshot_period()) {
            (Some(path), Some(period)) => {
                let path = path.clone();
                let shared = Arc::clone(&self.shared);
                let restored = Arc::clone(&restore_done);
                Some(std::thread::spawn(move || {
                    let mut last = Instant::now();
                    while !shared.stop.load(Ordering::SeqCst) {
                        std::thread::sleep(Duration::from_millis(200));
                        if last.elapsed() < period {
                            continue;
                        }
                        last = Instant::now();
                        // Wait for the restore to land first — saving a
                        // pre-restore memo over the snapshot being
                        // restored would shed its warm state.
                        if !restored.load(Ordering::SeqCst) {
                            continue;
                        }
                        match memo::global().save_snapshot_if_warm(&path) {
                            Ok(0) => {}
                            Ok(n) => eprintln!(
                                "memo: periodic snapshot of {n} vectors to {}",
                                path.display()
                            ),
                            Err(e) => eprintln!("warn: periodic memo snapshot failed: {e:#}"),
                        }
                    }
                }))
            }
            _ => None,
        };
        self.shared.exec.start(Exec::default_workers());
        // Re-queue journaled jobs the previous process never finished.
        // Each runs under a fresh id through the normal submit path (so
        // it is journaled, watchable, and drainable like any job) but
        // bypasses the admission cap — an acked job is never refused. The
        // old id is closed with `requeued` so a second restart does not
        // replay it again. The store diff makes this cheap: everything
        // the dead process persisted comes back as cache hits.
        for rec in &self.recovered {
            let requeued = GridRequest::from_json(&rec.grid)
                .and_then(|grid| spawn_grid_job(&self.shared, grid, Admission::Bypass));
            match requeued {
                Ok(Spawned::Job { id, points }) => eprintln!(
                    "journal: recovered job {} (never finished); re-queued as job {id} \
                     ({points} points)",
                    rec.job
                ),
                // Bypass admission never answers queued-full.
                Ok(Spawned::QueuedFull { .. }) => {}
                Err(e) => eprintln!(
                    "warn: journaled job {} could not be re-queued: {e:#}",
                    rec.job
                ),
            }
            if let Some(j) = &self.shared.journal {
                j.record_end(rec.job, "requeued");
            }
        }
        let result = reactor::run_loop(
            &self.listener,
            &self.shared,
            &self.wake_rx,
            self.drain,
            self.conn_timeout,
        );
        // The reactor normally returns with `stop` set and the pool shut
        // down; on a fatal poller error, set/stop them here so the joins
        // below cannot hang.
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.exec.shutdown(Instant::now() + Duration::from_secs(1));
        if let Some(h) = restore {
            let _ = h.join();
        }
        if let Some(h) = periodic {
            let _ = h.join();
        }
        if let Some(path) = &snapshot {
            match memo::global().save_snapshot_if_warm(path) {
                Ok(0) => {
                    eprintln!("memo: empty at shutdown; keeping the existing snapshot")
                }
                Ok(n) => eprintln!("memo: snapshotted {n} vectors to {}", path.display()),
                Err(e) => eprintln!("warn: failed to snapshot memo: {e:#}"),
            }
        }
        result
    }
}

/// How a job reaches the executor pool.
pub(crate) enum Admission {
    /// Normal client submits: refuse with `queued-full` past the cap.
    Bounded,
    /// Journal recovery: capacity is not checked — an acked job is never
    /// refused.
    Bypass,
}

/// Outcome of [`spawn_grid_job`].
pub(crate) enum Spawned {
    Job { id: u64, points: usize },
    QueuedFull { queued: usize },
}

/// Decrements the in-flight-warm count even if the sweep unwinds.
struct WarmGuard<'a>(&'a Shared);

impl Drop for WarmGuard<'_> {
    fn drop(&mut self) {
        self.0.warms.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Running jobs + in-flight warms, read by the reactor's drain phase.
pub(crate) fn running_and_warming(shared: &Shared) -> (usize, usize) {
    let running = sync::lock(&shared.jobs)
        .values()
        .filter(|j| matches!(j.state, JobState::Running))
        .count();
    (running, shared.warms.load(Ordering::SeqCst))
}

/// Force-close the channels of abandoned jobs so watchers terminate;
/// called by the reactor once the drain settles or its deadline passes.
pub(crate) fn force_close_running(shared: &Shared) {
    let jobs = sync::lock(&shared.jobs);
    for (id, job) in jobs.iter() {
        if matches!(job.state, JobState::Running) {
            job.chan.close(Json::Obj(vec![
                ("event".into(), Json::str("end")),
                ("job".into(), Json::u64(*id)),
                (
                    "error".into(),
                    Json::str("server shut down before the job finished"),
                ),
            ]));
        }
    }
}

/// Resolve a `watch` request to its ack response and job channel.
pub(crate) fn watch_attach(msg: &Json, shared: &Arc<Shared>) -> Result<(Json, Arc<JobChannel>)> {
    let id = msg.field("job")?.as_u64()?;
    let jobs = sync::lock(&shared.jobs);
    match jobs.get(&id) {
        Some(job) => Ok((
            ok_response(vec![
                ("job".into(), Json::u64(id)),
                ("watching".into(), Json::Bool(true)),
                ("total".into(), Json::usize(job.chan.total)),
            ]),
            Arc::clone(&job.chan),
        )),
        None => {
            if sync::lock(&shared.expired).contains(&id) {
                anyhow::bail!("job {id} expired (pruned from the job table); resubmit it")
            }
            anyhow::bail!("unknown job {id}")
        }
    }
}

/// Dispatch one request. Never panics on client input: every failure
/// becomes an `ok:false` response. `watch` and `warm` never reach this —
/// the reactor handles them (attach / pool hand-off) itself.
pub(crate) fn handle_request(msg: &Json, shared: &Arc<Shared>) -> Json {
    let verb = match msg.get("verb").map(|v| v.as_str()) {
        Some(Ok(v)) => v.to_string(),
        _ => return error_response("request must carry a string `verb`"),
    };
    let result = match verb.as_str() {
        "ping" => Ok(ok_response(vec![("pong".into(), Json::Bool(true))])),
        "submit" => submit(msg, shared),
        "map" => map_submit(msg, shared),
        "status" => status(msg, shared),
        "result" => result_lookup(msg, shared),
        "ring" => ring_info(msg, shared),
        "repair" => repair_merge(msg, shared),
        "shutdown" => {
            shared.stop.store(true, Ordering::SeqCst);
            shared.notify.wake();
            Ok(ok_response(vec![
                ("stopping".into(), Json::Bool(true)),
                ("draining".into(), Json::Bool(true)),
            ]))
        }
        // Defensive: the reactor intercepts `warm` before dispatching here.
        "warm" => Err(anyhow::anyhow!(
            "warm is handled by the reactor's executor hand-off"
        )),
        other => Err(anyhow::anyhow!(
            "unknown verb `{other}` (use ping|warm|submit|map|watch|status|result|ring|repair|shutdown)"
        )),
    };
    result.unwrap_or_else(|e| error_response(format!("{e:#}")))
}

fn refuse_if_stopping(shared: &Shared) -> Result<()> {
    if shared.stop.load(Ordering::SeqCst) {
        anyhow::bail!("server is shutting down; not accepting new work");
    }
    Ok(())
}

/// Is the executor's waiting queue at the admission cap?
fn admission_full(shared: &Shared) -> Option<Json> {
    let queued = shared.exec.queue_len();
    let cap = shared.exec.cap();
    (queued >= cap).then(|| queued_full_response(queued, cap))
}

/// `warm`: run the requested grid on the executor pool. Returns `None`
/// when the grid was enqueued (the answer arrives through the completion
/// mailbox once the sweep finishes) or `Some(response)` for an immediate
/// refusal (stopping, malformed, queue full).
///
/// Store occupancy is deliberately NOT included in the answer: counting
/// packed entries parses every pack file (an O(store-bytes) walk that
/// belongs on the `status` path, not on every warm request).
pub(crate) fn warm_enqueue(
    msg: &Json,
    shared: &Arc<Shared>,
    token: usize,
    verb_idx: usize,
    started: Instant,
) -> Option<Json> {
    // Register before the stop check (SeqCst totally orders both): a
    // `shutdown` either happened first — this check refuses — or the
    // drain's counter read happens after the increment and waits for
    // this warm like any job. No window where an accepted warm is
    // invisible to the drain.
    shared.warms.fetch_add(1, Ordering::SeqCst);
    let refusal = refuse_if_stopping(shared)
        .err()
        .map(|e| error_response(format!("{e:#}")))
        .or_else(|| admission_full(shared));
    if let Some(resp) = refusal {
        shared.warms.fetch_sub(1, Ordering::SeqCst);
        return Some(resp);
    }
    let grid = match GridRequest::from_json(msg) {
        Ok(g) => g,
        Err(e) => {
            shared.warms.fetch_sub(1, Ordering::SeqCst);
            return Some(error_response(format!("{e:#}")));
        }
    };
    let shared_task = Arc::clone(shared);
    let task = move || {
        let _guard = WarmGuard(&shared_task);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared_task
                .sched
                .run_grid(&grid.models, &grid.groups, &grid.archs, grid.seed)
        }));
        let response = match outcome {
            Ok(results) => ok_response(vec![(
                "stats".into(),
                stats_to_json(&results.stats),
            )]),
            Err(_) => error_response("warm sweep panicked"),
        };
        shared_task.notify.complete(Completion {
            token,
            verb_idx,
            started,
            response,
        });
    };
    if shared.exec.submit_unbounded(Box::new(task)) {
        None
    } else {
        shared.warms.fetch_sub(1, Ordering::SeqCst);
        Some(error_response("server is shutting down; not accepting new work"))
    }
}

/// One ring-maintenance pass (peer probes + anti-entropy repair),
/// scheduled onto the executor by the reactor's tick. A stopping server
/// neither probes nor repairs.
pub(crate) fn ring_maintenance(shared: &Arc<Shared>) {
    if shared.stop.load(Ordering::SeqCst) {
        return;
    }
    if let Some(state) = shared.ring.get() {
        state.maintain(shared.sched.store());
    }
}

/// Append the routing provenance to an answer traveling back through a
/// forwarding node: which node owns the pack, and that the request was
/// forwarded (so clients re-point `status`/`watch` polling at the owner).
fn with_ring_fields(mut resp: Json, owner: &str) -> Json {
    if let Json::Obj(fields) = &mut resp {
        fields.push(("owner".into(), Json::str(owner)));
        fields.push(("forwarded".into(), Json::Bool(true)));
    }
    resp
}

/// Ring-mode `submit` routing, called by the reactor before normal
/// dispatch. Returns `None` when the submit was handed to the executor
/// as a forward task (the answer arrives through the completion mailbox,
/// exactly like `warm`), or `Some(response)` to answer inline — which
/// includes every locally-computed case: this node owns the packs, the
/// grid spans owners, the message is already a forwarded copy (loop
/// prevention: a receiving node never re-forwards), or parsing failed.
///
/// An accepted forward is journaled on THIS node before the task is
/// enqueued: if the process dies mid-forward, restart recovery re-queues
/// the grid locally — the work is never silently lost, merely computed
/// on the wrong node and repaired later.
pub(crate) fn submit_intercept(
    msg: &Json,
    shared: &Arc<Shared>,
    token: usize,
    verb_idx: usize,
    started: Instant,
) -> Option<Json> {
    let Some(state) = shared.ring.get() else {
        return Some(handle_request(msg, shared));
    };
    if msg.get("forwarded").is_some() {
        return Some(handle_request(msg, shared));
    }
    let Ok(grid) = GridRequest::from_json(msg) else {
        // Malformed: let the normal submit path produce the real error.
        return Some(handle_request(msg, shared));
    };
    // Route by pack. Only a grid whose every (model, group, seed) pack
    // hashes to one single REMOTE owner is forwarded; anything owned
    // here or spanning owners computes locally (misplaced entries get
    // origin-tagged by the store and repaired by the maintenance pass).
    let mut owner: Option<usize> = None;
    for m in &grid.models {
        for g in &grid.groups {
            let o = state.owner_of(&pack_stem_for(m.name, &g.label(), grid.seed));
            match owner {
                None => owner = Some(o),
                Some(prev) if prev != o => return Some(handle_request(msg, shared)),
                Some(_) => {}
            }
        }
    }
    let owner = match owner {
        Some(o) if o != state.self_idx() => o,
        _ => return Some(handle_request(msg, shared)),
    };
    // Same admission contract as `warm`: register with the drain before
    // the stop check, refuse past the queue cap, and run on the pool.
    shared.warms.fetch_add(1, Ordering::SeqCst);
    let refusal = refuse_if_stopping(shared)
        .err()
        .map(|e| error_response(format!("{e:#}")))
        .or_else(|| admission_full(shared));
    if let Some(resp) = refusal {
        shared.warms.fetch_sub(1, Ordering::SeqCst);
        return Some(resp);
    }
    // Journal before forwarding: an acked submit survives a crash of
    // this node even though the work is meant to run elsewhere. The
    // terminal record lands when the owner acks (`forwarded`) or the
    // degraded compute finishes; a crash in between re-queues the grid
    // locally at restart.
    let id = shared.next_job.fetch_add(1, Ordering::SeqCst);
    if let Some(j) = &shared.journal {
        j.record_submit(id, &grid.to_json());
    }
    let mut fwd = msg.clone();
    if let Json::Obj(fields) = &mut fwd {
        fields.push(("forwarded".into(), Json::Bool(true)));
    }
    let state = Arc::clone(state);
    let shared_task = Arc::clone(shared);
    let task = move || {
        let _guard = WarmGuard(&shared_task);
        let response = forward_or_degrade(&shared_task, &state, owner, id, &fwd, &grid);
        shared_task.notify.complete(Completion {
            token,
            verb_idx,
            started,
            response,
        });
    };
    if shared.exec.submit_unbounded(Box::new(task)) {
        None
    } else {
        shared.warms.fetch_sub(1, Ordering::SeqCst);
        if let Some(j) = &shared.journal {
            j.record_end(id, "failed");
        }
        Some(error_response("server is shutting down; not accepting new work"))
    }
}

/// Executor-side half of a routed submit: try to forward to the owner
/// (bounded retries with backoff), fall back to computing the grid
/// locally in degraded mode. Runs on a pool worker — never the reactor.
fn forward_or_degrade(
    shared: &Arc<Shared>,
    state: &RingState,
    owner: usize,
    id: u64,
    fwd: &Json,
    grid: &GridRequest,
) -> Json {
    let p = state.peer(owner);
    let retry = Retry {
        attempts: 2,
        base: Duration::from_millis(100),
        jitter_seed: std::process::id() as u64,
    };
    let mut answer: Option<Json> = None;
    // A peer already marked Down skips straight to degraded mode instead
    // of burning connect timeouts on every submit; the maintenance probe
    // is what promotes it back to Up.
    if p.health() != Health::Down {
        for attempt in 1..=retry.attempts.max(1) {
            match peer::forward(p, fwd, state.timeout) {
                Ok(resp) => {
                    answer = Some(resp);
                    break;
                }
                Err(e) => {
                    p.forward_errors.fetch_add(1, Ordering::SeqCst);
                    eprintln!(
                        "warn: forward attempt {attempt}/{} to {} failed: {e:#}",
                        retry.attempts, p.addr
                    );
                    if attempt < retry.attempts {
                        std::thread::sleep(retry.backoff(attempt));
                    }
                }
            }
        }
    }
    match answer {
        Some(resp) if resp_is_ok(&resp) => {
            p.forwards.fetch_add(1, Ordering::SeqCst);
            if let Some(j) = &shared.journal {
                j.record_end(id, "forwarded");
            }
            with_ring_fields(resp, &p.addr)
        }
        Some(resp) if super::proto::is_queued_full(&resp) => {
            // The owner is alive but saturated: pass its refusal through
            // untouched (plus provenance) so the client's own retry
            // backoff governs, and burn no local compute.
            if let Some(j) = &shared.journal {
                j.record_end(id, "forward-refused");
            }
            with_ring_fields(resp, &p.addr)
        }
        other => {
            // Transport failure after retries, a Down owner, or an
            // owner-side refusal (e.g. it is draining): degraded mode.
            // Compute locally — the store origin-tags the misplaced
            // entries and the repair pass pushes them to the owner once
            // it is Up again.
            if let Some(resp) = other {
                let why = resp
                    .get("error")
                    .and_then(|e| e.as_str().ok())
                    .unwrap_or("owner refused the forward");
                eprintln!(
                    "warn: owner {} refused forwarded submit: {why}; computing locally",
                    p.addr
                );
            } else {
                eprintln!(
                    "warn: owner {} unreachable; computing locally (degraded)",
                    p.addr
                );
            }
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                shared
                    .sched
                    .run_grid(&grid.models, &grid.groups, &grid.archs, grid.seed)
            }));
            match outcome {
                Ok(results) => {
                    if let Some(j) = &shared.journal {
                        j.record_end(id, "done-degraded");
                    }
                    ok_response(vec![
                        ("state".into(), Json::str("done-degraded")),
                        ("stats".into(), stats_to_json(&results.stats)),
                        ("job".into(), Json::u64(id)),
                        ("owner".into(), Json::str(&p.addr)),
                    ])
                }
                Err(_) => {
                    if let Some(j) = &shared.journal {
                        j.record_end(id, "failed");
                    }
                    error_response("degraded sweep panicked")
                }
            }
        }
    }
}

/// Does a peer's answer carry `ok: true`?
fn resp_is_ok(resp: &Json) -> bool {
    matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
}

/// `ring`: ring geometry + per-peer health/gauges; with `model`/`group`
/// (and optional `seed`), also resolve which node owns that pack.
fn ring_info(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    let Some(state) = shared.ring.get() else {
        anyhow::bail!("no ring configured (start `codr serve` with --ring or CODR_RING)");
    };
    let mut fields = vec![("ring".into(), state.gauges())];
    if let Some(m) = msg.get("model") {
        let model = m.as_str()?;
        let groups = parse_group_list(msg.field("group")?.as_str()?)?;
        if groups.len() != 1 {
            anyhow::bail!("`group` must name exactly one sweep group");
        }
        let seed = match msg.get("seed") {
            Some(s) => s.as_u64()?,
            None => 42,
        };
        let stem = pack_stem_for(model, &groups[0].label(), seed);
        let owner = state.owner_of(&stem);
        fields.push((
            "pack".into(),
            Json::Obj(vec![
                ("stem".into(), Json::str(&stem)),
                ("owner".into(), Json::str(state.node(owner))),
                ("owned".into(), Json::Bool(owner == state.self_idx())),
            ]),
        ));
    }
    Ok(ok_response(fields))
}

/// `repair`: merge entries another ring node pushed for a pack this node
/// owns. The merge runs through the store's normal upsert path (save
/// lock + advisory pack lock), so pushed entries and locally-computed
/// ones interleave safely; the pusher only trims its copy on an `ok`
/// answer. Pack payloads are small (tens of entries), so the disk I/O
/// here sits on the reactor like `result` lookups do.
fn repair_merge(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    refuse_if_stopping(shared)?;
    let pack = msg.field("pack")?;
    let model = pack.field("model")?.as_str()?.to_string();
    let group = pack.field("group")?.as_str()?.to_string();
    let seed = pack.field("seed")?.as_u64()?;
    let entries = match msg.get("entries") {
        Some(e) => e.as_arr()?.to_vec(),
        None => Vec::new(),
    };
    let merged = shared
        .sched
        .store()
        .merge_repair(&model, &group, seed, entries)?;
    Ok(ok_response(vec![("merged".into(), Json::usize(merged))]))
}

/// Allocate a job id and insert a Running job into the table, pruning
/// old terminal entries past the retention cap. Shared by every
/// async-job verb (`submit`, `map`).
fn register_job(shared: &Arc<Shared>, chan: &Arc<JobChannel>) -> Result<u64> {
    let id = shared.next_job.fetch_add(1, Ordering::SeqCst);
    let mut jobs = sync::lock(&shared.jobs);
    // Checked under the jobs lock: the drain reads this table only
    // after `stop` is set, so either it observes the job inserted
    // below, or this check observes the stop and refuses — a job id
    // is never handed out for work the drain cannot see.
    refuse_if_stopping(shared)?;
    if jobs.len() >= max_retained_jobs() {
        let mut finished: Vec<u64> = jobs
            .iter()
            .filter(|(_, j)| !matches!(j.state, JobState::Running))
            .map(|(&jid, _)| jid)
            .collect();
        finished.sort_unstable();
        let excess = jobs.len() + 1 - max_retained_jobs();
        let mut expired = sync::lock(&shared.expired);
        for old in finished.into_iter().take(excess) {
            jobs.remove(&old);
            if expired.len() == EXPIRED_RING {
                expired.pop_front();
            }
            expired.push_back(old);
        }
    }
    jobs.insert(
        id,
        Job {
            state: JobState::Running,
            chan: Arc::clone(chan),
        },
    );
    Ok(id)
}

/// `submit`: enqueue the grid on the executor pool, reply immediately
/// with a job id for `status` polling or `watch` streaming — or with
/// `state:"queued-full"` when the admission queue is at the cap.
fn submit(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    let grid = GridRequest::from_json(msg)?;
    match spawn_grid_job(shared, grid, Admission::Bounded)? {
        Spawned::Job { id, points } => Ok(ok_response(vec![
            ("job".into(), Json::u64(id)),
            ("points".into(), Json::usize(points)),
        ])),
        Spawned::QueuedFull { queued } => {
            Ok(queued_full_response(queued, shared.exec.cap()))
        }
    }
}

/// Register + journal + enqueue one sweep job. Shared by the `submit`
/// verb and by journal recovery at startup. Admission is checked
/// *before* the job is registered or journaled — a refused submit burns
/// no id and leaves no journal record (only the reactor thread admits,
/// so the check cannot race). The submit record lands (fsynced) before
/// this returns, so an acked job is always recoverable; the executor
/// task writes the terminal record.
pub(crate) fn spawn_grid_job(
    shared: &Arc<Shared>,
    grid: GridRequest,
    admission: Admission,
) -> Result<Spawned> {
    if matches!(admission, Admission::Bounded) {
        let queued = shared.exec.queue_len();
        if queued >= shared.exec.cap() {
            return Ok(Spawned::QueuedFull { queued });
        }
    }
    let points = grid.points();
    let chan = Arc::new(JobChannel::new(points, Arc::clone(&shared.notify)));
    let id = register_job(shared, &chan)?;
    if let Some(j) = &shared.journal {
        j.record_submit(id, &grid.to_json());
    }
    let shared_task = Arc::clone(shared);
    let task_chan = Arc::clone(&chan);
    let task = move || {
        let progress = |p: &PointDone<'_>| task_chan.publish_point(id, p);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared_task.sched.run_grid_observed(
                &grid.models,
                &grid.groups,
                &grid.archs,
                grid.seed,
                Some(&progress),
            )
        }));
        let (state, terminal, end) = match outcome {
            Ok(results) => {
                // `partial`: the grid finished but some points' compute
                // panicked (isolated) — their results were neither
                // produced nor stored. Still terminal: resubmitting
                // retries just the failed points (the rest are hits).
                let terminal = if results.stats.failed > 0 {
                    "partial"
                } else {
                    "done"
                };
                let end = Json::Obj(vec![
                    ("event".into(), Json::str("end")),
                    ("job".into(), Json::u64(id)),
                    ("state".into(), Json::str(terminal)),
                    ("stats".into(), stats_to_json(&results.stats)),
                ]);
                (JobState::Done(results.stats), terminal, end)
            }
            Err(_) => (
                JobState::Failed("sweep worker panicked".into()),
                "failed",
                Json::Obj(vec![
                    ("event".into(), Json::str("end")),
                    ("job".into(), Json::u64(id)),
                    ("state".into(), Json::str("failed")),
                    ("error".into(), Json::str("sweep worker panicked")),
                ]),
            ),
        };
        if let Some(job) = sync::lock(&shared_task.jobs).get_mut(&id) {
            job.state = state;
        }
        if let Some(j) = &shared_task.journal {
            j.record_end(id, terminal);
        }
        task_chan.close(end);
    };
    if !shared.exec.submit_unbounded(Box::new(task)) {
        // Hard stop raced the enqueue: the task will never run. Fail the
        // job so `status`/`watch` terminate instead of hanging Running.
        let err = "server is shutting down; not accepting new work";
        if let Some(job) = sync::lock(&shared.jobs).get_mut(&id) {
            job.state = JobState::Failed(err.into());
        }
        if let Some(j) = &shared.journal {
            j.record_end(id, "failed");
        }
        chan.close(Json::Obj(vec![
            ("event".into(), Json::str("end")),
            ("job".into(), Json::u64(id)),
            ("state".into(), Json::str("failed")),
            ("error".into(), Json::str(err)),
        ]));
        anyhow::bail!(err);
    }
    Ok(Spawned::Job { id, points })
}

/// `map`: run a mapping-space search for one layer as an async job on
/// the executor pool (bounded admission, like `submit`). Each evaluated
/// candidate publishes a `point` event on the job's channel (`group`
/// carries the candidate's tile label, `arch` is always CoDR); the
/// terminal `end` event carries search stats plus the full Pareto front
/// as `map`.
fn map_submit(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    if let Some(resp) = admission_full(shared) {
        return Ok(resp);
    }
    let name = msg.field("model")?.as_str()?;
    let model = crate::models::parse_model(name)?;
    let layer: Option<String> = match msg.get("layer") {
        Some(l) => Some(l.as_str()?.to_string()),
        None => None,
    };
    let group = match msg.get("group") {
        Some(g) => {
            let gs = parse_group_list(g.as_str()?)?;
            if gs.len() != 1 {
                anyhow::bail!("`group` must name exactly one sweep group");
            }
            gs[0]
        }
        None => SweepGroup::Original,
    };
    let seed = match msg.get("seed") {
        Some(s) => s.as_u64()?,
        None => 42,
    };
    let mut cfg = SearchConfig::default();
    if let Some(m) = msg.get("max_candidates") {
        cfg.max_candidates = m.as_u64()?.max(1) as usize;
    }
    if let Some(q) = msg.get("quick") {
        cfg.quick = q.as_bool()?;
    }
    // Resolve the searched layer now (pure — no weights needed) so the
    // reply and the channel carry the real candidate count.
    let spec = model
        .layers
        .iter()
        .filter(|l| l.kind == LayerKind::Conv)
        .find(|l| layer.as_deref().map(|n| l.name == n).unwrap_or(true))
        .ok_or_else(|| match &layer {
            Some(n) => anyhow::anyhow!("model {name} has no conv layer named `{n}`"),
            None => anyhow::anyhow!("model {name} has no conv layers"),
        })?
        .clone();
    let (kept, ..) = enumerate_mappings(&spec, &Codr::default(), &cfg);
    let candidates = kept.len();
    let layer_name = spec.name.clone();
    let chan = Arc::new(JobChannel::new(candidates, Arc::clone(&shared.notify)));
    let id = register_job(shared, &chan)?;
    let shared_task = Arc::clone(shared);
    let task_chan = Arc::clone(&chan);
    let task = move || {
        let t0 = Instant::now();
        let progress = |c: &crate::mapping::CandidateResult| {
            task_chan.publish_point(
                id,
                &PointDone {
                    model: model.name,
                    group: c.mapping.tile_label(),
                    arch: "CoDR",
                    cache_hit: c.cache_hit,
                    error: None,
                },
            );
        };
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared_task.sched.run_map(
                &model,
                Some(spec.name.as_str()),
                group,
                seed,
                &cfg,
                Some(&progress),
            )
        }));
        let (state, end) = match outcome {
            Ok(Ok(report)) => {
                let stats = SweepStats {
                    requested: report.enumerated,
                    cache_hits: report.cache_hits,
                    computed: report.evaluated - report.cache_hits,
                    wall_ms: t0.elapsed().as_millis() as u64,
                    ..Default::default()
                };
                let end = Json::Obj(vec![
                    ("event".into(), Json::str("end")),
                    ("job".into(), Json::u64(id)),
                    ("state".into(), Json::str("done")),
                    ("stats".into(), stats_to_json(&stats)),
                    ("map".into(), report.to_json()),
                ]);
                (JobState::Done(stats), end)
            }
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                (
                    JobState::Failed(msg.clone()),
                    Json::Obj(vec![
                        ("event".into(), Json::str("end")),
                        ("job".into(), Json::u64(id)),
                        ("state".into(), Json::str("failed")),
                        ("error".into(), Json::Str(msg)),
                    ]),
                )
            }
            Err(_) => (
                JobState::Failed("map worker panicked".into()),
                Json::Obj(vec![
                    ("event".into(), Json::str("end")),
                    ("job".into(), Json::u64(id)),
                    ("state".into(), Json::str("failed")),
                    ("error".into(), Json::str("map worker panicked")),
                ]),
            ),
        };
        if let Some(job) = sync::lock(&shared_task.jobs).get_mut(&id) {
            job.state = state;
        }
        task_chan.close(end);
    };
    if !shared.exec.submit_unbounded(Box::new(task)) {
        let err = "server is shutting down; not accepting new work";
        if let Some(job) = sync::lock(&shared.jobs).get_mut(&id) {
            job.state = JobState::Failed(err.into());
        }
        chan.close(Json::Obj(vec![
            ("event".into(), Json::str("end")),
            ("job".into(), Json::u64(id)),
            ("state".into(), Json::str("failed")),
            ("error".into(), Json::str(err)),
        ]));
        anyhow::bail!(err);
    }
    Ok(ok_response(vec![
        ("job".into(), Json::u64(id)),
        ("layer".into(), Json::str(layer_name)),
        ("candidates".into(), Json::usize(candidates)),
    ]))
}

/// `status`: with `job`, that job's state; without, server-wide counters.
fn status(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    if let Some(job) = msg.get("job") {
        let id = job.as_u64()?;
        let state = sync::lock(&shared.jobs).get(&id).map(|j| j.state.clone());
        let mut fields = vec![("job".into(), Json::u64(id))];
        match state {
            Some(JobState::Running) => fields.push(("state".into(), Json::str("running"))),
            Some(JobState::Done(stats)) => {
                let state = if stats.failed > 0 { "partial" } else { "done" };
                fields.push(("state".into(), Json::str(state)));
                fields.push(("stats".into(), stats_to_json(&stats)));
            }
            Some(JobState::Failed(err)) => {
                fields.push(("state".into(), Json::str("failed")));
                fields.push(("error".into(), Json::Str(err)));
            }
            None => {
                // A pruned terminal id and a never-issued id are
                // different answers: the former is a completed job the
                // client was too slow to poll, the latter a client bug.
                if !sync::lock(&shared.expired).contains(&id) {
                    anyhow::bail!("unknown job {id}");
                }
                fields.push(("state".into(), Json::str("expired")));
            }
        }
        return Ok(ok_response(fields));
    }
    let jobs = sync::lock(&shared.jobs);
    let running = jobs
        .values()
        .filter(|j| matches!(j.state, JobState::Running))
        .count();
    let jobs_len = jobs.len();
    drop(jobs);
    let store = shared.sched.store();
    let st = store.stats();
    let cache = memo::global();
    let memo = cache.breakdown();
    let (arena_entries, arena_bytes, arena_tombstoned) = cache.arena_stats();
    let mut fields = vec![
        ("jobs".into(), Json::usize(jobs_len)),
        ("running".into(), Json::usize(running)),
        (
            "warming".into(),
            Json::usize(shared.warms.load(Ordering::SeqCst)),
        ),
        (
            "watchers".into(),
            Json::usize(shared.watchers.load(Ordering::SeqCst)),
        ),
        (
            "conns".into(),
            Json::usize(shared.conns.load(Ordering::SeqCst)),
        ),
        (
            "queued".into(),
            Json::usize(shared.exec.queue_len()),
        ),
        (
            "max_queued".into(),
            Json::usize(shared.exec.cap()),
        ),
        (
            "executors".into(),
            Json::usize(shared.exec.workers()),
        ),
        // Per-verb request/answer/error counts and p50/p99 latency.
        ("verbs".into(), shared.metrics.to_json()),
        // Kept for pre-v2 clients; the structured `store` object is the
        // forward surface.
        ("store_entries".into(), Json::usize(st.entries)),
        (
            "store".into(),
            Json::Obj(vec![
                ("entries".into(), Json::usize(st.entries)),
                ("packed_files".into(), Json::usize(st.packed_files)),
                ("v1_files".into(), Json::usize(st.v1_files)),
                ("bytes".into(), Json::u64(st.bytes)),
                (
                    "cap_bytes".into(),
                    match store.cap_bytes() {
                        Some(b) => Json::u64(b),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
        (
            "memo".into(),
            Json::Obj(vec![
                ("entries".into(), Json::usize(cache.len())),
                // `hits` spans both levels (kept for pre-fingerprint
                // clients); the breakdown fields are the forward surface.
                ("hits".into(), Json::u64(memo.hits())),
                ("misses".into(), Json::u64(memo.misses)),
                ("evictions".into(), Json::u64(memo.evictions)),
                ("lookups".into(), Json::u64(memo.lookups)),
                ("l1_hits".into(), Json::u64(memo.l1_hits)),
                ("l2_hits".into(), Json::u64(memo.l2_hits)),
                ("collision_verifies".into(), Json::u64(memo.collision_verifies)),
                ("double_computes".into(), Json::u64(memo.double_computes)),
                ("lock_waits".into(), Json::u64(memo.lock_waits)),
                (
                    "arena".into(),
                    Json::Obj(vec![
                        ("entries".into(), Json::usize(arena_entries)),
                        ("bytes".into(), Json::u64(arena_bytes)),
                        // Bytes held by tombstoned (dead, not yet
                        // compacted) interned vectors — the arena's
                        // reclaimable slack.
                        ("tombstoned_bytes".into(), Json::u64(arena_tombstoned)),
                    ]),
                ),
            ]),
        ),
    ];
    if let Some(state) = shared.ring.get() {
        fields.push(("ring".into(), state.gauges()));
    }
    Ok(ok_response(fields))
}

/// `result`: summarize one stored point without simulating anything.
fn result_lookup(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    let model = msg.field("model")?.as_str()?;
    let group_spec = msg.field("group")?.as_str()?;
    let groups = parse_group_list(group_spec)?;
    if groups.len() != 1 {
        anyhow::bail!("`group` must name exactly one sweep group, got `{group_spec}`");
    }
    let group = &groups[0];
    let arch = Arch::parse(msg.field("arch")?.as_str()?)?;
    let seed = match msg.get("seed") {
        Some(s) => s.as_u64()?,
        None => 42,
    };
    let key = CacheKey::for_point(
        model,
        group,
        arch.name(),
        &arch.build().tile_config(),
        &MemConfig::default(),
        seed,
    );
    match shared.sched.store().load(&key) {
        LoadOutcome::Hit(r) => {
            let c = r.compression();
            Ok(ok_response(vec![
                ("model".into(), Json::str(model)),
                ("group".into(), Json::str(group.label())),
                ("arch".into(), Json::str(arch.name())),
                ("seed".into(), Json::u64(seed)),
                ("layers".into(), Json::usize(r.layers.len())),
                ("cycles".into(), Json::u64(r.cycles())),
                ("sram_accesses".into(), Json::u64(r.mem().sram_accesses())),
                ("energy_uj".into(), Json::f64(r.energy().total_uj())),
                (
                    "bits_per_weight".into(),
                    Json::f64(c.bits_per_weight()),
                ),
            ]))
        }
        LoadOutcome::Miss => Err(anyhow::anyhow!(
            "point not in store — warm it first (`codr warm` or the warm verb)"
        )),
        LoadOutcome::Corrupt => Err(anyhow::anyhow!(
            "store entry for that point is corrupt; re-warm to recompute"
        )),
    }
}
