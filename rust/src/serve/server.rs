//! The long-running sweep service behind `codr serve`.
//!
//! Blocking std::net (tokio is unavailable offline): a poll-accept loop
//! hands each connection to its own thread; every connection can issue
//! any number of line-delimited JSON requests. All connections share one
//! [`Scheduler`], so the in-flight dedup spans clients — two clients
//! warming the same grid simulate it once.
//!
//! Verbs: `ping`, `warm` (synchronous sweep), `submit` (async job),
//! `status` (job or server), `result` (store lookup), `shutdown`.

use super::proto::{
    error_response, ok_response, read_message, stats_to_json, write_message, GridRequest,
};
use super::scheduler::Scheduler;
use super::store::{CacheKey, LoadOutcome, ResultStore};
use crate::arch::MemConfig;
use crate::coordinator::{Arch, SweepStats};
use crate::models::parse_group_list;
use crate::reuse::memo;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Progress of one submitted job.
#[derive(Clone, Debug)]
enum JobState {
    Running,
    Done(SweepStats),
    Failed(String),
}

/// Shared server state: the scheduler (store + in-flight claims) plus the
/// job table.
struct Shared {
    sched: Scheduler,
    jobs: Mutex<HashMap<u64, JobState>>,
    next_job: AtomicU64,
    stop: AtomicBool,
}

/// A bound, not-yet-running sweep service.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

/// Where the persistent memo snapshot for a store lives, honoring
/// `CODR_MEMO_SNAPSHOT` (`off`/`0`/empty disables, any other value is a
/// path override; unset defaults to `<store>/memo.snapshot`).
pub fn memo_snapshot_path(store_dir: &Path) -> Option<std::path::PathBuf> {
    match std::env::var("CODR_MEMO_SNAPSHOT") {
        Ok(v) if v.is_empty() || v == "off" || v == "0" => None,
        Ok(v) => Some(std::path::PathBuf::from(v)),
        Err(_) => Some(store_dir.join("memo.snapshot")),
    }
}

impl Server {
    /// Bind the service. `addr` may use port 0 to pick a free port (the
    /// tests do); `store_dir` is created if missing.
    pub fn bind(addr: &str, store_dir: &Path) -> Result<Server> {
        Self::bind_with(addr, ResultStore::open(store_dir)?)
    }

    /// Bind the service over an already-opened store (the CLI uses this
    /// to apply `--store-cap-mb`).
    pub fn bind_with(addr: &str, store: ResultStore) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding codr serve to {addr}"))?;
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                sched: Scheduler::new(store),
                jobs: Mutex::new(HashMap::new()),
                next_job: AtomicU64::new(1),
                stop: AtomicBool::new(false),
            }),
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Accept-and-serve until a `shutdown` request arrives. Consumes the
    /// server; each connection runs on its own thread.
    ///
    /// The persistent vector memo brackets the accept loop: a snapshot
    /// from a previous process is restored lazily (on a background
    /// thread — binding and first requests never wait on it; until it
    /// lands, lookups simply miss and recompute), and the memo is
    /// snapshotted back on clean shutdown so the next process starts
    /// warm.
    pub fn run(self) -> Result<()> {
        let snapshot = memo_snapshot_path(self.shared.sched.store().dir());
        if let Some(path) = snapshot.clone() {
            std::thread::spawn(move || match memo::global().load_snapshot(&path) {
                Ok(n) if n > 0 => eprintln!("memo: restored {n} vectors from {}", path.display()),
                Ok(_) => {}
                Err(e) => eprintln!("warn: memo snapshot unusable ({e:#}); starting cold"),
            });
        }
        self.listener
            .set_nonblocking(true)
            .context("setting listener nonblocking")?;
        loop {
            if self.shared.stop.load(Ordering::SeqCst) {
                if let Some(path) = &snapshot {
                    match memo::global().save_snapshot(path, memo::snapshot_cap_bytes()) {
                        Ok(n) => eprintln!("memo: snapshotted {n} vectors to {}", path.display()),
                        Err(e) => eprintln!("warn: failed to snapshot memo: {e:#}"),
                    }
                }
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let shared = Arc::clone(&self.shared);
                    std::thread::spawn(move || {
                        if let Err(e) = serve_connection(stream, &shared) {
                            eprintln!("warn: connection ended with error: {e:#}");
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => return Err(e).context("accepting connection"),
            }
        }
    }
}

fn serve_connection(stream: TcpStream, shared: &Arc<Shared>) -> Result<()> {
    stream
        .set_nonblocking(false)
        .context("setting stream blocking")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    loop {
        let msg = match read_message(&mut reader) {
            Ok(Some(m)) => m,
            Ok(None) => return Ok(()), // clean EOF
            Err(e) => {
                // Malformed request: answer with the error, then drop the
                // connection (framing may be lost).
                let _ = write_message(&mut writer, &error_response(format!("{e:#}")));
                return Ok(());
            }
        };
        let response = handle_request(&msg, shared);
        write_message(&mut writer, &response)?;
        if shared.stop.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// Dispatch one request. Never panics on client input: every failure
/// becomes an `ok:false` response.
fn handle_request(msg: &Json, shared: &Arc<Shared>) -> Json {
    let verb = match msg.get("verb").map(|v| v.as_str()) {
        Some(Ok(v)) => v.to_string(),
        _ => return error_response("request must carry a string `verb`"),
    };
    let result = match verb.as_str() {
        "ping" => Ok(ok_response(vec![("pong".into(), Json::Bool(true))])),
        "warm" => warm(msg, shared),
        "submit" => submit(msg, shared),
        "status" => status(msg, shared),
        "result" => result_lookup(msg, shared),
        "shutdown" => {
            shared.stop.store(true, Ordering::SeqCst);
            Ok(ok_response(vec![(
                "stopping".into(),
                Json::Bool(true),
            )]))
        }
        other => Err(anyhow::anyhow!(
            "unknown verb `{other}` (use ping|warm|submit|status|result|shutdown)"
        )),
    };
    result.unwrap_or_else(|e| error_response(format!("{e:#}")))
}

/// `warm`: run the requested grid synchronously, reply with stats.
/// Store occupancy is deliberately NOT included here: counting packed
/// entries parses every pack file (an O(store-bytes) walk that belongs
/// on the `status` path, not on every warm request).
fn warm(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    let grid = GridRequest::from_json(msg)?;
    let results = shared
        .sched
        .run_grid(&grid.models, &grid.groups, &grid.archs, grid.seed);
    Ok(ok_response(vec![(
        "stats".into(),
        stats_to_json(&results.stats),
    )]))
}

/// `submit`: run the grid on a worker thread, reply immediately with a
/// job id for `status` polling.
/// Finished jobs retained for `status` polling; beyond this the oldest
/// terminal entries are pruned so a long-lived server's job table stays
/// bounded.
const MAX_RETAINED_JOBS: usize = 256;

fn submit(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    let grid = GridRequest::from_json(msg)?;
    let id = shared.next_job.fetch_add(1, Ordering::SeqCst);
    {
        let mut jobs = shared.jobs.lock().unwrap();
        if jobs.len() >= MAX_RETAINED_JOBS {
            let mut finished: Vec<u64> = jobs
                .iter()
                .filter(|(_, s)| !matches!(s, JobState::Running))
                .map(|(&jid, _)| jid)
                .collect();
            finished.sort_unstable();
            let excess = jobs.len() + 1 - MAX_RETAINED_JOBS;
            for old in finished.into_iter().take(excess) {
                jobs.remove(&old);
            }
        }
        jobs.insert(id, JobState::Running);
    }
    let shared_worker = Arc::clone(shared);
    std::thread::spawn(move || {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared_worker
                .sched
                .run_grid(&grid.models, &grid.groups, &grid.archs, grid.seed)
        }));
        let state = match outcome {
            Ok(results) => JobState::Done(results.stats),
            Err(_) => JobState::Failed("sweep worker panicked".into()),
        };
        shared_worker.jobs.lock().unwrap().insert(id, state);
    });
    Ok(ok_response(vec![
        ("job".into(), Json::u64(id)),
        ("points".into(), Json::usize(grid.points())),
    ]))
}

/// `status`: with `job`, that job's state; without, server-wide counters.
fn status(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    if let Some(job) = msg.get("job") {
        let id = job.as_u64()?;
        let state = shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .with_context(|| format!("unknown job {id}"))?;
        let mut fields = vec![("job".into(), Json::u64(id))];
        match state {
            JobState::Running => fields.push(("state".into(), Json::str("running"))),
            JobState::Done(stats) => {
                fields.push(("state".into(), Json::str("done")));
                fields.push(("stats".into(), stats_to_json(&stats)));
            }
            JobState::Failed(err) => {
                fields.push(("state".into(), Json::str("failed")));
                fields.push(("error".into(), Json::Str(err)));
            }
        }
        return Ok(ok_response(fields));
    }
    let jobs = shared.jobs.lock().unwrap();
    let running = jobs
        .values()
        .filter(|s| matches!(s, JobState::Running))
        .count();
    let store = shared.sched.store();
    let st = store.stats();
    let cache = memo::global();
    let (memo_hits, memo_misses) = cache.counters();
    Ok(ok_response(vec![
        ("jobs".into(), Json::usize(jobs.len())),
        ("running".into(), Json::usize(running)),
        // Kept for pre-v2 clients; the structured `store` object is the
        // forward surface.
        ("store_entries".into(), Json::usize(st.entries)),
        (
            "store".into(),
            Json::Obj(vec![
                ("entries".into(), Json::usize(st.entries)),
                ("packed_files".into(), Json::usize(st.packed_files)),
                ("v1_files".into(), Json::usize(st.v1_files)),
                ("bytes".into(), Json::u64(st.bytes)),
                (
                    "cap_bytes".into(),
                    match store.cap_bytes() {
                        Some(b) => Json::u64(b),
                        None => Json::Null,
                    },
                ),
            ]),
        ),
        (
            "memo".into(),
            Json::Obj(vec![
                ("entries".into(), Json::usize(cache.len())),
                ("hits".into(), Json::u64(memo_hits)),
                ("misses".into(), Json::u64(memo_misses)),
                ("evictions".into(), Json::u64(cache.evictions())),
            ]),
        ),
    ]))
}

/// `result`: summarize one stored point without simulating anything.
fn result_lookup(msg: &Json, shared: &Arc<Shared>) -> Result<Json> {
    let model = msg.field("model")?.as_str()?;
    let group_spec = msg.field("group")?.as_str()?;
    let groups = parse_group_list(group_spec)?;
    if groups.len() != 1 {
        anyhow::bail!("`group` must name exactly one sweep group, got `{group_spec}`");
    }
    let group = &groups[0];
    let arch = Arch::parse(msg.field("arch")?.as_str()?)?;
    let seed = match msg.get("seed") {
        Some(s) => s.as_u64()?,
        None => 42,
    };
    let key = CacheKey::for_point(
        model,
        group,
        arch.name(),
        &arch.build().tile_config(),
        &MemConfig::default(),
        seed,
    );
    match shared.sched.store().load(&key) {
        LoadOutcome::Hit(r) => {
            let c = r.compression();
            Ok(ok_response(vec![
                ("model".into(), Json::str(model)),
                ("group".into(), Json::str(group.label())),
                ("arch".into(), Json::str(arch.name())),
                ("seed".into(), Json::u64(seed)),
                ("layers".into(), Json::usize(r.layers.len())),
                ("cycles".into(), Json::u64(r.cycles())),
                ("sram_accesses".into(), Json::u64(r.mem().sram_accesses())),
                ("energy_uj".into(), Json::f64(r.energy().total_uj())),
                (
                    "bits_per_weight".into(),
                    Json::f64(c.bits_per_weight()),
                ),
            ]))
        }
        LoadOutcome::Miss => Err(anyhow::anyhow!(
            "point not in store — warm it first (`codr warm` or the warm verb)"
        )),
        LoadOutcome::Corrupt => Err(anyhow::anyhow!(
            "store entry for that point is corrupt; re-warm to recompute"
        )),
    }
}
