//! Nonblocking readiness loop for `codr serve`.
//!
//! The reactor is **one thread** owning every client socket. It multiplexes
//! with `epoll(7)` on Linux (falling back to `poll(2)` if the kernel
//! refuses an epoll fd) and plain `poll(2)` elsewhere — both via raw libc
//! declarations, since the offline registry has no tokio/mio/libc crates.
//!
//! Each connection is a small state machine:
//!
//! * **Idle** — bytes accumulate in a read buffer; complete `\n`-terminated
//!   JSON lines are parsed and dispatched. Cheap verbs (`ping`, `submit`,
//!   `map`, `status`, `result`, `watch` attach, `shutdown`) are answered
//!   inline on the reactor; answers land in a write buffer that flushes on
//!   writability.
//! * **AwaitWarm** — a `warm` grid is running on the executor pool
//!   ([`crate::serve::exec`]); the finished stats come back through the
//!   completion mailbox and the self-pipe waker.
//! * **Watching** — the connection streams job events. Worker threads never
//!   touch the socket: they publish to the job channel and ring the waker;
//!   the reactor copies fresh events into the write buffer (`events_from`
//!   cursor per watcher, so late attachment still replays exactly once).
//!
//! Idle connections are reaped by a lazy deadline heap (`--conn-timeout-secs`),
//! and shutdown runs the same drain contract as the old thread-per-connection
//! server: stop accepting, let running jobs/warms finish within
//! `--drain-secs`, abandon stragglers with the exact same warning, then give
//! watchers a short window to flush their terminal events.
//!
//! Locking note for `codr analyze`: the reactor's own state (connection map,
//! deadline heap, poller registry) is single-threaded and lock-free; the only
//! shared lock it introduces is the notifier `inbox`, a leaf like the job
//! channels (never wraps another acquisition).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::proto::{error_response, MAX_LINE_BYTES};
use crate::serve::server::{self, JobChannel, Shared};
use crate::util::json::Json;
use crate::util::sync;

// ------------------------------------------------------------------ syscalls

/// Minimal libc surface: pipes, nonblocking fcntl, poll, and (Linux) epoll.
/// The std runtime already links libc, so these resolve without a crate.
mod sys {
    #![allow(non_camel_case_types)]
    use std::os::raw::{c_int, c_short, c_void};

    #[cfg(target_os = "linux")]
    pub type nfds_t = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type nfds_t = std::os::raw::c_uint;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
    pub const POLLNVAL: c_short = 0x020;

    pub const F_GETFL: c_int = 3;
    pub const F_SETFL: c_int = 4;
    #[cfg(target_os = "linux")]
    pub const O_NONBLOCK: c_int = 0x800;
    #[cfg(not(target_os = "linux"))]
    pub const O_NONBLOCK: c_int = 0x4;

    #[cfg(target_os = "linux")]
    pub const EPOLL_CLOEXEC: c_int = 0x80000;
    #[cfg(target_os = "linux")]
    pub const EPOLL_CTL_ADD: c_int = 1;
    #[cfg(target_os = "linux")]
    pub const EPOLL_CTL_DEL: c_int = 2;
    #[cfg(target_os = "linux")]
    pub const EPOLL_CTL_MOD: c_int = 3;
    #[cfg(target_os = "linux")]
    pub const EPOLLIN: u32 = 0x001;
    #[cfg(target_os = "linux")]
    pub const EPOLLOUT: u32 = 0x004;
    #[cfg(target_os = "linux")]
    pub const EPOLLERR: u32 = 0x008;
    #[cfg(target_os = "linux")]
    pub const EPOLLHUP: u32 = 0x010;

    /// `struct epoll_event`; packed on x86-64, natural alignment elsewhere.
    #[cfg(target_os = "linux")]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
        pub fn poll(fds: *mut PollFd, nfds: nfds_t, timeout: c_int) -> c_int;
        #[cfg(target_os = "linux")]
        pub fn epoll_create1(flags: c_int) -> c_int;
        #[cfg(target_os = "linux")]
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        #[cfg(target_os = "linux")]
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
    }
}

fn set_nonblocking_fd(fd: RawFd) -> std::io::Result<()> {
    let flags = unsafe { sys::fcntl(fd, sys::F_GETFL) };
    if flags < 0 {
        return Err(std::io::Error::last_os_error());
    }
    if unsafe { sys::fcntl(fd, sys::F_SETFL, flags | sys::O_NONBLOCK) } < 0 {
        return Err(std::io::Error::last_os_error());
    }
    Ok(())
}

// -------------------------------------------------------------------- poller

/// What a registered fd should wake the loop for.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) struct Interest {
    pub read: bool,
    pub write: bool,
}

/// A readiness event. Errors and hangups report as both readable and
/// writable so the owning state machine discovers them on its next I/O.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

enum Backend {
    /// Linux epoll instance (owned fd).
    #[cfg(target_os = "linux")]
    Epoll(RawFd),
    /// Portable `poll(2)`: the fd set is rebuilt from `regs` each wait.
    Poll,
}

/// Readiness multiplexer over raw fds, keyed by caller-chosen tokens.
pub(crate) struct Poller {
    backend: Backend,
    regs: HashMap<usize, (RawFd, Interest)>,
}

impl Default for Poller {
    fn default() -> Self {
        Self::new()
    }
}

impl Poller {
    /// Prefer epoll on Linux; fall back to `poll(2)` if the kernel refuses
    /// (containers occasionally filter the syscall) and everywhere else.
    pub fn new() -> Poller {
        #[cfg(target_os = "linux")]
        {
            let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
            if fd >= 0 {
                return Poller { backend: Backend::Epoll(fd), regs: HashMap::new() };
            }
            eprintln!(
                "warn: epoll unavailable ({}); serving via poll(2)",
                std::io::Error::last_os_error()
            );
        }
        Poller::poll_only()
    }

    /// Force the portable `poll(2)` backend (exercised by unit tests so the
    /// fallback path stays honest on Linux CI too).
    pub fn poll_only() -> Poller {
        Poller { backend: Backend::Poll, regs: HashMap::new() }
    }

    pub fn register(&mut self, token: usize, fd: RawFd, interest: Interest) -> std::io::Result<()> {
        #[cfg(target_os = "linux")]
        if let Backend::Epoll(ep) = &self.backend {
            let mut ev = sys::EpollEvent { events: epoll_mask(interest), data: token as u64 };
            if unsafe { sys::epoll_ctl(*ep, sys::EPOLL_CTL_ADD, fd, &mut ev) } < 0 {
                return Err(std::io::Error::last_os_error());
            }
        }
        self.regs.insert(token, (fd, interest));
        Ok(())
    }

    pub fn modify(&mut self, token: usize, interest: Interest) -> std::io::Result<()> {
        let Some((fd, slot)) = self.regs.get_mut(&token) else {
            return Ok(());
        };
        let fd = *fd;
        *slot = interest;
        #[cfg(target_os = "linux")]
        if let Backend::Epoll(ep) = &self.backend {
            let mut ev = sys::EpollEvent { events: epoll_mask(interest), data: token as u64 };
            if unsafe { sys::epoll_ctl(*ep, sys::EPOLL_CTL_MOD, fd, &mut ev) } < 0 {
                return Err(std::io::Error::last_os_error());
            }
        }
        #[cfg(not(target_os = "linux"))]
        let _ = fd;
        Ok(())
    }

    pub fn deregister(&mut self, token: usize) {
        let Some((fd, _)) = self.regs.remove(&token) else {
            return;
        };
        #[cfg(target_os = "linux")]
        if let Backend::Epoll(ep) = &self.backend {
            let mut ev = sys::EpollEvent { events: 0, data: 0 };
            // The fd may already be closed by the caller; a failed DEL is fine.
            let _ = unsafe { sys::epoll_ctl(*ep, sys::EPOLL_CTL_DEL, fd, &mut ev) };
        }
        #[cfg(not(target_os = "linux"))]
        let _ = fd;
    }

    /// Wait up to `timeout` and fill `out` with readiness events. A signal
    /// interruption returns an empty set rather than an error.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> std::io::Result<()> {
        out.clear();
        let ms = i32::try_from(timeout.as_millis()).unwrap_or(i32::MAX);
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(ep) => {
                const CAP: usize = 256;
                let mut buf = [sys::EpollEvent { events: 0, data: 0 }; CAP];
                let n = unsafe { sys::epoll_wait(*ep, buf.as_mut_ptr(), CAP as i32, ms) };
                if n < 0 {
                    let e = std::io::Error::last_os_error();
                    if e.kind() == std::io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for ev in buf.iter().take(n.max(0) as usize) {
                    // Copy out of the (possibly packed) struct before use.
                    let bits = { ev.events };
                    let data = { ev.data };
                    let err = bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0;
                    out.push(Event {
                        token: data as usize,
                        readable: err || bits & sys::EPOLLIN != 0,
                        writable: err || bits & sys::EPOLLOUT != 0,
                    });
                }
                Ok(())
            }
            Backend::Poll => {
                let mut fds: Vec<sys::PollFd> = Vec::with_capacity(self.regs.len());
                let mut toks: Vec<usize> = Vec::with_capacity(self.regs.len());
                for (tok, (fd, interest)) in &self.regs {
                    let mut events = 0;
                    if interest.read {
                        events |= sys::POLLIN;
                    }
                    if interest.write {
                        events |= sys::POLLOUT;
                    }
                    fds.push(sys::PollFd { fd: *fd, events, revents: 0 });
                    toks.push(*tok);
                }
                let n = unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as sys::nfds_t, ms) };
                if n < 0 {
                    let e = std::io::Error::last_os_error();
                    if e.kind() == std::io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for (pfd, tok) in fds.iter().zip(toks) {
                    let r = pfd.revents;
                    if r == 0 {
                        continue;
                    }
                    let err = r & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0;
                    out.push(Event {
                        token: tok,
                        readable: err || r & sys::POLLIN != 0,
                        writable: err || r & sys::POLLOUT != 0,
                    });
                }
                Ok(())
            }
        }
    }
}

#[cfg(target_os = "linux")]
fn epoll_mask(interest: Interest) -> u32 {
    let mut m = 0;
    if interest.read {
        m |= sys::EPOLLIN;
    }
    if interest.write {
        m |= sys::EPOLLOUT;
    }
    m
}

impl Drop for Poller {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        if let Backend::Epoll(ep) = &self.backend {
            unsafe { sys::close(*ep) };
        }
    }
}

// --------------------------------------------------------------- self-pipe

/// A finished background task (today: `warm` grids) addressed to the
/// connection that requested it.
pub(crate) struct Completion {
    pub token: usize,
    pub verb_idx: usize,
    pub started: Instant,
    pub response: Json,
}

/// Write half of the reactor's self-pipe plus the completion mailbox.
/// Cloned (via `Arc`) into executor tasks and job channels; any thread can
/// ring the reactor awake. Writes are nonblocking and fire-and-forget: a
/// full pipe already guarantees a pending wakeup, and a closed pipe (reactor
/// gone) returns `EPIPE` harmlessly because Rust ignores `SIGPIPE`.
pub(crate) struct Notifier {
    tx: RawFd,
    inbox: Mutex<Vec<Completion>>,
}

impl Notifier {
    /// Ring the reactor without queueing anything (job-channel publishes).
    pub fn wake(&self) {
        let byte = [1u8];
        let _ = unsafe { sys::write(self.tx, byte.as_ptr().cast(), 1) };
    }

    /// Queue a completion for delivery on the loop, then ring it.
    pub fn complete(&self, c: Completion) {
        sync::lock(&self.inbox).push(c);
        self.wake();
    }

    pub fn take_completions(&self) -> Vec<Completion> {
        std::mem::take(&mut *sync::lock(&self.inbox))
    }
}

impl Drop for Notifier {
    fn drop(&mut self) {
        unsafe { sys::close(self.tx) };
    }
}

/// Read half of the self-pipe, owned by the reactor.
pub(crate) struct WakeRx(RawFd);

impl WakeRx {
    pub fn fd(&self) -> RawFd {
        self.0
    }

    /// Drain every pending wakeup byte (nonblocking).
    pub fn drain(&self) {
        let mut buf = [0u8; 256];
        loop {
            let n = unsafe { sys::read(self.0, buf.as_mut_ptr().cast(), buf.len()) };
            if n < buf.len() as isize {
                break;
            }
        }
    }
}

impl Drop for WakeRx {
    fn drop(&mut self) {
        unsafe { sys::close(self.0) };
    }
}

/// Build the self-pipe: (reactor read half, shareable write half).
pub(crate) fn wake_pair() -> std::io::Result<(WakeRx, Notifier)> {
    let mut fds = [0 as std::os::raw::c_int; 2];
    if unsafe { sys::pipe(fds.as_mut_ptr()) } != 0 {
        return Err(std::io::Error::last_os_error());
    }
    let (rx, tx) = (fds[0], fds[1]);
    for fd in [rx, tx] {
        if let Err(e) = set_nonblocking_fd(fd) {
            unsafe {
                sys::close(rx);
                sys::close(tx);
            }
            return Err(e);
        }
    }
    Ok((WakeRx(rx), Notifier { tx, inbox: Mutex::new(Vec::new()) }))
}

// ---------------------------------------------------------------- connection

const TOKEN_LISTENER: usize = 0;
const TOKEN_WAKER: usize = 1;
const FIRST_CONN_TOKEN: usize = 2;

/// How long flushed terminal events get after the drain decision, matching
/// the old server's watcher-flush window.
const FLUSH_WINDOW: Duration = Duration::from_millis(500);

/// Ring-mode maintenance cadence: peer health probes + the anti-entropy
/// repair pass are scheduled onto the executor about this often. The
/// tick itself only *submits* a task — all network I/O stays off the
/// reactor thread.
const RING_TICK: Duration = Duration::from_millis(500);

pub(crate) enum ConnState {
    /// Parsing request lines.
    Idle,
    /// A `warm` grid is on the executor pool; answer comes via completion.
    AwaitWarm,
    /// Streaming job events; `cursor` counts events already buffered.
    Watching { chan: Arc<JobChannel>, cursor: usize },
}

struct Conn {
    stream: TcpStream,
    state: ConnState,
    rbuf: Vec<u8>,
    /// Bytes of `rbuf` already scanned for a newline (avoids rescans).
    scanned: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    interest: Interest,
    last_activity: Instant,
    close_after_flush: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            state: ConnState::Idle,
            rbuf: Vec::new(),
            scanned: 0,
            wbuf: Vec::new(),
            wpos: 0,
            interest: Interest { read: true, write: false },
            last_activity: Instant::now(),
            close_after_flush: false,
            dead: false,
        }
    }

    fn pending_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }
}

/// Write as much buffered output as the socket accepts right now. Errors
/// (including a peer that vanished mid-stream) mark the connection dead so
/// the sweep deregisters it promptly.
fn flush_conn(conn: &mut Conn) {
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    if conn.wpos >= conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    } else if conn.wpos > 64 * 1024 {
        conn.wbuf.drain(..conn.wpos);
        conn.wpos = 0;
    }
}

// ------------------------------------------------------------------ reactor

enum Phase {
    Serving,
    /// Stop accepted; waiting for running jobs + warms within the deadline.
    Draining { deadline: Instant },
    /// Jobs settled (or abandoned); flushing terminal events to watchers.
    Flushing { deadline: Instant },
}

struct Reactor {
    shared: Arc<Shared>,
    poller: Poller,
    conns: HashMap<usize, Conn>,
    /// Lazy idle-reap heap: (deadline, token), earliest first. Entries are
    /// revalidated against `last_activity` when they surface, so stale ones
    /// are harmless.
    reap: BinaryHeap<Reverse<(Instant, usize)>>,
    conn_timeout: Option<Duration>,
    next_token: usize,
    /// Next ring-maintenance deadline; `None` when no ring is configured.
    next_ring_tick: Option<Instant>,
}

/// Drive the serve loop until shutdown completes. Owns every connection;
/// returns after the drain/flush sequence.
pub(crate) fn run_loop(
    listener: &TcpListener,
    shared: &Arc<Shared>,
    wake: &WakeRx,
    drain: Duration,
    conn_timeout: Option<Duration>,
) -> Result<()> {
    listener.set_nonblocking(true).context("setting the listener nonblocking")?;
    let mut r = Reactor {
        shared: Arc::clone(shared),
        poller: Poller::new(),
        conns: HashMap::new(),
        reap: BinaryHeap::new(),
        conn_timeout,
        next_token: FIRST_CONN_TOKEN,
        next_ring_tick: shared.ring.get().map(|_| Instant::now() + RING_TICK),
    };
    r.poller
        .register(TOKEN_LISTENER, listener.as_raw_fd(), Interest { read: true, write: false })
        .context("registering the listener with the poller")?;
    r.poller
        .register(TOKEN_WAKER, wake.fd(), Interest { read: true, write: false })
        .context("registering the wake pipe with the poller")?;

    let mut phase = Phase::Serving;
    let mut events: Vec<Event> = Vec::new();
    loop {
        let timeout = r.poll_timeout(&phase);
        r.poller.wait(&mut events, timeout).context("waiting for readiness events")?;

        let mut woke = false;
        for ev in events.drain(..) {
            match ev.token {
                TOKEN_LISTENER => r.accept_ready(listener, matches!(phase, Phase::Serving)),
                TOKEN_WAKER => woke = true,
                tok => {
                    if ev.readable {
                        r.conn_readable(tok);
                    }
                    if ev.writable {
                        r.conn_writable(tok);
                    }
                }
            }
        }
        if woke {
            wake.drain();
            r.deliver_completions();
            r.pump_watchers();
        }
        r.reap_idle();
        r.maybe_ring_tick(matches!(phase, Phase::Serving));
        r.sweep();

        match phase {
            Phase::Serving => {
                if r.shared.stop.load(Ordering::SeqCst) {
                    // Stop intake, let the pool finish what it holds.
                    r.poller.deregister(TOKEN_LISTENER);
                    r.shared.exec.request_stop();
                    phase = Phase::Draining { deadline: Instant::now() + drain };
                }
            }
            Phase::Draining { deadline } => {
                let (running, warming) = server::running_and_warming(&r.shared);
                let settled = running == 0 && warming == 0;
                if settled || Instant::now() >= deadline {
                    if !settled {
                        eprintln!(
                            "warn: drain deadline passed with {running} job(s) and \
                             {warming} warm(s) still running; abandoning them"
                        );
                    }
                    r.shared.exec.shutdown(deadline);
                    server::force_close_running(&r.shared);
                    r.deliver_completions();
                    r.pump_watchers();
                    r.sweep();
                    phase =
                        Phase::Flushing { deadline: deadline.max(Instant::now() + FLUSH_WINDOW) };
                }
            }
            Phase::Flushing { deadline } => {
                let flushed = r.conns.values().all(|c| {
                    !c.pending_write() && !matches!(c.state, ConnState::Watching { .. })
                });
                if flushed || Instant::now() >= deadline {
                    return Ok(());
                }
            }
        }
    }
}

impl Reactor {
    fn poll_timeout(&self, phase: &Phase) -> Duration {
        let mut cap = match phase {
            Phase::Serving => Duration::from_secs(1),
            _ => Duration::from_millis(200),
        };
        if let (Some(due), Phase::Serving) = (self.next_ring_tick, phase) {
            cap = cap.min(due.saturating_duration_since(Instant::now()));
        }
        match self.reap.peek() {
            Some(&Reverse((deadline, _))) if self.conn_timeout.is_some() => {
                cap.min(deadline.saturating_duration_since(Instant::now()))
            }
            _ => cap,
        }
    }

    /// Fire the ring-maintenance task when its deadline is due (Serving
    /// phase only — a draining server neither probes nor repairs). The
    /// task runs on the executor; overlap is prevented by the ring
    /// state's own maintenance mutex, so a slow pass simply makes later
    /// ticks no-ops.
    fn maybe_ring_tick(&mut self, serving: bool) {
        if !serving {
            return;
        }
        let Some(due) = self.next_ring_tick else {
            return;
        };
        if Instant::now() < due {
            return;
        }
        self.next_ring_tick = Some(Instant::now() + RING_TICK);
        let shared = Arc::clone(&self.shared);
        self.shared
            .exec
            .submit_unbounded(Box::new(move || server::ring_maintenance(&shared)));
    }

    fn accept_ready(&mut self, listener: &TcpListener, serving: bool) {
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if !serving || stream.set_nonblocking(true).is_err() {
                        continue; // dropped: refused during drain or unusable
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    let fd = stream.as_raw_fd();
                    let interest = Interest { read: true, write: false };
                    if self.poller.register(token, fd, interest).is_err() {
                        continue;
                    }
                    self.conns.insert(token, Conn::new(stream));
                    self.shared.conns.fetch_add(1, Ordering::SeqCst);
                    self.push_reap(token);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    eprintln!("warn: accept failed: {e}");
                    break;
                }
            }
        }
    }

    fn conn_readable(&mut self, token: usize) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        let mut chunk = [0u8; 4096];
        loop {
            // Backpressure: while an answer/stream is in flight, stop
            // slurping once a full line's worth of pipelined bytes is held.
            if !matches!(conn.state, ConnState::Idle) && conn.rbuf.len() >= MAX_LINE_BYTES {
                break;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        self.process_lines(token);
    }

    fn conn_writable(&mut self, token: usize) {
        if let Some(conn) = self.conns.get_mut(&token) {
            flush_conn(conn);
        }
    }

    /// Parse and dispatch every complete line while the connection is Idle.
    fn process_lines(&mut self, token: usize) {
        loop {
            let line = {
                let Some(conn) = self.conns.get_mut(&token) else {
                    return;
                };
                if conn.dead || conn.close_after_flush || !matches!(conn.state, ConnState::Idle) {
                    return;
                }
                match conn.rbuf[conn.scanned..].iter().position(|b| *b == b'\n') {
                    Some(off) => {
                        let end = conn.scanned + off;
                        let line: Vec<u8> = conn.rbuf.drain(..=end).collect();
                        conn.scanned = 0;
                        line
                    }
                    None => {
                        conn.scanned = conn.rbuf.len();
                        if conn.rbuf.len() > MAX_LINE_BYTES {
                            let resp =
                                error_response(format!("message exceeds {MAX_LINE_BYTES} bytes"));
                            conn.close_after_flush = true;
                            self.send(token, &resp);
                        }
                        return;
                    }
                }
            };
            let text = String::from_utf8_lossy(&line);
            let text = text.trim();
            if text.is_empty() {
                continue;
            }
            let text = text.to_string();
            self.dispatch(token, &text);
        }
    }

    /// Handle one framed request line. The reactor answers most verbs
    /// inline; `warm` rides the executor pool and `watch` re-parks the
    /// connection as a streaming watcher.
    fn dispatch(&mut self, token: usize, line: &str) {
        crate::faults::sleep_point("serve.conn.stall", Duration::from_secs(2));
        let msg = match Json::parse(line) {
            Ok(m) => m,
            Err(e) => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.close_after_flush = true;
                }
                self.send(token, &error_response(format!("{e:#}")));
                return;
            }
        };
        let verb = msg
            .get("verb")
            .and_then(|v| v.as_str().ok())
            .unwrap_or_default()
            .to_string();
        let started = Instant::now();
        let idx = self.shared.metrics.begin(&verb);
        match verb.as_str() {
            "watch" => match server::watch_attach(&msg, &self.shared) {
                Ok((ack, chan)) => {
                    self.shared.metrics.finish(idx, started, true);
                    self.send(token, &ack);
                    self.shared.watchers.fetch_add(1, Ordering::SeqCst);
                    if let Some(conn) = self.conns.get_mut(&token) {
                        conn.state = ConnState::Watching { chan, cursor: 0 };
                    } else {
                        self.shared.watchers.fetch_sub(1, Ordering::SeqCst);
                        return;
                    }
                    self.pump_one(token);
                }
                Err(e) => {
                    self.shared.metrics.finish(idx, started, false);
                    self.send(token, &error_response(format!("{e:#}")));
                }
            },
            "warm" => match server::warm_enqueue(&msg, &self.shared, token, idx, started) {
                None => {
                    if let Some(conn) = self.conns.get_mut(&token) {
                        conn.state = ConnState::AwaitWarm;
                    }
                }
                Some(resp) => {
                    self.shared.metrics.finish(idx, started, resp_ok(&resp));
                    self.send(token, &resp);
                }
            },
            // Ring mode: a submit whose pack belongs to another node is
            // forwarded off-loop; the connection parks (same contract as
            // warm) until the forward/degraded answer comes back through
            // the completion mailbox.
            "submit" if self.shared.ring.get().is_some() => {
                match server::submit_intercept(&msg, &self.shared, token, idx, started) {
                    None => {
                        if let Some(conn) = self.conns.get_mut(&token) {
                            conn.state = ConnState::AwaitWarm;
                        }
                    }
                    Some(resp) => {
                        self.shared.metrics.finish(idx, started, resp_ok(&resp));
                        self.send(token, &resp);
                    }
                }
            }
            _ => {
                let resp = server::handle_request(&msg, &self.shared);
                self.shared.metrics.finish(idx, started, resp_ok(&resp));
                self.send(token, &resp);
            }
        }
        // Mirror the blocking server: once stop is set, a connection closes
        // after its in-flight answer; watch/warm streams settle first.
        if self.shared.stop.load(Ordering::SeqCst) {
            if let Some(conn) = self.conns.get_mut(&token) {
                if matches!(conn.state, ConnState::Idle) {
                    conn.close_after_flush = true;
                }
            }
        }
    }

    /// Deliver finished executor tasks to their connections. Metrics are
    /// recorded even when the requester hung up, so conservation holds.
    fn deliver_completions(&mut self) {
        for c in self.shared.notify.take_completions() {
            self.shared.metrics.finish(c.verb_idx, c.started, resp_ok(&c.response));
            let awaiting = matches!(
                self.conns.get(&c.token).map(|conn| &conn.state),
                Some(ConnState::AwaitWarm)
            );
            if !awaiting {
                continue;
            }
            if let Some(conn) = self.conns.get_mut(&c.token) {
                conn.state = ConnState::Idle;
                conn.last_activity = Instant::now();
            }
            self.push_reap(c.token);
            self.send(c.token, &c.response);
            if self.shared.stop.load(Ordering::SeqCst) {
                if let Some(conn) = self.conns.get_mut(&c.token) {
                    conn.close_after_flush = true;
                }
            }
            self.process_lines(c.token);
        }
    }

    fn pump_watchers(&mut self) {
        let tokens: Vec<usize> = self
            .conns
            .iter()
            .filter(|(_, c)| matches!(c.state, ConnState::Watching { .. }))
            .map(|(t, _)| *t)
            .collect();
        for token in tokens {
            self.pump_one(token);
            let idle = matches!(
                self.conns.get(&token).map(|c| &c.state),
                Some(ConnState::Idle)
            );
            if idle {
                self.process_lines(token);
            }
        }
    }

    /// Copy fresh channel events into one watcher's write buffer; detach the
    /// watcher when the stream ends (or the drop fault seam fires).
    fn pump_one(&mut self, token: usize) {
        let (events, closed) = {
            let Some(conn) = self.conns.get_mut(&token) else {
                return;
            };
            let ConnState::Watching { chan, cursor } = &mut conn.state else {
                return;
            };
            let (events, closed) = chan.events_from(*cursor);
            *cursor += events.len();
            (events, closed)
        };
        for ev in &events {
            self.send(token, ev);
            if crate::faults::point("serve.watch.drop") {
                eprintln!(
                    "warn: connection ended with error: fault injected: serve.watch.drop"
                );
                self.shared.watchers.fetch_sub(1, Ordering::SeqCst);
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.state = ConnState::Idle;
                    conn.close_after_flush = true;
                }
                return;
            }
        }
        if closed {
            self.shared.watchers.fetch_sub(1, Ordering::SeqCst);
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.state = ConnState::Idle;
                conn.last_activity = Instant::now();
                if self.shared.stop.load(Ordering::SeqCst) {
                    conn.close_after_flush = true;
                }
            }
            self.push_reap(token);
        }
    }

    /// Append one line-framed JSON message and flush opportunistically.
    fn send(&mut self, token: usize, msg: &Json) {
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.wbuf.extend_from_slice(msg.to_string().as_bytes());
            conn.wbuf.push(b'\n');
            flush_conn(conn);
        }
    }

    fn push_reap(&mut self, token: usize) {
        let Some(timeout) = self.conn_timeout else {
            return;
        };
        if let Some(conn) = self.conns.get(&token) {
            self.reap.push(Reverse((conn.last_activity + timeout, token)));
        }
    }

    /// Pop due deadlines; kill connections that sat Idle past the timeout.
    /// Busy connections (mid-warm, watching) are skipped — they re-enter the
    /// heap when they return to Idle.
    fn reap_idle(&mut self) {
        let Some(timeout) = self.conn_timeout else {
            return;
        };
        let now = Instant::now();
        while let Some(&Reverse((deadline, token))) = self.reap.peek() {
            if deadline > now {
                break;
            }
            self.reap.pop();
            let verdict = match self.conns.get(&token) {
                None => None,
                Some(conn) => {
                    if !matches!(conn.state, ConnState::Idle) || conn.close_after_flush {
                        None // re-armed on the next Idle transition
                    } else {
                        Some(conn.last_activity + timeout)
                    }
                }
            };
            match verdict {
                Some(due) if due <= now => {
                    if let Some(conn) = self.conns.get_mut(&token) {
                        conn.dead = true;
                    }
                }
                Some(due) => self.reap.push(Reverse((due, token))),
                None => {}
            }
        }
    }

    /// Post-iteration housekeeping: finish pending closes, reconcile poller
    /// interest with each connection's buffers, drop dead connections.
    fn sweep(&mut self) {
        let mut dead: Vec<usize> = Vec::new();
        for (token, conn) in self.conns.iter_mut() {
            if !conn.dead && conn.close_after_flush && !conn.pending_write() {
                conn.dead = true;
            }
            if conn.dead {
                dead.push(*token);
                continue;
            }
            let want = Interest {
                read: matches!(conn.state, ConnState::Idle | ConnState::AwaitWarm)
                    || conn.rbuf.len() < MAX_LINE_BYTES,
                write: conn.pending_write(),
            };
            if want != conn.interest {
                conn.interest = want;
                let _ = self.poller.modify(*token, want);
            }
        }
        for token in dead {
            self.remove_conn(token);
        }
    }

    fn remove_conn(&mut self, token: usize) {
        self.poller.deregister(token);
        if let Some(conn) = self.conns.remove(&token) {
            if matches!(conn.state, ConnState::Watching { .. }) {
                self.shared.watchers.fetch_sub(1, Ordering::SeqCst);
            }
            self.shared.conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

fn resp_ok(msg: &Json) -> bool {
    msg.get("ok").and_then(|v| v.as_bool().ok()).unwrap_or(false)
}

// -------------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;

    fn raw_pipe() -> (RawFd, RawFd) {
        let mut fds = [0 as std::os::raw::c_int; 2];
        assert_eq!(unsafe { sys::pipe(fds.as_mut_ptr()) }, 0);
        set_nonblocking_fd(fds[0]).unwrap();
        set_nonblocking_fd(fds[1]).unwrap();
        (fds[0], fds[1])
    }

    fn close_fd(fd: RawFd) {
        unsafe { sys::close(fd) };
    }

    fn poller_sees_readable(mut poller: Poller) {
        let (rx, tx) = raw_pipe();
        poller.register(7, rx, Interest { read: true, write: false }).unwrap();

        // Nothing written yet: a short wait reports no events.
        let mut events = Vec::new();
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.iter().all(|e| e.token != 7));

        let byte = [9u8];
        assert_eq!(unsafe { sys::write(tx, byte.as_ptr().cast(), 1) }, 1);
        poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
        let ev = events.iter().find(|e| e.token == 7).expect("readable event");
        assert!(ev.readable);

        // Interest off: the pending byte no longer reports.
        poller.modify(7, Interest { read: false, write: false }).unwrap();
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.iter().all(|e| e.token != 7));

        poller.deregister(7);
        close_fd(rx);
        close_fd(tx);
    }

    #[test]
    fn default_backend_reports_readiness() {
        poller_sees_readable(Poller::new());
    }

    #[test]
    fn poll_fallback_backend_reports_readiness() {
        poller_sees_readable(Poller::poll_only());
    }

    #[test]
    fn writable_interest_reports_on_empty_pipe() {
        for mut poller in [Poller::new(), Poller::poll_only()] {
            let (rx, tx) = raw_pipe();
            poller.register(3, tx, Interest { read: false, write: true }).unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
            let ev = events.iter().find(|e| e.token == 3).expect("writable event");
            assert!(ev.writable);
            poller.deregister(3);
            close_fd(rx);
            close_fd(tx);
        }
    }

    #[test]
    fn wake_pair_delivers_completions() {
        let (rx, notifier) = wake_pair().unwrap();
        let mut poller = Poller::new();
        poller.register(TOKEN_WAKER, rx.fd(), Interest { read: true, write: false }).unwrap();

        notifier.complete(Completion {
            token: 42,
            verb_idx: 0,
            started: Instant::now(),
            response: Json::Obj(vec![("ok".into(), Json::Bool(true))]),
        });
        notifier.wake(); // extra rings coalesce harmlessly

        let mut events = Vec::new();
        poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
        assert!(events.iter().any(|e| e.token == TOKEN_WAKER && e.readable));
        rx.drain();
        let got = notifier.take_completions();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].token, 42);
        assert!(notifier.take_completions().is_empty());

        // Drained: no further readiness from the pipe.
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.iter().all(|e| e.token != TOKEN_WAKER));
    }

    #[test]
    fn wake_after_receiver_closed_is_harmless() {
        let (rx, notifier) = wake_pair().unwrap();
        drop(rx);
        notifier.wake(); // EPIPE is swallowed (std ignores SIGPIPE)
    }
}
