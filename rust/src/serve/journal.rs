//! Crash-restart job journal: `<store>/jobs.journal`.
//!
//! `codr serve` records every accepted sweep job at two points — on
//! submission (with the full grid request) and at its terminal state —
//! as append-only, checksummed line-JSON:
//!
//! ```text
//! {"check":<fnv1a64 of rec's bytes>,"rec":{"kind":"submit","job":1,"grid":{...}}}
//! {"check":...,"rec":{"kind":"end","job":1,"state":"done"}}
//! ```
//!
//! On startup the journal is replayed: a submit without a matching end
//! is a job the previous process accepted but never finished (it was
//! killed mid-grid), and the server re-queues it through the normal
//! submit path under a fresh id — recomputation is cheap because the
//! store diff turns everything the dead process persisted into hits.
//! The re-queue writes an `end` record with `state:"requeued"` for the
//! old id, so a *second* restart does not replay it again; the journal
//! is then compacted (atomic rewrite keeping only still-open records).
//!
//! Damage tolerance follows the store's discipline: every record
//! carries a checksum of its own bytes, and because the file is
//! append-only, a torn or corrupt line can only be the tail — replay
//! stops there and loses at most the record being written during the
//! crash. Appends are fsynced: a submission is journaled before its
//! `ok` response leaves the server.
//!
//! `map` jobs are deliberately NOT journaled: their results are store
//! candidates keyed the same way, but the report lives only in the job
//! channel — a crashed map search is simply re-run by the client (its
//! candidates replay as store hits).

use crate::util::hash::fnv1a64;
use crate::util::sync;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Journal file name inside the store directory.
pub const JOURNAL_FILE: &str = "jobs.journal";

/// A journaled job the previous process never finished.
#[derive(Clone, Debug)]
pub struct Recovered {
    /// The job id the dead process assigned (for log correlation only —
    /// the re-queue runs under a fresh id).
    pub job: u64,
    /// The original grid request, as submitted.
    pub grid: Json,
}

/// Append-only journal handle. Writers serialize on the internal lock;
/// appends are line-atomic from the reader's perspective because replay
/// stops at the first damaged line.
pub struct Journal {
    path: PathBuf,
    file: Mutex<std::fs::File>,
}

impl Journal {
    /// Journal path for a store directory.
    pub fn path_in(store_dir: &Path) -> PathBuf {
        store_dir.join(JOURNAL_FILE)
    }

    /// Open (creating if needed) the journal in `store_dir`, replay it,
    /// compact away everything terminal, and return the open jobs for
    /// re-queueing. The compacted rewrite is atomic (tmp + rename), so
    /// a crash during open leaves either the old journal or the
    /// compacted one — never a half-written file.
    pub fn open(store_dir: &Path) -> Result<(Journal, Vec<Recovered>)> {
        std::fs::create_dir_all(store_dir)
            .with_context(|| format!("creating store dir {}", store_dir.display()))?;
        let path = Self::path_in(store_dir);
        let open_jobs = match std::fs::read_to_string(&path) {
            Ok(text) => replay(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            // Unreadable journal: recover nothing rather than refuse to
            // serve — the store itself is intact either way.
            Err(e) => {
                eprintln!("warn: jobs.journal unreadable ({e}); starting with no recovery");
                Vec::new()
            }
        };
        // Compact: only still-open submits survive the rewrite. (They
        // are re-queued right after open; the requeued `end` records
        // then append to this fresh file.)
        let mut compacted = String::new();
        for r in &open_jobs {
            compacted.push_str(&frame(&submit_rec(r.job, &r.grid)));
            compacted.push('\n');
        }
        let tmp = store_dir.join(format!(".{JOURNAL_FILE}.tmp-{}", std::process::id()));
        // Injection seam: the compacted rewrite is torn mid-write.
        // Replay tolerates a damaged tail by construction, so a crash
        // here loses at most the last open record.
        let mut compacted = compacted.into_bytes();
        crate::faults::torn_point("journal.compact.torn", &mut compacted);
        if let Err(e) = std::fs::write(&tmp, &compacted) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("writing {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("renaming to {}", path.display()));
        }
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {} for append", path.display()))?;
        Ok((
            Journal {
                path,
                file: Mutex::new(file),
            },
            open_jobs,
        ))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Journal a job submission. Called before the `ok` response is
    /// written, so an acked job is always recoverable.
    pub fn record_submit(&self, job: u64, grid: &Json) {
        self.append(&submit_rec(job, grid));
    }

    /// Journal a job's terminal state (`done`, `partial`, `failed`, or
    /// `requeued` for the old id of a recovered job).
    pub fn record_end(&self, job: u64, state: &str) {
        self.append(&Json::Obj(vec![
            ("kind".into(), Json::str("end")),
            ("job".into(), Json::u64(job)),
            ("state".into(), Json::str(state)),
        ]));
    }

    /// Append one framed record and fsync. Best-effort by policy: a
    /// full disk must degrade recovery, not take the server down.
    fn append(&self, rec: &Json) {
        let mut guard = sync::lock(&self.file);
        let line = frame(rec);
        if let Err(e) = writeln!(guard, "{line}").and_then(|_| guard.sync_data()) {
            eprintln!(
                "warn: jobs.journal append failed ({e}); this job will not survive a crash"
            );
        }
    }
}

/// Wrap a record with its checksum. The check covers the record's exact
/// serialized bytes, and our own writer is the only producer, so
/// verify re-serializes the parsed record and compares.
fn frame(rec: &Json) -> String {
    let body = rec.to_string();
    Json::Obj(vec![
        ("check".into(), Json::u64(fnv1a64(body.as_bytes()))),
        ("rec".into(), rec.clone()),
    ])
    .to_string()
}

/// Replay journal text into the list of still-open jobs, in submission
/// order. Stops at the first damaged line (append-only ⇒ only the tail
/// can be torn).
fn replay(text: &str) -> Vec<Recovered> {
    let mut open: Vec<Recovered> = Vec::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Some(rec) = verify(line) else {
            break; // torn tail: everything before it already replayed
        };
        let kind = rec.get("kind").and_then(|k| k.as_str().ok());
        let job = rec.get("job").and_then(|j| j.as_u64().ok());
        match (kind, job) {
            (Some("submit"), Some(job)) => {
                if let Some(grid) = rec.get("grid") {
                    open.push(Recovered {
                        job,
                        grid: grid.clone(),
                    });
                }
            }
            (Some("end"), Some(job)) => open.retain(|r| r.job != job),
            // Unknown kinds are skipped, not fatal: a future build may
            // append record types this one does not know.
            _ => {}
        }
    }
    open
}

/// Parse + checksum-verify one journal line.
fn verify(line: &str) -> Option<Json> {
    let j = Json::parse(line.trim()).ok()?;
    let check = j.get("check")?.as_u64().ok()?;
    let rec = j.get("rec")?;
    if fnv1a64(rec.to_string().as_bytes()) != check {
        return None;
    }
    Some(rec.clone())
}

fn submit_rec(job: u64, grid: &Json) -> Json {
    Json::Obj(vec![
        ("kind".into(), Json::str("submit")),
        ("job".into(), Json::u64(job)),
        ("grid".into(), grid.clone()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "codr-journal-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn grid(models: &str) -> Json {
        Json::Obj(vec![("models".into(), Json::str(models))])
    }

    #[test]
    fn open_jobs_survive_a_restart_and_terminal_ones_do_not() {
        let dir = temp_dir("roundtrip");
        {
            let (j, recovered) = Journal::open(&dir).unwrap();
            assert!(recovered.is_empty());
            j.record_submit(1, &grid("tiny"));
            j.record_submit(2, &grid("alexnet"));
            j.record_end(1, "done");
        }
        let (_, recovered) = Journal::open(&dir).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].job, 2);
        assert_eq!(
            recovered[0].grid.get("models").unwrap().as_str().unwrap(),
            "alexnet"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn requeued_state_closes_the_old_id() {
        let dir = temp_dir("requeue");
        {
            let (j, _) = Journal::open(&dir).unwrap();
            j.record_submit(7, &grid("tiny"));
        }
        {
            let (j, recovered) = Journal::open(&dir).unwrap();
            assert_eq!(recovered.len(), 1);
            // The server re-queues under a fresh id and closes the old.
            j.record_submit(1, &recovered[0].grid);
            j.record_end(7, "requeued");
            j.record_end(1, "done");
        }
        let (_, recovered) = Journal::open(&dir).unwrap();
        assert!(recovered.is_empty(), "{recovered:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_bounds_the_file_across_restarts() {
        let dir = temp_dir("compact");
        {
            let (j, _) = Journal::open(&dir).unwrap();
            for n in 1..=50 {
                j.record_submit(n, &grid("tiny"));
                j.record_end(n, "done");
            }
            j.record_submit(51, &grid("tiny"));
        }
        let before = std::fs::metadata(Journal::path_in(&dir)).unwrap().len();
        let (_, recovered) = Journal::open(&dir).unwrap();
        let after = std::fs::metadata(Journal::path_in(&dir)).unwrap().len();
        assert_eq!(recovered.len(), 1);
        assert!(
            after < before / 10,
            "compaction must drop terminal records ({before} -> {after})"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_loses_only_the_last_record() {
        let dir = temp_dir("torn");
        {
            let (j, _) = Journal::open(&dir).unwrap();
            j.record_submit(1, &grid("tiny"));
            j.record_submit(2, &grid("alexnet"));
        }
        // Tear the last line mid-record, as a crash mid-append would.
        let path = Journal::path_in(&dir);
        let text = std::fs::read_to_string(&path).unwrap();
        let keep = text.trim_end().rfind('\n').unwrap() + 20;
        std::fs::write(&path, &text[..keep]).unwrap();
        let (_, recovered) = Journal::open(&dir).unwrap();
        assert_eq!(recovered.len(), 1, "{recovered:?}");
        assert_eq!(recovered[0].job, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_line_stops_replay_without_panicking() {
        let dir = temp_dir("corrupt");
        {
            let (j, _) = Journal::open(&dir).unwrap();
            j.record_submit(1, &grid("tiny"));
        }
        let path = Journal::path_in(&dir);
        // Flip a byte inside the record body: the checksum catches it.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] = bytes[mid].wrapping_add(1);
        std::fs::write(&path, &bytes).unwrap();
        let (_, recovered) = Journal::open(&dir).unwrap();
        assert!(recovered.is_empty(), "damaged record must not replay");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_journal_is_an_empty_one() {
        let dir = temp_dir("fresh");
        let (j, recovered) = Journal::open(&dir).unwrap();
        assert!(recovered.is_empty());
        assert!(j.path().exists(), "open must create the journal");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
