//! Content-addressed, on-disk result store.
//!
//! Sweep results are fully deterministic given `(model, sweep group,
//! arch, seed, accelerator config)`, so a [`ModelResult`] computed once
//! can serve every later figure. Each point is one JSON file named by the
//! point coordinates plus a 64-bit FNV-1a fingerprint of the *full*
//! canonical key — the fingerprint covers the tiling and memory
//! configuration and the store/codec versions, so a config or schema
//! change silently misses instead of serving stale numbers.
//!
//! Loads are corruption-tolerant by design: any read, parse, schema, or
//! key-mismatch failure degrades to [`LoadOutcome::Corrupt`] and the
//! caller recomputes. A broken cache can cost time, never correctness.

use crate::arch::{MemConfig, TileConfig};
use crate::models::SweepGroup;
use crate::sim::codec::{model_result_from_json, model_result_to_json, CODEC_VERSION};
use crate::sim::ModelResult;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Version of the store's file layout + envelope (independent of the
/// result schema, which [`CODEC_VERSION`] tracks).
pub const STORE_FORMAT_VERSION: u32 = 1;

/// 64-bit FNV-1a — stable, dependency-free content hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The identity of one sweep point. Two keys are interchangeable iff
/// every figure derived from their results is identical.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheKey {
    pub model: String,
    pub group: String,
    pub arch: String,
    pub seed: u64,
    /// FNV-1a of the canonical key string (includes the fields above plus
    /// the accelerator tile/memory configuration and format versions).
    pub fingerprint: u64,
}

impl CacheKey {
    /// Build the key for one sweep point under the accelerator
    /// configuration that will simulate it.
    pub fn for_point(
        model: &str,
        group: &SweepGroup,
        arch: &str,
        tile: &TileConfig,
        mem: &MemConfig,
        seed: u64,
    ) -> CacheKey {
        let canonical = format!(
            "store=v{STORE_FORMAT_VERSION}|codec=v{CODEC_VERSION}|model={model}|group={}|\
             arch={arch}|seed={seed}|tile={},{},{},{},{},{},{},{}|\
             mem={},{},{},{},{},{}",
            group.label(),
            tile.t_pu,
            tile.t_m,
            tile.t_n,
            tile.t_ro,
            tile.t_co,
            tile.t_ri,
            tile.t_ci,
            tile.mults_per_pu,
            mem.input_sram_kb,
            mem.output_sram_kb,
            mem.weight_sram_kb,
            mem.sram_word_bits,
            mem.dram_pj_per_byte,
            mem.rf_bytes,
        );
        CacheKey {
            model: model.to_string(),
            group: group.label(),
            arch: arch.to_string(),
            seed,
            fingerprint: fnv1a64(canonical.as_bytes()),
        }
    }

    /// File stem: human-greppable coordinates plus the fingerprint.
    pub fn file_stem(&self) -> String {
        let sanitize = |s: &str| -> String {
            s.chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect()
        };
        format!(
            "{}-{}-{}-s{}-{:016x}",
            sanitize(&self.model),
            sanitize(&self.group),
            sanitize(&self.arch),
            self.seed,
            self.fingerprint
        )
    }
}

/// What a store lookup found.
#[derive(Debug)]
pub enum LoadOutcome {
    /// Valid entry for exactly this key.
    Hit(Box<ModelResult>),
    /// No entry on disk.
    Miss,
    /// An entry exists but is unreadable, truncated, from another
    /// format/codec version, or keyed differently (hash collision).
    /// Callers recompute; the bad file is overwritten on save.
    Corrupt,
}

/// On-disk result store rooted at one directory. Cheap to clone; safe to
/// share across threads (all state is the path — concurrency is handled
/// with atomic write-then-rename).
#[derive(Clone, Debug)]
pub struct ResultStore {
    dir: PathBuf,
}

impl ResultStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<ResultStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating result store at {}", dir.display()))?;
        Ok(ResultStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path_for(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.json", key.file_stem()))
    }

    /// Look up one point. Never errors: every failure mode maps to
    /// [`LoadOutcome::Miss`] or [`LoadOutcome::Corrupt`].
    pub fn load(&self, key: &CacheKey) -> LoadOutcome {
        let path = self.path_for(key);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return LoadOutcome::Miss,
            Err(_) => return LoadOutcome::Corrupt,
        };
        match Self::decode_entry(&text, key) {
            Ok(r) => LoadOutcome::Hit(Box::new(r)),
            Err(_) => LoadOutcome::Corrupt,
        }
    }

    fn decode_entry(text: &str, key: &CacheKey) -> Result<ModelResult> {
        let j = Json::parse(text)?;
        let version = j.field("version")?.as_u32()?;
        if version != STORE_FORMAT_VERSION {
            anyhow::bail!("store format v{version}, expected v{STORE_FORMAT_VERSION}");
        }
        let k = j.field("key")?;
        let matches = k.field("model")?.as_str()? == key.model
            && k.field("group")?.as_str()? == key.group
            && k.field("arch")?.as_str()? == key.arch
            && k.field("seed")?.as_u64()? == key.seed
            && k.field("fingerprint")?.as_u64()? == key.fingerprint;
        if !matches {
            anyhow::bail!("entry keyed for a different point");
        }
        model_result_from_json(j.field("result")?)
    }

    /// Persist one point. Atomic: writes a temp file in the store dir and
    /// renames over the target, so concurrent readers and a mid-write
    /// crash both see either the old entry or the new one, never a torn
    /// file.
    pub fn save(&self, key: &CacheKey, result: &ModelResult) -> Result<()> {
        let envelope = Json::Obj(vec![
            ("version".into(), Json::u64(STORE_FORMAT_VERSION as u64)),
            (
                "key".into(),
                Json::Obj(vec![
                    ("model".into(), Json::str(&key.model)),
                    ("group".into(), Json::str(&key.group)),
                    ("arch".into(), Json::str(&key.arch)),
                    ("seed".into(), Json::u64(key.seed)),
                    ("fingerprint".into(), Json::u64(key.fingerprint)),
                ]),
            ),
            ("result".into(), model_result_to_json(result)),
        ]);
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let path = self.path_for(key);
        let tmp = self.dir.join(format!(
            ".{}.tmp-{}-{}",
            key.file_stem(),
            std::process::id(),
            TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        std::fs::write(&tmp, envelope.to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path).with_context(|| format!("renaming to {}", path.display()))?;
        Ok(())
    }

    /// Number of entries currently on disk (non-temp `.json` files).
    pub fn len(&self) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| {
                        let name = e.file_name();
                        let name = name.to_string_lossy();
                        name.ends_with(".json") && !name.starts_with('.')
                    })
                    .count()
            })
            .unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Arch;
    use crate::models::{tiny_cnn, Workload};
    use crate::sim::simulate_model;

    fn temp_store(tag: &str) -> ResultStore {
        let dir = std::env::temp_dir().join(format!(
            "codr-store-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        ResultStore::open(dir).unwrap()
    }

    fn tiny_point() -> (CacheKey, ModelResult) {
        let model = tiny_cnn();
        let group = SweepGroup::Original;
        let wl = Workload::generate(&model, None, None, 9);
        let acc = Arch::Codr.build();
        let result = simulate_model(acc.as_ref(), &wl, &group.label());
        let key = CacheKey::for_point(
            "tiny",
            &group,
            Arch::Codr.name(),
            &acc.tile_config(),
            &MemConfig::default(),
            9,
        );
        (key, result)
    }

    #[test]
    fn fnv_vectors() {
        // Reference FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn keys_separate_points() {
        let tile = TileConfig::codr();
        let mem = MemConfig::default();
        let k = |m: &str, g: SweepGroup, s: u64| CacheKey::for_point(m, &g, "CoDR", &tile, &mem, s);
        let base = k("tiny", SweepGroup::Original, 42);
        assert_ne!(base.fingerprint, k("tiny", SweepGroup::Original, 43).fingerprint);
        assert_ne!(base.fingerprint, k("tiny", SweepGroup::Density(50), 42).fingerprint);
        assert_ne!(base.fingerprint, k("vgg16", SweepGroup::Original, 42).fingerprint);
        let ucnn = CacheKey::for_point(
            "tiny",
            &SweepGroup::Original,
            "UCNN",
            &TileConfig::ucnn(),
            &mem,
            42,
        );
        assert_ne!(base.fingerprint, ucnn.fingerprint);
        // Same point, same key — content addressing is stable.
        assert_eq!(base, k("tiny", SweepGroup::Original, 42));
    }

    #[test]
    fn save_then_load_hits() {
        let store = temp_store("hit");
        let (key, result) = tiny_point();
        assert!(matches!(store.load(&key), LoadOutcome::Miss));
        store.save(&key, &result).unwrap();
        assert_eq!(store.len(), 1);
        match store.load(&key) {
            LoadOutcome::Hit(r) => assert_eq!(*r, result),
            other => panic!("expected hit, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn garbage_and_truncation_degrade_to_corrupt() {
        let store = temp_store("corrupt");
        let (key, result) = tiny_point();
        store.save(&key, &result).unwrap();
        let path = store.path_for(&key);

        // Truncate to half: unparseable.
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Arbitrary garbage.
        std::fs::write(&path, "not json at all {{{").unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Valid JSON, wrong shape.
        std::fs::write(&path, "{\"version\":1}").unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Future store format.
        let bumped = full.replacen("\"version\":1", "\"version\":99", 1);
        std::fs::write(&path, bumped).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Saving again repairs the entry.
        store.save(&key, &result).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Hit(_)));
        let _ = std::fs::remove_dir_all(store.dir());
    }
}
