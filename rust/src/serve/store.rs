//! Content-addressed, on-disk result store — packed group format (v2).
//!
//! Sweep results are fully deterministic given `(model, sweep group,
//! arch, seed, accelerator config)`, so a [`ModelResult`] computed once
//! can serve every later figure. Format v2 packs **all points of one
//! `(model, group, seed)` pack** into a single JSON file — one envelope,
//! one entry per `(arch, config)` fingerprint — so a warmed grid of P
//! points across G packs costs G files and G syscall chains instead of
//! P (the same access-count discipline the paper applies to SRAM, §V).
//!
//! Integrity is layered so damage degrades by the smallest possible unit:
//!
//! * every entry carries the full cache key, fingerprinted over the
//!   tiling/memory configuration and the codec version — config or schema
//!   changes miss instead of serving stale numbers;
//! * every entry carries a `check` hash ([`result_check`]) of its result
//!   subtree — one bit-rotted entry degrades to [`LoadOutcome::Corrupt`]
//!   (recompute) without discarding its siblings;
//! * only whole-file parse failure corrupts a whole pack, and the next
//!   save rebuilds it.
//!
//! Legacy v1 single-point files are **read-through migrated**: still
//! loaded, folded into the packed file as soon as they are read (or
//! saved over), then deleted — a v1-era store converges to packed v2
//! files under a plain warm run with zero recomputation. Key
//! fingerprints are unchanged from v1 (the canonical key string still
//! says `store=v1`, now meaning *key schema* v1), which is what makes
//! that migration a cache hit rather than a cold start.
//!
//! Loads are corruption-tolerant by design: any read, parse, schema,
//! check, or key-mismatch failure degrades to [`LoadOutcome::Corrupt`]
//! and the caller recomputes. A broken cache can cost time, never
//! correctness.

use crate::arch::{MemConfig, TileConfig};
use crate::models::SweepGroup;
use crate::sim::codec::{model_result_from_json, model_result_to_json, result_check, CODEC_VERSION};
use crate::sim::ModelResult;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// Re-exported from `util::hash` (it moved there so the codec and the
// memo snapshot can share it); existing `store::fnv1a64` callers keep
// working.
pub use crate::util::hash::fnv1a64;

/// Version of the store's file layout + envelope (independent of the
/// result schema, which [`CODEC_VERSION`] tracks). v2 = packed group
/// files; v1 = one file per point (still readable, migrated on read).
pub const STORE_FORMAT_VERSION: u32 = 2;

/// The legacy single-point envelope version.
const V1_FORMAT: u32 = 1;

/// Version of the *canonical key string* the fingerprint hashes. This is
/// deliberately frozen at 1 even though the file layout moved to v2: the
/// layout says where bytes live, not what they mean, and keeping the key
/// schema stable is what lets v1-era files hit (and migrate) instead of
/// cold-starting the store.
const KEY_SCHEMA_VERSION: u32 = 1;

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Stem of the packed file a `(model, group, seed)` triple maps to —
/// the ring's unit of placement. Must stay in lockstep with
/// [`CacheKey::pack_stem`]: the ring routes requests by hashing this
/// string *before* any key exists, and the file the eventual save
/// writes has to land where the routing said it would.
pub(crate) fn pack_stem_for(model: &str, group: &str, seed: u64) -> String {
    format!("{}-{}-s{}", sanitize(model), sanitize(group), seed)
}

/// `CODR_STORE_WRITE_V1=1` — keep the store in the legacy single-point
/// layout: saves write v1 files AND read-through migration is disabled,
/// so a store that must stay readable by a pre-v2 binary is never
/// converted under it.
fn legacy_v1_mode() -> bool {
    crate::analysis::env_registry::var("CODR_STORE_WRITE_V1")
        .is_some_and(|v| v == "1" || v == "true")
}

/// The identity of one sweep point. Two keys are interchangeable iff
/// every figure derived from their results is identical.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheKey {
    pub model: String,
    pub group: String,
    pub arch: String,
    pub seed: u64,
    /// FNV-1a of the canonical key string (includes the fields above plus
    /// the accelerator tile/memory configuration and format versions).
    pub fingerprint: u64,
}

impl CacheKey {
    /// Build the key for one sweep point under the accelerator
    /// configuration that will simulate it.
    pub fn for_point(
        model: &str,
        group: &SweepGroup,
        arch: &str,
        tile: &TileConfig,
        mem: &MemConfig,
        seed: u64,
    ) -> CacheKey {
        let canonical = format!(
            "store=v{KEY_SCHEMA_VERSION}|codec=v{CODEC_VERSION}|model={model}|group={}|\
             arch={arch}|seed={seed}|tile={},{},{},{},{},{},{},{}|\
             mem={},{},{},{},{},{}",
            group.label(),
            tile.t_pu,
            tile.t_m,
            tile.t_n,
            tile.t_ro,
            tile.t_co,
            tile.t_ri,
            tile.t_ci,
            tile.mults_per_pu,
            mem.input_sram_kb,
            mem.output_sram_kb,
            mem.weight_sram_kb,
            mem.sram_word_bits,
            mem.dram_pj_per_byte,
            mem.rf_bytes,
        );
        CacheKey {
            model: model.to_string(),
            group: group.label(),
            arch: arch.to_string(),
            seed,
            fingerprint: fnv1a64(canonical.as_bytes()),
        }
    }

    /// v1 file stem: human-greppable coordinates plus the fingerprint.
    pub fn file_stem(&self) -> String {
        format!(
            "{}-{}-{}-s{}-{:016x}",
            sanitize(&self.model),
            sanitize(&self.group),
            sanitize(&self.arch),
            self.seed,
            self.fingerprint
        )
    }

    /// Packed-file stem: the `(model, group, seed)` pack this key lives
    /// in. Arch and configuration distinguish entries *inside* the pack
    /// (by fingerprint), not files.
    pub fn pack_stem(&self) -> String {
        pack_stem_for(&self.model, &self.group, self.seed)
    }

    /// Do two keys share one packed file?
    pub fn same_pack(&self, other: &CacheKey) -> bool {
        self.model == other.model && self.group == other.group && self.seed == other.seed
    }
}

/// What a store lookup found.
#[derive(Debug)]
pub enum LoadOutcome {
    /// Valid entry for exactly this key.
    Hit(Box<ModelResult>),
    /// No entry on disk.
    Miss,
    /// An entry exists but is unreadable, truncated, from another
    /// format/codec version, check-mismatched, or keyed differently
    /// (hash collision). Callers recompute; the bad entry is overwritten
    /// on save.
    Corrupt,
}

/// On-disk size/occupancy summary — the `status` verb reports this.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Loadable-shaped result entries (packed entries + v1 files).
    pub entries: usize,
    /// Packed v2 group files.
    pub packed_files: usize,
    /// Legacy v1 single-point files not yet migrated.
    pub v1_files: usize,
    /// Total bytes of result data on disk.
    pub bytes: u64,
}

/// What the parse of one packed file yielded.
enum Pack {
    Absent,
    Corrupt,
    Entries(Vec<Json>),
}

/// How long a pack's advisory `.lock` file may sit untouched before a
/// contender treats its holder as dead and takes the lock over. Real
/// holds last milliseconds (one pack rewrite); anything this old
/// belongs to a crashed process.
const LOCK_STALE: Duration = Duration::from_secs(30);

/// Upper bound on waiting for a pack lock. Past it the save proceeds
/// unlocked: the lock is advisory, and the fallback is the pre-lock
/// last-writer-wins behavior — a lost sibling entry recomputes later,
/// never corruption (writes stay atomic either way).
const LOCK_MAX_WAIT: Duration = Duration::from_secs(60);

/// The advisory lock file guarding one pack's read-modify-write. In-
/// process writers already serialize on [`ResultStore::save_lock`]; this
/// extends the same guarantee across *processes* sharing a store
/// directory, so two servers saving into one pack merge their entries
/// instead of the last rename winning.
///
/// Protocol: create `<pack>.json.lock` with `create_new` (exclusive on
/// every platform std supports); on contention, poll until the holder
/// releases, taking over locks older than [`LOCK_STALE`] (takeover is
/// rename-then-delete, so exactly one contender wins the removal).
struct PackLock {
    path: PathBuf,
}

impl PackLock {
    fn acquire(pack_path: &Path) -> Option<PackLock> {
        Self::acquire_with(pack_path, LOCK_STALE, LOCK_MAX_WAIT)
    }

    fn acquire_with(pack_path: &Path, stale: Duration, max_wait: Duration) -> Option<PackLock> {
        let path = lock_path(pack_path);
        let t0 = Instant::now();
        let mut first = true;
        loop {
            if !first && t0.elapsed() >= max_wait {
                return None;
            }
            first = false;
            match std::fs::OpenOptions::new()
                .write(true)
                // analyze: allow(fault_seams): advisory lock file, no data behind it; a crash leaves a stale lock reclaimed by takeover
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    use std::io::Write as _;
                    // Holder identity, for humans debugging a wedged store.
                    let _ = write!(f, "{}", std::process::id());
                    return Some(PackLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let age = std::fs::metadata(&path)
                        .and_then(|md| md.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok());
                    match age {
                        Some(age) if age >= stale => {
                            // Stale takeover: rename first so exactly one
                            // contender owns the removal — two processes
                            // can both see the lock as stale, but only
                            // the successful renamer deletes it. The stat
                            // and the rename are not atomic, though: a
                            // rival takeover may complete (and a fresh
                            // lock appear) in between, so re-verify age
                            // on the grave — which IS exclusively ours —
                            // and put a live lock back if we stole one.
                            let grave = path
                                .with_extension(format!("lock.stale-{}", std::process::id()));
                            // analyze: allow(fault_seams): lock takeover; a crash strands a stale grave file, not data
                            if std::fs::rename(&path, &grave).is_ok() {
                                let still_stale = std::fs::metadata(&grave)
                                    .and_then(|md| md.modified())
                                    .ok()
                                    .and_then(|t| t.elapsed().ok())
                                    .is_some_and(|a| a >= stale);
                                if still_stale {
                                    let _ = std::fs::remove_file(&grave);
                                    continue; // race the other contenders for create_new
                                }
                                // Stole a live lock: restore it (or drop
                                // the grave if yet another lock already
                                // took the path) and keep waiting.
                                // analyze: allow(fault_seams): restores a stolen live lock; worst case is a stale lock
                                if std::fs::rename(&grave, &path).is_err() {
                                    let _ = std::fs::remove_file(&grave);
                                }
                            }
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        // Lock held and fresh — or released between the
                        // open and the stat, or its mtime is unreadable/
                        // in the future (clock skew on a shared
                        // filesystem). Retry, but never busy-spin.
                        _ => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
                // Unwritable store directory: stay advisory — the save
                // itself will surface the real error if it matters.
                Err(_) => return None,
            }
        }
    }
}

impl Drop for PackLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// `<pack>.json` → `<pack>.json.lock`. The suffix keeps lock files out
/// of everything that walks `*.json` (stats, cap eviction, loads).
fn lock_path(pack_path: &Path) -> PathBuf {
    let mut os = pack_path.as_os_str().to_owned();
    os.push(".lock");
    PathBuf::from(os)
}

/// On-disk result store rooted at one directory. Cheap to clone; safe to
/// share across threads (writers serialize on a shared lock so two
/// in-process saves to one pack cannot drop each other's entries, and
/// every write is temp-file + rename so readers and mid-write crashes
/// see either the old pack or the new one, never a torn file) — and
/// safe to share across **processes**: pack read-modify-writes take an
/// advisory `<pack>.json.lock` file (create-exclusive, stale-by-age
/// takeover), so two servers saving into one store merge their entries
/// instead of last-writer-wins.
#[derive(Clone)]
pub struct ResultStore {
    dir: PathBuf,
    /// Soft size cap; oldest packs are evicted after a save pushes the
    /// store past it.
    cap_bytes: Option<u64>,
    save_lock: Arc<Mutex<()>>,
    /// Ring mode only: saves into packs this node does not own get an
    /// `origin` marker so the anti-entropy repair pass can find and push
    /// them. Set once at server startup; shared by every clone (the
    /// scheduler's store is a clone of the one the CLI opened).
    origin: Arc<std::sync::OnceLock<OriginTag>>,
}

impl std::fmt::Debug for ResultStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResultStore")
            .field("dir", &self.dir)
            .field("cap_bytes", &self.cap_bytes)
            .finish()
    }
}

/// Origin marker configuration for ring mode: this node's ring address
/// plus the ownership predicate (does this node own a pack stem?).
pub(crate) struct OriginTag {
    pub(crate) addr: String,
    pub(crate) owned: Box<dyn Fn(&str) -> bool + Send + Sync>,
}

impl ResultStore {
    /// Open (creating if needed) a store rooted at `dir`. Stale `.tmp-*`
    /// files from crashed writers are swept here: a temp file is only
    /// reachable by the process that created it, so anything still lying
    /// around at open belongs to a writer that died mid-save. (A writer
    /// in another *live* process racing this sweep loses its temp file
    /// and fails that one save cleanly — the point recomputes later.)
    pub fn open(dir: impl Into<PathBuf>) -> Result<ResultStore> {
        Self::open_capped(dir, None)
    }

    /// Open with a size cap in bytes (`None` = unbounded). When a save
    /// pushes the store past the cap, whole packs are evicted oldest-
    /// first (by modification time) until the store fits again.
    pub fn open_capped(dir: impl Into<PathBuf>, cap_bytes: Option<u64>) -> Result<ResultStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating result store at {}", dir.display()))?;
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for e in rd.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                // `.lock.stale-*` graves are transient takeover artifacts
                // (rename-then-delete); one left behind means the taking-
                // over process died between the two steps.
                if (name.starts_with('.') && name.contains(".tmp-"))
                    || name.contains(".lock.stale-")
                {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        Ok(ResultStore {
            dir,
            cap_bytes,
            save_lock: Arc::new(Mutex::new(())),
            origin: Arc::new(std::sync::OnceLock::new()),
        })
    }

    /// Install the ring-mode origin marker (at most once; later calls
    /// are ignored). From then on, saves into packs the `owned`
    /// predicate rejects carry `"origin": <addr>` on each entry —
    /// ignored by every reader ([`decode_entry`] matches key/check/
    /// result only), stripped again when repair merges the entry into
    /// its owner.
    pub(crate) fn set_origin(&self, tag: OriginTag) {
        let _ = self.origin.set(tag);
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn cap_bytes(&self) -> Option<u64> {
        self.cap_bytes
    }

    /// Path of the packed (v2) file holding this key's pack.
    pub fn pack_path_for(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.pack.json", key.pack_stem()))
    }

    /// Path a legacy v1 single-point file for this key would have.
    pub fn v1_path_for(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.json", key.file_stem()))
    }

    /// Look up one point. Never errors: every failure mode maps to
    /// [`LoadOutcome::Miss`] or [`LoadOutcome::Corrupt`].
    pub fn load(&self, key: &CacheKey) -> LoadOutcome {
        self.load_group(std::slice::from_ref(key))
            .pop()
            // analyze: allow(panic_policy): load_group returns exactly one outcome per input key
            .expect("one outcome per key")
    }

    /// Look up every key of one pack with a single packed-file read (the
    /// scheduler diffs a grid per `(model, group)`, so this is one
    /// syscall chain for all archs of a point instead of one per arch).
    /// All keys must share a pack (`CacheKey::same_pack`). v1 hits are
    /// folded into the packed file before returning (read-through
    /// migration, best-effort) and their single-point files deleted.
    pub fn load_group(&self, keys: &[CacheKey]) -> Vec<LoadOutcome> {
        if keys.is_empty() {
            return Vec::new();
        }
        debug_assert!(
            keys.iter().all(|k| k.same_pack(&keys[0])),
            "load_group keys must share one (model, group, seed) pack"
        );
        let pack = match std::fs::read_to_string(self.pack_path_for(&keys[0])) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Pack::Absent,
            Err(_) => Pack::Corrupt,
            Ok(text) => match decode_pack(&text) {
                Ok(entries) => Pack::Entries(entries),
                Err(_) => Pack::Corrupt,
            },
        };
        let mut migrate: Vec<(CacheKey, ModelResult)> = Vec::new();
        let outcomes = keys
            .iter()
            .map(|key| match &pack {
                // An unreadable pack loses whatever it held, but intact
                // v1 files still serve (smallest unit of damage). With no
                // v1 fallback the key reports Corrupt — not Miss — so
                // the recompute-and-save path rebuilds the pack.
                Pack::Corrupt => match self.load_v1(key, &mut migrate) {
                    LoadOutcome::Hit(r) => LoadOutcome::Hit(r),
                    _ => LoadOutcome::Corrupt,
                },
                Pack::Absent => self.load_v1(key, &mut migrate),
                Pack::Entries(entries) => {
                    match entries
                        .iter()
                        .find(|e| entry_fingerprint(e) == Some(key.fingerprint))
                    {
                        Some(entry) => match decode_entry(entry, key) {
                            Ok(r) => LoadOutcome::Hit(Box::new(r)),
                            Err(_) => LoadOutcome::Corrupt,
                        },
                        None => self.load_v1(key, &mut migrate),
                    }
                }
            })
            .collect();
        if !migrate.is_empty() && !legacy_v1_mode() {
            let new = migrate
                .iter()
                .map(|(k, r)| (k.fingerprint, entry_to_json(k, r)))
                .collect();
            let cleanup = migrate.iter().map(|(k, _)| self.v1_path_for(k)).collect();
            // Best-effort: a read-only store directory just keeps serving
            // from the v1 files. (A corrupt pack is rebuilt here from the
            // v1 survivors; its undecodable entries were lost either way.)
            let _ = self.upsert_entries(&migrate[0].0, new, cleanup);
        }
        outcomes
    }

    /// Legacy single-point lookup; a hit is queued for migration.
    fn load_v1(&self, key: &CacheKey, migrate: &mut Vec<(CacheKey, ModelResult)>) -> LoadOutcome {
        let text = match std::fs::read_to_string(self.v1_path_for(key)) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return LoadOutcome::Miss,
            Err(_) => return LoadOutcome::Corrupt,
        };
        match decode_v1(&text, key) {
            Ok(r) => {
                migrate.push((key.clone(), r.clone()));
                LoadOutcome::Hit(Box::new(r))
            }
            Err(_) => LoadOutcome::Corrupt,
        }
    }

    /// Persist one point into its pack. Read-modify-write under the save
    /// lock, then an atomic temp-file + rename; sibling entries (even
    /// ones this build cannot decode but whose key is readable) survive
    /// the rewrite untouched. Any v1 file for this key is deleted after
    /// the pack lands.
    ///
    /// Under [`legacy_v1_mode`] (`CODR_STORE_WRITE_V1=1`) the legacy
    /// single-point format is written instead — the rollback escape
    /// hatch for pre-v2 binaries, and the seed for the CI migration
    /// smoke.
    pub fn save(&self, key: &CacheKey, result: &ModelResult) -> Result<()> {
        if legacy_v1_mode() {
            return self.save_v1(key, result);
        }
        let mut entry = entry_to_json(key, result);
        if let Some(tag) = self.origin.get() {
            if !(tag.owned)(&key.pack_stem()) {
                if let Json::Obj(fields) = &mut entry {
                    fields.push(("origin".into(), Json::str(&tag.addr)));
                }
            }
        }
        self.upsert_entries(
            key,
            vec![(key.fingerprint, entry)],
            vec![self.v1_path_for(key)],
        )
    }

    /// Write the legacy v1 single-point format (envelope version 1) —
    /// kept for rollback compatibility and for seeding migration tests.
    pub fn save_v1(&self, key: &CacheKey, result: &ModelResult) -> Result<()> {
        let envelope = Json::Obj(vec![
            ("version".into(), Json::u64(V1_FORMAT as u64)),
            ("key".into(), key_to_json(key)),
            ("result".into(), model_result_to_json(result)),
        ]);
        self.write_atomic(&self.v1_path_for(key), &envelope.to_string())
    }

    /// Upsert `new` `(fingerprint, entry)` pairs into `pack_key`'s packed
    /// file, then delete `v1_cleanup` files and enforce the size cap.
    fn upsert_entries(
        &self,
        pack_key: &CacheKey,
        new: Vec<(u64, Json)>,
        v1_cleanup: Vec<PathBuf>,
    ) -> Result<()> {
        let guard = crate::util::sync::lock(&self.save_lock);
        let path = self.pack_path_for(pack_key);
        // In-process writers serialize on `save_lock`; the advisory file
        // lock extends the read-modify-write to writers in *other
        // processes* sharing this directory, so concurrent saves merge
        // instead of the last rename winning. Failing to take it (60s of
        // contention, unwritable dir) degrades to the old last-writer-
        // wins race — a lost entry recomputes, nothing corrupts.
        let file_lock = PackLock::acquire(&path);
        if file_lock.is_none() {
            eprintln!(
                "warn: proceeding without {} — concurrent pack writers may drop entries",
                lock_path(&path).display()
            );
        }
        // Existing entries keyed by fingerprint. A pack that fails to
        // parse wholesale starts fresh (its data was unreachable anyway);
        // entries whose fingerprint is unreadable are dropped on rewrite
        // (they could never be matched by any key).
        let mut entries: Vec<(u64, Json)> = match std::fs::read_to_string(&path) {
            Ok(text) => decode_pack(&text)
                .map(|es| {
                    es.into_iter()
                        .filter_map(|e| entry_fingerprint(&e).map(|fp| (fp, e)))
                        .collect()
                })
                .unwrap_or_default(),
            Err(_) => Vec::new(),
        };
        for (fp, node) in new {
            match entries.iter_mut().find(|(f, _)| *f == fp) {
                Some(slot) => slot.1 = node,
                None => entries.push((fp, node)),
            }
        }
        let envelope = pack_envelope(pack_key, entries);
        self.write_atomic(&path, &envelope.to_string())?;
        for p in v1_cleanup {
            let _ = std::fs::remove_file(p);
        }
        drop(file_lock);
        drop(guard);
        self.enforce_cap(&path);
        Ok(())
    }

    /// Pack files on disk whose stem the `owned` predicate rejects —
    /// the anti-entropy repair pass's work list in ring mode. Returns
    /// `(stem, path)` pairs, sorted for deterministic repair order.
    pub(crate) fn misplaced_packs(&self, owned: &dyn Fn(&str) -> bool) -> Vec<(String, PathBuf)> {
        let mut out = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return out };
        for e in rd.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name.starts_with('.') {
                continue;
            }
            let Some(stem) = name.strip_suffix(".pack.json") else { continue };
            if !owned(stem) {
                out.push((stem.to_string(), e.path()));
            }
        }
        out.sort();
        out
    }

    /// Read one pack file for a repair push: the pack coordinates plus
    /// every entry with a readable fingerprint (entries without one can
    /// never be matched by any key, so they are not worth shipping).
    pub(crate) fn read_pack_for_repair(
        &self,
        path: &Path,
    ) -> Result<(String, String, u64, Vec<(u64, Json)>)> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)?;
        let version = j.field("version")?.as_u32()?;
        if version != STORE_FORMAT_VERSION {
            anyhow::bail!("store pack format v{version}, expected v{STORE_FORMAT_VERSION}");
        }
        let pack = j.field("pack")?;
        let model = pack.field("model")?.as_str()?.to_string();
        let group = pack.field("group")?.as_str()?.to_string();
        let seed = pack.field("seed")?.as_u64()?;
        let entries = j
            .take("entries")?
            .into_arr()?
            .into_iter()
            .filter_map(|e| entry_fingerprint(&e).map(|fp| (fp, e)))
            .collect();
        Ok((model, group, seed, entries))
    }

    /// Owner-side repair merge: upsert pushed entries into this node's
    /// pack, stripping their `origin` markers (they are home now). Runs
    /// under the same save-lock + advisory pack-lock discipline as a
    /// normal save, so a repair merges with — never clobbers — entries
    /// this node computed itself. Returns how many entries were merged.
    pub(crate) fn merge_repair(
        &self,
        model: &str,
        group: &str,
        seed: u64,
        entries: Vec<Json>,
    ) -> Result<usize> {
        let key = CacheKey {
            model: model.to_string(),
            group: group.to_string(),
            arch: String::new(),
            seed,
            fingerprint: 0,
        };
        let new: Vec<(u64, Json)> = entries
            .into_iter()
            .filter_map(|mut e| {
                let fp = entry_fingerprint(&e)?;
                if let Json::Obj(fields) = &mut e {
                    fields.retain(|(k, _)| k != "origin");
                }
                Some((fp, e))
            })
            .collect();
        let merged = new.len();
        if merged == 0 {
            return Ok(0);
        }
        self.upsert_entries(&key, new, Vec::new())?;
        Ok(merged)
    }

    /// Forwarder-side trim after the owner acked a repair push: drop the
    /// acked fingerprints — plus entries whose fingerprint is unreadable
    /// (no key can ever match them) — from the local misplaced pack,
    /// removing the file outright when nothing is left. Entries saved
    /// locally while the push was in flight keep their fingerprints and
    /// survive for the next repair pass: trimming is by identity, not
    /// "whatever the file holds now".
    pub(crate) fn remove_pack_entries(
        &self,
        model: &str,
        group: &str,
        seed: u64,
        acked: &[u64],
    ) -> Result<()> {
        let key = CacheKey {
            model: model.to_string(),
            group: group.to_string(),
            arch: String::new(),
            seed,
            fingerprint: 0,
        };
        let guard = crate::util::sync::lock(&self.save_lock);
        let path = self.pack_path_for(&key);
        let file_lock = PackLock::acquire(&path);
        if file_lock.is_none() {
            eprintln!(
                "warn: proceeding without {} — a concurrent writer may race this trim",
                lock_path(&path).display()
            );
        }
        let remaining: Vec<(u64, Json)> = match std::fs::read_to_string(&path) {
            Ok(text) => decode_pack(&text)
                .map(|es| {
                    es.into_iter()
                        .filter_map(|e| entry_fingerprint(&e).map(|fp| (fp, e)))
                        .filter(|(fp, _)| !acked.contains(fp))
                        .collect()
                })
                .unwrap_or_default(),
            Err(_) => Vec::new(),
        };
        if remaining.is_empty() {
            let _ = std::fs::remove_file(&path);
            return Ok(());
        }
        let envelope = pack_envelope(&key, remaining);
        self.write_atomic(&path, &envelope.to_string())?;
        drop(file_lock);
        drop(guard);
        Ok(())
    }

    /// Atomic write: temp file in the store dir, rename over the target.
    /// The temp file is removed on *every* failure path — a failed save
    /// must leave no `.tmp-*` garbage behind.
    fn write_atomic(&self, path: &Path, text: &str) -> Result<()> {
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let stem = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        let tmp = self.dir.join(format!(
            ".{stem}.tmp-{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        // Injection seam: a torn pack write (crash between write and
        // rename landing only a prefix). The per-entry check hashes turn
        // the damage into a recompute on the next read, never bad data.
        // The copy is taken only when faults are armed.
        let mangled;
        let bytes: &[u8] = if crate::faults::armed() {
            let mut b = text.as_bytes().to_vec();
            crate::faults::torn_point("store.pack_write.torn", &mut b);
            mangled = b;
            &mangled
        } else {
            text.as_bytes()
        };
        if let Err(e) = std::fs::write(&tmp, bytes) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("writing {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("renaming to {}", path.display()));
        }
        Ok(())
    }

    /// Evict oldest packs until the store fits `cap_bytes` again. The
    /// just-written pack is never the victim (a cap smaller than one
    /// pack would otherwise evict every save immediately).
    fn enforce_cap(&self, just_written: &Path) {
        let Some(cap) = self.cap_bytes else { return };
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return };
        let mut files: Vec<(std::time::SystemTime, u64, PathBuf)> = Vec::new();
        let mut total: u64 = 0;
        for e in rd.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name.starts_with('.') || !name.ends_with(".json") {
                continue;
            }
            let Ok(md) = e.metadata() else { continue };
            if !md.is_file() {
                continue;
            }
            total += md.len();
            let mtime = md.modified().unwrap_or(std::time::UNIX_EPOCH);
            files.push((mtime, md.len(), e.path()));
        }
        if total <= cap {
            return;
        }
        // Oldest first; equal mtimes (coarse filesystem clocks stamp a
        // burst of saves identically) tie-break on the path so the
        // eviction order is stable across runs and machines.
        files.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.2.cmp(&b.2)));
        for (_, size, path) in files {
            if total <= cap {
                break;
            }
            if path == just_written {
                continue;
            }
            if std::fs::remove_file(&path).is_ok() {
                total = total.saturating_sub(size);
            }
        }
    }

    /// On-disk occupancy. One directory walk; packed files are parsed to
    /// count their entries (status-path cost, not hot-path cost).
    pub fn stats(&self) -> StoreStats {
        let mut s = StoreStats::default();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return s;
        };
        for e in rd.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name.starts_with('.') || !name.ends_with(".json") {
                continue;
            }
            let Ok(md) = e.metadata() else { continue };
            if !md.is_file() {
                continue;
            }
            s.bytes += md.len();
            if name.ends_with(".pack.json") {
                s.packed_files += 1;
                if let Ok(text) = std::fs::read_to_string(e.path()) {
                    if let Ok(entries) = decode_pack(&text) {
                        s.entries += entries.len();
                    }
                }
            } else {
                s.v1_files += 1;
                s.entries += 1;
            }
        }
        s
    }

    /// Number of result entries currently on disk (packed + v1).
    pub fn len(&self) -> usize {
        self.stats().entries
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn key_to_json(key: &CacheKey) -> Json {
    Json::Obj(vec![
        ("model".into(), Json::str(&key.model)),
        ("group".into(), Json::str(&key.group)),
        ("arch".into(), Json::str(&key.arch)),
        ("seed".into(), Json::u64(key.seed)),
        ("fingerprint".into(), Json::u64(key.fingerprint)),
    ])
}

fn entry_to_json(key: &CacheKey, result: &ModelResult) -> Json {
    let result_node = model_result_to_json(result);
    Json::Obj(vec![
        ("key".into(), key_to_json(key)),
        ("check".into(), Json::u64(result_check(&result_node))),
        ("result".into(), result_node),
    ])
}

/// The on-disk pack envelope for a full set of `(fingerprint, entry)`
/// pairs. Shared by the save upsert and the repair trim so both rewrite
/// paths stay byte-compatible.
fn pack_envelope(pack_key: &CacheKey, entries: Vec<(u64, Json)>) -> Json {
    Json::Obj(vec![
        ("version".into(), Json::u64(STORE_FORMAT_VERSION as u64)),
        (
            "pack".into(),
            Json::Obj(vec![
                ("model".into(), Json::str(&pack_key.model)),
                ("group".into(), Json::str(&pack_key.group)),
                ("seed".into(), Json::u64(pack_key.seed)),
            ]),
        ),
        (
            "entries".into(),
            Json::Arr(entries.into_iter().map(|(_, e)| e).collect()),
        ),
    ])
}

/// Parse a packed file into its entry nodes (envelope-level checks only;
/// entries are decoded — and fail — individually).
fn decode_pack(text: &str) -> Result<Vec<Json>> {
    let j = Json::parse(text)?;
    let version = j.field("version")?.as_u32()?;
    if version != STORE_FORMAT_VERSION {
        anyhow::bail!("store pack format v{version}, expected v{STORE_FORMAT_VERSION}");
    }
    j.take("entries")?.into_arr()
}

/// Cheap per-entry addressing: the fingerprint, if readable.
fn entry_fingerprint(entry: &Json) -> Option<u64> {
    entry.get("key")?.get("fingerprint")?.as_u64().ok()
}

fn key_matches(k: &Json, key: &CacheKey) -> Result<bool> {
    Ok(k.field("model")?.as_str()? == key.model
        && k.field("group")?.as_str()? == key.group
        && k.field("arch")?.as_str()? == key.arch
        && k.field("seed")?.as_u64()? == key.seed
        && k.field("fingerprint")?.as_u64()? == key.fingerprint)
}

/// Decode one packed entry for `key`: full key match, check-hash verify,
/// then the versioned result codec.
fn decode_entry(entry: &Json, key: &CacheKey) -> Result<ModelResult> {
    if !key_matches(entry.field("key")?, key)? {
        anyhow::bail!("entry keyed for a different point");
    }
    let result_node = entry.field("result")?;
    let check = entry.field("check")?.as_u64()?;
    if check != result_check(result_node) {
        anyhow::bail!("entry check hash mismatch (damaged result)");
    }
    model_result_from_json(result_node)
}

/// Decode a legacy v1 single-point file.
fn decode_v1(text: &str, key: &CacheKey) -> Result<ModelResult> {
    let j = Json::parse(text)?;
    let version = j.field("version")?.as_u32()?;
    if version != V1_FORMAT {
        anyhow::bail!("store format v{version}, expected v{V1_FORMAT}");
    }
    if !key_matches(j.field("key")?, key)? {
        anyhow::bail!("entry keyed for a different point");
    }
    model_result_from_json(j.field("result")?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Arch;
    use crate::models::{tiny_cnn, Workload};
    use crate::sim::simulate_model;

    fn temp_store(tag: &str) -> ResultStore {
        let dir = std::env::temp_dir().join(format!(
            "codr-store-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        ResultStore::open(dir).unwrap()
    }

    fn point_for(arch: Arch, seed: u64) -> (CacheKey, ModelResult) {
        let model = tiny_cnn();
        let group = SweepGroup::Original;
        let wl = Workload::generate(&model, None, None, seed);
        let acc = arch.build();
        let result = simulate_model(acc.as_ref(), &wl, &group.label());
        let key = CacheKey::for_point(
            "tiny",
            &group,
            arch.name(),
            &acc.tile_config(),
            &MemConfig::default(),
            seed,
        );
        (key, result)
    }

    fn tiny_point() -> (CacheKey, ModelResult) {
        point_for(Arch::Codr, 9)
    }

    fn visible_files(store: &ResultStore) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(store.dir())
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        names
    }

    fn tmp_files(store: &ResultStore) -> Vec<String> {
        visible_files(store)
            .into_iter()
            .filter(|n| n.contains(".tmp-"))
            .collect()
    }

    #[test]
    fn fnv_vectors() {
        // Reference FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn keys_separate_points() {
        let tile = TileConfig::codr();
        let mem = MemConfig::default();
        let k = |m: &str, g: SweepGroup, s: u64| CacheKey::for_point(m, &g, "CoDR", &tile, &mem, s);
        let base = k("tiny", SweepGroup::Original, 42);
        assert_ne!(base.fingerprint, k("tiny", SweepGroup::Original, 43).fingerprint);
        assert_ne!(base.fingerprint, k("tiny", SweepGroup::Density(50), 42).fingerprint);
        assert_ne!(base.fingerprint, k("vgg16", SweepGroup::Original, 42).fingerprint);
        let ucnn = CacheKey::for_point(
            "tiny",
            &SweepGroup::Original,
            "UCNN",
            &TileConfig::ucnn(),
            &mem,
            42,
        );
        assert_ne!(base.fingerprint, ucnn.fingerprint);
        // Same point, same key — content addressing is stable.
        assert_eq!(base, k("tiny", SweepGroup::Original, 42));
        // Same pack for every arch of a point; other groups/seeds differ.
        assert!(base.same_pack(&ucnn));
        assert!(!base.same_pack(&k("tiny", SweepGroup::Density(50), 42)));
        assert!(!base.same_pack(&k("tiny", SweepGroup::Original, 43)));
    }

    #[test]
    fn save_then_load_hits_from_one_packed_file() {
        let store = temp_store("hit");
        let (key, result) = tiny_point();
        assert!(matches!(store.load(&key), LoadOutcome::Miss));
        store.save(&key, &result).unwrap();
        assert_eq!(store.len(), 1);
        match store.load(&key) {
            LoadOutcome::Hit(r) => assert_eq!(*r, result),
            other => panic!("expected hit, got {other:?}"),
        }
        // Exactly one file on disk, and it is the pack (no v1 file).
        let files = visible_files(&store);
        assert_eq!(files.len(), 1, "{files:?}");
        assert!(files[0].ends_with(".pack.json"), "{files:?}");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn all_archs_of_a_point_share_one_pack() {
        let store = temp_store("pack");
        for arch in Arch::all() {
            let (key, result) = point_for(arch, 9);
            store.save(&key, &result).unwrap();
        }
        let stats = store.stats();
        assert_eq!(stats.packed_files, 1, "G files for G packs, not P points");
        assert_eq!(stats.v1_files, 0);
        assert_eq!(stats.entries, 3);
        assert!(stats.bytes > 0);
        // Every arch loads back from the shared pack.
        for arch in Arch::all() {
            let (key, result) = point_for(arch, 9);
            match store.load(&key) {
                LoadOutcome::Hit(r) => assert_eq!(*r, result),
                other => panic!("expected hit for {}, got {other:?}", arch.name()),
            }
        }
        // A different seed opens a second pack.
        let (key2, result2) = point_for(Arch::Codr, 10);
        store.save(&key2, &result2).unwrap();
        assert_eq!(store.stats().packed_files, 2);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn load_group_reads_every_arch_in_one_pass() {
        let store = temp_store("group");
        let mut keys = Vec::new();
        for arch in Arch::all() {
            let (key, result) = point_for(arch, 9);
            store.save(&key, &result).unwrap();
            keys.push(key);
        }
        let outcomes = store.load_group(&keys);
        assert_eq!(outcomes.len(), 3);
        assert!(outcomes.iter().all(|o| matches!(o, LoadOutcome::Hit(_))));
        // Mixed pack: drop one entry's file-level sibling → still one
        // hit per remaining key plus a miss for a key of the same pack
        // that was never saved.
        let ghost = CacheKey {
            fingerprint: keys[0].fingerprint ^ 1,
            ..keys[0].clone()
        };
        let outcomes = store.load_group(&[keys[1].clone(), ghost]);
        assert!(matches!(outcomes[0], LoadOutcome::Hit(_)));
        assert!(matches!(outcomes[1], LoadOutcome::Miss));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn corrupt_entry_degrades_alone_and_siblings_survive() {
        let store = temp_store("entrycorrupt");
        let (k_codr, r_codr) = point_for(Arch::Codr, 9);
        let (k_ucnn, r_ucnn) = point_for(Arch::Ucnn, 9);
        store.save(&k_codr, &r_codr).unwrap();
        store.save(&k_ucnn, &r_ucnn).unwrap();
        let path = store.pack_path_for(&k_codr);

        // Surgical damage: flip the first entry's check hash. Whole-file
        // JSON stays valid, so only that entry degrades.
        let text = std::fs::read_to_string(&path).unwrap();
        let check_pos = text.find("\"check\":").unwrap();
        let digit = check_pos + "\"check\":".len();
        let mut bytes = text.clone().into_bytes();
        bytes[digit] = if bytes[digit] == b'9' { b'1' } else { b'9' };
        std::fs::write(&path, &bytes).unwrap();

        let (first, second) = if text[..check_pos].contains(&k_codr.fingerprint.to_string()) {
            ((&k_codr, &r_codr), (&k_ucnn, &r_ucnn))
        } else {
            ((&k_ucnn, &r_ucnn), (&k_codr, &r_codr))
        };
        assert!(matches!(store.load(first.0), LoadOutcome::Corrupt));
        match store.load(second.0) {
            LoadOutcome::Hit(r) => assert_eq!(*r, *second.1),
            other => panic!("sibling must survive, got {other:?}"),
        }
        // Re-saving the damaged entry repairs it without touching the
        // sibling.
        store.save(first.0, first.1).unwrap();
        assert!(matches!(store.load(first.0), LoadOutcome::Hit(_)));
        assert!(matches!(store.load(second.0), LoadOutcome::Hit(_)));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn garbage_and_truncation_degrade_to_corrupt() {
        let store = temp_store("corrupt");
        let (key, result) = tiny_point();
        store.save(&key, &result).unwrap();
        let path = store.pack_path_for(&key);

        // Truncate to half: unparseable.
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Arbitrary garbage.
        std::fs::write(&path, "not json at all {{{").unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Valid JSON, wrong shape.
        std::fs::write(&path, "{\"version\":2}").unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Future store format.
        let bumped = full.replacen("\"version\":2", "\"version\":99", 1);
        std::fs::write(&path, bumped).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Corrupt));

        // Saving again repairs the entry.
        store.save(&key, &result).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Hit(_)));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn v1_files_load_and_migrate_on_read() {
        let store = temp_store("migrate");
        let mut points = Vec::new();
        for arch in Arch::all() {
            let (key, result) = point_for(arch, 9);
            store.save_v1(&key, &result).unwrap();
            points.push((key, result));
        }
        let stats = store.stats();
        assert_eq!((stats.v1_files, stats.packed_files), (3, 0));

        // First read hits from the v1 file and folds the pack.
        match store.load(&points[0].0) {
            LoadOutcome::Hit(r) => assert_eq!(*r, points[0].1),
            other => panic!("expected v1 hit, got {other:?}"),
        }
        let stats = store.stats();
        assert_eq!(stats.packed_files, 1, "migration must create the pack");
        assert_eq!(stats.v1_files, 2, "only the read entry migrated so far");
        assert!(!store.v1_path_for(&points[0].0).exists());

        // A grouped read migrates the rest in one write; the directory
        // converges to packed files only.
        let keys: Vec<CacheKey> = points.iter().map(|(k, _)| k.clone()).collect();
        let outcomes = store.load_group(&keys);
        assert!(outcomes.iter().all(|o| matches!(o, LoadOutcome::Hit(_))));
        let stats = store.stats();
        assert_eq!((stats.v1_files, stats.packed_files, stats.entries), (0, 1, 3));

        // And the migrated entries still decode from the pack.
        for (key, result) in &points {
            match store.load(key) {
                LoadOutcome::Hit(r) => assert_eq!(*r, *result),
                other => panic!("expected packed hit, got {other:?}"),
            }
        }
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn corrupt_pack_still_serves_intact_v1_files() {
        let store = temp_store("packdownv1");
        let (k_codr, r_codr) = point_for(Arch::Codr, 9);
        let (k_ucnn, r_ucnn) = point_for(Arch::Ucnn, 9);
        store.save(&k_codr, &r_codr).unwrap();
        store.save_v1(&k_ucnn, &r_ucnn).unwrap();
        std::fs::write(store.pack_path_for(&k_codr), "}{ definitely not json").unwrap();

        // The packed entry is lost (Corrupt → recompute), but the intact
        // legacy file keeps serving — and its read rebuilds the pack.
        assert!(matches!(store.load(&k_codr), LoadOutcome::Corrupt));
        match store.load(&k_ucnn) {
            LoadOutcome::Hit(r) => assert_eq!(*r, r_ucnn),
            other => panic!("v1 fallback must survive a corrupt pack, got {other:?}"),
        }
        let stats = store.stats();
        assert_eq!((stats.packed_files, stats.v1_files), (1, 0));
        assert!(matches!(store.load(&k_ucnn), LoadOutcome::Hit(_)));
        // The corrupt entry is simply gone from the rebuilt pack: a miss
        // now, never stale data.
        assert!(matches!(store.load(&k_codr), LoadOutcome::Miss));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn legacy_env_var_writes_v1_format() {
        let store = temp_store("legacyenv");
        let (key, result) = tiny_point();
        // Avoid mutating process env (tests run in parallel): the env
        // path is equivalent to save_v1, which the migration tests and
        // the CI smoke drive; here we just pin the v1 envelope shape.
        store.save_v1(&key, &result).unwrap();
        let text = std::fs::read_to_string(store.v1_path_for(&key)).unwrap();
        assert!(text.starts_with("{\"version\":1,"));
        assert!(matches!(store.load(&key), LoadOutcome::Hit(_)));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn failed_save_leaves_no_temp_files() {
        let store = temp_store("tmpleak");
        let (key, result) = tiny_point();
        // Block the rename target with a non-empty directory.
        let pack = store.pack_path_for(&key);
        std::fs::create_dir_all(pack.join("blocker")).unwrap();
        assert!(store.save(&key, &result).is_err());
        assert!(tmp_files(&store).is_empty(), "{:?}", tmp_files(&store));
        // Same discipline on the v1 writer.
        let v1 = store.v1_path_for(&key);
        std::fs::create_dir_all(v1.join("blocker")).unwrap();
        assert!(store.save_v1(&key, &result).is_err());
        assert!(tmp_files(&store).is_empty(), "{:?}", tmp_files(&store));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn pack_lock_excludes_holders_and_releases_on_drop() {
        let store = temp_store("lock");
        let (key, result) = tiny_point();
        let pack = store.pack_path_for(&key);
        store.save(&key, &result).unwrap();
        // No lock file survives a completed save.
        assert!(!lock_path(&pack).exists());

        let held = PackLock::acquire_with(&pack, LOCK_STALE, Duration::from_millis(200))
            .expect("uncontended acquire");
        assert!(lock_path(&pack).exists());
        // A second contender times out while the lock is held (the
        // holder is fresh, so no stale takeover).
        assert!(
            PackLock::acquire_with(&pack, LOCK_STALE, Duration::from_millis(60)).is_none(),
            "held lock must exclude a second writer"
        );
        drop(held);
        assert!(!lock_path(&pack).exists(), "drop must release the lock");
        // Released: the next acquire is immediate.
        let again = PackLock::acquire_with(&pack, LOCK_STALE, Duration::from_millis(200));
        assert!(again.is_some());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn pack_lock_takes_over_stale_holders() {
        let store = temp_store("staleLock");
        let (key, result) = tiny_point();
        let pack = store.pack_path_for(&key);
        // A crashed writer's leftover: a lock file nobody will release.
        std::fs::write(lock_path(&pack), "99999").unwrap();
        std::thread::sleep(Duration::from_millis(80));
        // With a 20ms staleness bound the leftover is taken over at once;
        // the save then proceeds under the fresh lock.
        let lock = PackLock::acquire_with(&pack, Duration::from_millis(20), Duration::from_secs(5))
            .expect("stale lock must be taken over");
        drop(lock);
        store.save(&key, &result).unwrap();
        assert!(matches!(store.load(&key), LoadOutcome::Hit(_)));
        assert!(!lock_path(&pack).exists());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn open_sweeps_stale_temp_files() {
        let store = temp_store("tmpsweep");
        let stale = store.dir().join(".orphan.pack.json.tmp-12345-0");
        std::fs::write(&stale, "half-written").unwrap();
        // A takeover grave left by a process that died between its
        // rename and delete is reaped too.
        let grave = store.dir().join("orphan.pack.json.lock.stale-12345");
        std::fs::write(&grave, "9").unwrap();
        // Non-temp hidden files and real data survive the sweep.
        let hidden = store.dir().join(".keepme");
        std::fs::write(&hidden, "x").unwrap();
        let (key, result) = tiny_point();
        store.save(&key, &result).unwrap();
        let reopened = ResultStore::open(store.dir()).unwrap();
        assert!(!stale.exists(), "stale temp file must be reaped at open");
        assert!(!grave.exists(), "takeover grave must be reaped at open");
        assert!(hidden.exists());
        assert!(matches!(reopened.load(&key), LoadOutcome::Hit(_)));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn size_cap_evicts_oldest_packs_first() {
        let dir = std::env::temp_dir().join(format!("codr-store-test-cap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Measure one pack, then cap the store at roughly two of them.
        let probe = ResultStore::open(&dir).unwrap();
        let (k0, r0) = point_for(Arch::Codr, 1);
        probe.save(&k0, &r0).unwrap();
        let pack_bytes = probe.stats().bytes;
        let _ = std::fs::remove_dir_all(&dir);

        let store = ResultStore::open_capped(&dir, Some(pack_bytes * 2 + pack_bytes / 2)).unwrap();
        let mut keys = Vec::new();
        for seed in 1..=4u64 {
            let (k, r) = point_for(Arch::Codr, seed);
            store.save(&k, &r).unwrap();
            keys.push(k);
            // Distinct mtimes so "oldest" is well-defined even on coarse
            // filesystem timestamps.
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let stats = store.stats();
        assert!(stats.bytes <= pack_bytes * 2 + pack_bytes / 2, "{stats:?}");
        assert!(stats.packed_files < 4, "{stats:?}");
        // The newest pack always survives; the oldest is the first out.
        assert!(matches!(store.load(&keys[3]), LoadOutcome::Hit(_)));
        assert!(matches!(store.load(&keys[0]), LoadOutcome::Miss));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
