//! Static consistent-hash ring over pack keys for multi-host serving.
//!
//! Placement is by **pack** — the `(model, group, seed)` unit the v2
//! store already writes as one `*.pack.json` file — so the ring moves
//! whole files, never entries. Every node is started with the identical
//! `--ring host1:port,host2:port,...` list (or `CODR_RING`); each hashes
//! pack stems onto the same [`VNODES_PER_NODE`]-virtual-node circle
//! (stable `fnv1a64` for vnode positions, the dual-stream [`Fp128`]
//! fingerprint for keys), so any node can answer "who owns this pack"
//! without talking to anyone.
//!
//! Any node accepts any request. Work whose packs it does not own is
//! forwarded to the owner through [`super::peer`]; when the owner is
//! Down the node computes locally instead (degraded mode — entries are
//! tagged with an `origin` marker by the store), and the anti-entropy
//! [`RingState::maintain`] pass — probes first, then repair — pushes
//! misplaced packs to their owner once it is Up again. Repair merges
//! through the owner's normal pack upsert path (save lock + advisory
//! pack lock), so a repair never clobbers entries the owner computed
//! itself, and the local copy is only trimmed after the owner acks.

use super::peer::{self, Health, Peer};
use super::store::ResultStore;
use crate::util::hash::{fnv1a64, Fp128};
use crate::util::json::Json;
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::Duration;

/// Virtual nodes per ring node: enough that a two-node ring splits pack
/// stems roughly evenly instead of by one arbitrary hash boundary.
pub(crate) const VNODES_PER_NODE: usize = 64;

/// The immutable ring geometry: the configured node list, which entry
/// is this process, and the sorted virtual-node circle.
pub(crate) struct Ring {
    nodes: Vec<String>,
    self_idx: usize,
    /// `(position, node index)`, sorted — ties broken by node index so
    /// every node computes the identical circle from the same list.
    vnodes: Vec<(u64, usize)>,
}

impl Ring {
    /// Parse a `host1:port,host2:port,...` spec. `self_addrs` are the
    /// strings this process answers to (the `--addr` argument and the
    /// bound socket address); exactly one ring entry must match one of
    /// them — a node that is not in its own ring config would route
    /// every pack away and own nothing.
    pub(crate) fn parse(spec: &str, self_addrs: &[String]) -> Result<Ring> {
        let nodes: Vec<String> = spec
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if nodes.len() < 2 {
            anyhow::bail!(
                "--ring needs at least two host:port entries, got {} in `{spec}`",
                nodes.len()
            );
        }
        for n in &nodes {
            if !n.contains(':') {
                anyhow::bail!("ring entry `{n}` is not host:port");
            }
        }
        for (i, n) in nodes.iter().enumerate() {
            if nodes[..i].contains(n) {
                anyhow::bail!("ring entry `{n}` appears twice");
            }
        }
        let self_idx = nodes
            .iter()
            .position(|n| self_addrs.contains(n))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "--ring must include this node's own address (listening on {}, ring: {spec})",
                    self_addrs.join(" / ")
                )
            })?;
        let mut vnodes: Vec<(u64, usize)> = Vec::with_capacity(nodes.len() * VNODES_PER_NODE);
        for (idx, node) in nodes.iter().enumerate() {
            for replica in 0..VNODES_PER_NODE {
                vnodes.push((fnv1a64(format!("{node}#{replica}").as_bytes()), idx));
            }
        }
        vnodes.sort_unstable();
        Ok(Ring {
            nodes,
            self_idx,
            vnodes,
        })
    }

    /// Hash a pack stem onto the circle. Both independent halves of the
    /// store fingerprint are folded in, so stems that collide in one
    /// 64-bit stream still spread.
    fn key_point(stem: &str) -> u64 {
        let bytes: Vec<i8> = stem.bytes().map(|b| b as i8).collect();
        let fp = Fp128::of_i8(&bytes);
        fp.lo ^ fp.hi
    }

    /// Index of the node owning `stem`: the first virtual node at or
    /// after the key's position, wrapping at the top of the circle.
    pub(crate) fn owner_of(&self, stem: &str) -> usize {
        let point = Ring::key_point(stem);
        let at = self
            .vnodes
            .partition_point(|(pos, _)| *pos < point)
            % self.vnodes.len();
        self.vnodes[at].1
    }

    pub(crate) fn self_idx(&self) -> usize {
        self.self_idx
    }

    pub(crate) fn nodes(&self) -> &[String] {
        &self.nodes
    }
}

/// The live ring: geometry plus per-peer health/gauges, the peer
/// timeout, and the maintenance serialization lock. One per server,
/// installed into `serve::Shared` at startup when `--ring` is given.
pub(crate) struct RingState {
    ring: Ring,
    /// One slot per ring node (the self slot exists but is never
    /// probed or forwarded to), so peer and node indexes line up.
    peers: Vec<Peer>,
    pub(crate) timeout: Duration,
    /// Serializes maintenance passes (probe sweep + repair push): ticks
    /// arrive on a fixed cadence but a pass may outlive one interval
    /// when probes time out, and two concurrent repair pushes of the
    /// same pack would double-send entries. Outermost in the lock
    /// hierarchy (tier 0): a pass acquires the store save lock and pack
    /// locks underneath it, never the reverse.
    maintenance: Mutex<()>,
}

impl RingState {
    pub(crate) fn new(ring: Ring) -> RingState {
        let peers = ring.nodes.iter().map(Peer::new).collect();
        RingState {
            ring,
            peers,
            timeout: peer::peer_timeout(),
            maintenance: Mutex::new(()),
        }
    }

    pub(crate) fn self_addr(&self) -> &str {
        &self.ring.nodes[self.ring.self_idx]
    }

    pub(crate) fn self_idx(&self) -> usize {
        self.ring.self_idx
    }

    pub(crate) fn nodes(&self) -> &[String] {
        self.ring.nodes()
    }

    pub(crate) fn node(&self, idx: usize) -> &str {
        &self.ring.nodes[idx]
    }

    pub(crate) fn owner_of(&self, stem: &str) -> usize {
        self.ring.owner_of(stem)
    }

    /// Does this node own `stem`? The store's origin-tagging predicate.
    pub(crate) fn owns(&self, stem: &str) -> bool {
        self.ring.owner_of(stem) == self.ring.self_idx
    }

    pub(crate) fn peer(&self, idx: usize) -> &Peer {
        &self.peers[idx]
    }

    /// The `ring` gauge object for `status` and the `ring` verb:
    /// aggregate forward/repair counts plus one entry per remote peer.
    pub(crate) fn gauges(&self) -> Json {
        let mut forwards = 0u64;
        let mut repairs = 0u64;
        let mut peers = Vec::new();
        for (i, p) in self.peers.iter().enumerate() {
            if i == self.ring.self_idx {
                continue;
            }
            forwards += p.forwards.load(Ordering::SeqCst);
            repairs += p.repairs.load(Ordering::SeqCst);
            peers.push(p.to_json());
        }
        Json::Obj(vec![
            ("self".into(), Json::str(self.self_addr())),
            (
                "nodes".into(),
                Json::Arr(self.ring.nodes.iter().map(Json::str).collect()),
            ),
            ("forwards".into(), Json::u64(forwards)),
            ("repairs".into(), Json::u64(repairs)),
            ("peers".into(), Json::Arr(peers)),
        ])
    }

    /// One maintenance pass: probe every remote peer, then push any
    /// misplaced packs to owners that are Up. Scheduled by the reactor
    /// on a fixed tick but executed on the pool — the reactor never
    /// blocks on a peer. A tick that arrives while a pass is still
    /// running is skipped (the lock is try-acquired), so slow probes
    /// cannot pile passes up.
    pub(crate) fn maintain(&self, store: &ResultStore) {
        let _guard = match self.maintenance.try_lock() {
            Ok(g) => g,
            // A previous pass panicked mid-probe; the lock protects
            // nothing across passes, so take it over.
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => return,
        };
        for (i, p) in self.peers.iter().enumerate() {
            if i == self.ring.self_idx {
                continue;
            }
            peer::probe(p, self.timeout);
        }
        self.repair(store);
    }

    /// Anti-entropy: push every pack this node holds but does not own to
    /// its owner, then trim the pushed entries locally. The local copy
    /// is only trimmed after the owner acks the merge — a failed push
    /// changes nothing and the next tick retries — and the trim removes
    /// exactly the acked fingerprints, so entries written locally while
    /// the push was in flight survive for the following pass.
    fn repair(&self, store: &ResultStore) {
        for (stem, path) in store.misplaced_packs(&|s| self.owns(s)) {
            let owner = self.ring.owner_of(&stem);
            if owner == self.ring.self_idx {
                continue;
            }
            let p = &self.peers[owner];
            if p.health() != Health::Up {
                continue;
            }
            let (model, group, seed, entries) = match store.read_pack_for_repair(&path) {
                Ok(pack) => pack,
                Err(e) => {
                    eprintln!("warn: repair cannot read {}: {e:#}", path.display());
                    continue;
                }
            };
            if entries.is_empty() {
                // Nothing addressable to merge; trim so the pass stops
                // re-reading a husk every tick.
                let _ = store.remove_pack_entries(&model, &group, seed, &[]);
                continue;
            }
            let fps: Vec<u64> = entries.iter().map(|(fp, _)| *fp).collect();
            let msg = Json::Obj(vec![
                ("verb".into(), Json::str("repair")),
                (
                    "pack".into(),
                    Json::Obj(vec![
                        ("model".into(), Json::str(&model)),
                        ("group".into(), Json::str(&group)),
                        ("seed".into(), Json::u64(seed)),
                    ]),
                ),
                (
                    "entries".into(),
                    Json::Arr(entries.into_iter().map(|(_, e)| e).collect()),
                ),
            ]);
            match peer::forward(p, &msg, self.timeout) {
                Ok(resp)
                    if matches!(resp.get("ok").and_then(|o| o.as_bool().ok()), Some(true)) =>
                {
                    match store.remove_pack_entries(&model, &group, seed, &fps) {
                        Ok(()) => {
                            p.repairs.fetch_add(1, Ordering::SeqCst);
                            eprintln!(
                                "ring: repaired pack {stem} ({} entries) to owner {}",
                                fps.len(),
                                p.addr
                            );
                        }
                        Err(e) => eprintln!(
                            "warn: owner {} acked pack {stem} but the local trim failed: {e:#}",
                            p.addr
                        ),
                    }
                }
                Ok(resp) => {
                    let why = resp
                        .get("error")
                        .and_then(|e| e.as_str().ok())
                        .unwrap_or("refused");
                    eprintln!("warn: owner {} refused repair of {stem}: {why}", p.addr);
                }
                Err(e) => {
                    eprintln!(
                        "warn: repair push of {stem} to {} failed (will retry): {e:#}",
                        p.addr
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two(self_addr: &str) -> Ring {
        Ring::parse(
            "127.0.0.1:7001,127.0.0.1:7002",
            &[self_addr.to_string()],
        )
        .unwrap()
    }

    #[test]
    fn parse_rejects_bad_configs() {
        let me = vec!["127.0.0.1:7001".to_string()];
        assert!(Ring::parse("", &me).is_err());
        assert!(Ring::parse("127.0.0.1:7001", &me).is_err(), "one node");
        assert!(Ring::parse("127.0.0.1:7001,localhost", &me).is_err(), "no port");
        assert!(
            Ring::parse("127.0.0.1:7001,127.0.0.1:7001", &me).is_err(),
            "duplicate"
        );
        let err = Ring::parse("127.0.0.1:7002,127.0.0.1:7003", &me).unwrap_err();
        assert!(err.to_string().contains("own address"), "{err:#}");
    }

    #[test]
    fn ownership_is_identical_from_every_node_and_spreads() {
        let a = two("127.0.0.1:7001");
        let b = two("127.0.0.1:7002");
        assert_eq!(a.self_idx(), 0);
        assert_eq!(b.self_idx(), 1);
        let mut owned = [0usize; 2];
        for model in ["tiny", "alexnet", "vgg16", "mobile"] {
            for seed in 0..32u64 {
                let stem = format!("{model}-Orig-s{seed}");
                let oa = a.owner_of(&stem);
                // Placement must not depend on which node asks.
                assert_eq!(oa, b.owner_of(&stem), "{stem}");
                // And must be stable call over call.
                assert_eq!(oa, a.owner_of(&stem), "{stem}");
                owned[oa] += 1;
            }
        }
        // 64 vnodes/node over 128 stems: both nodes own a real share.
        assert!(owned[0] >= 16, "skewed: {owned:?}");
        assert!(owned[1] >= 16, "skewed: {owned:?}");
    }

    #[test]
    fn ring_state_gauges_shape() {
        let state = RingState::new(two("127.0.0.1:7001"));
        assert_eq!(state.self_addr(), "127.0.0.1:7001");
        // `owns` must agree with `owner_of` against the self index.
        let stem = "tiny-Orig-s9";
        assert_eq!(state.owns(stem), state.owner_of(stem) == state.self_idx());
        let g = state.gauges();
        assert_eq!(g.get("self").unwrap().as_str().unwrap(), "127.0.0.1:7001");
        assert_eq!(g.get("nodes").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(g.get("forwards").unwrap().as_u64().unwrap(), 0);
        let peers = g.get("peers").unwrap().as_arr().unwrap();
        assert_eq!(peers.len(), 1, "self slot excluded");
        assert_eq!(
            peers[0].get("addr").unwrap().as_str().unwrap(),
            "127.0.0.1:7002"
        );
        assert_eq!(peers[0].get("state").unwrap().as_str().unwrap(), "up");
    }
}
