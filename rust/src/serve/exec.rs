//! Fixed-size executor pool with a bounded admission queue.
//!
//! The reactor (see [`crate::serve::reactor`]) never blocks: CPU-heavy work
//! (sweep jobs, `map` searches, `warm` grids) is handed to this pool as
//! boxed closures. The pool owns a **fixed** number of worker threads —
//! `CODR_SERVE_EXECUTORS`, default 4 — so the server's thread count is
//! independent of the number of connected clients.
//!
//! Admission is bounded: at most `cap` tasks may be **waiting** in the
//! queue (tasks already running on a worker do not count). When the queue
//! is full, [`Exec::submit`] refuses the task and the caller answers the
//! client with `state:"queued-full"` instead of stalling intake. The cap
//! is the `--max-queued` CLI switch.
//!
//! Shutdown is two-phase, mirroring the drain contract: a soft
//! [`Exec::request_stop`] lets workers finish the queue and exit when it
//! is empty, and a hard stop (deadline passed) makes workers exit before
//! picking up any further queued task. Panics inside a task are contained
//! with `catch_unwind` so one poisoned sweep cannot take a worker down.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::sync;

/// A unit of work handed to the pool by the reactor.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// Default bound on the number of *waiting* tasks (`--max-queued`).
pub const DEFAULT_MAX_QUEUED: usize = 64;

/// Result of an admission attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    /// The task was queued (or is about to run).
    Accepted,
    /// The queue was full; the task was dropped. Carries the queue length
    /// observed at refusal time, for the `queued-full` answer.
    QueuedFull(usize),
}

struct ExecQueue {
    tasks: VecDeque<Task>,
    /// Soft stop: finish queued tasks, then exit.
    stop: bool,
    /// Hard stop: exit without picking up further queued tasks.
    halt: bool,
}

/// Fixed worker pool with bounded admission.
pub struct Exec {
    queue: Mutex<ExecQueue>,
    ready: Condvar,
    cap: AtomicUsize,
    /// Tasks currently executing on a worker (gauge, for `status`).
    active: AtomicUsize,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Default for Exec {
    fn default() -> Self {
        Self::new()
    }
}

impl Exec {
    pub fn new() -> Self {
        Exec {
            queue: Mutex::new(ExecQueue { tasks: VecDeque::new(), stop: false, halt: false }),
            ready: Condvar::new(),
            cap: AtomicUsize::new(DEFAULT_MAX_QUEUED),
            active: AtomicUsize::new(0),
            threads: Mutex::new(Vec::new()),
        }
    }

    /// Number of worker threads, from `CODR_SERVE_EXECUTORS` (default 4,
    /// clamped to at least 1).
    pub fn default_workers() -> usize {
        crate::analysis::env_registry::var("CODR_SERVE_EXECUTORS")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(4)
            .max(1)
    }

    /// Set the admission cap (`--max-queued`), clamped to at least 1.
    pub fn set_cap(&self, cap: usize) {
        self.cap.store(cap.max(1), Ordering::SeqCst);
    }

    pub fn cap(&self) -> usize {
        self.cap.load(Ordering::SeqCst)
    }

    /// Number of tasks waiting in the queue (not yet on a worker).
    pub fn queue_len(&self) -> usize {
        sync::lock(&self.queue).tasks.len()
    }

    /// Number of tasks currently executing on a worker.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Number of live worker threads (reported by `status`).
    pub fn workers(&self) -> usize {
        sync::lock(&self.threads).len()
    }

    /// Spawn `n` worker threads. Called once from `Server::run`.
    pub fn start(self: &std::sync::Arc<Self>, n: usize) {
        let mut threads = sync::lock(&self.threads);
        for i in 0..n.max(1) {
            let pool = std::sync::Arc::clone(self);
            let handle = std::thread::Builder::new()
                .name(format!("codr-exec-{i}"))
                .spawn(move || pool.worker_loop());
            match handle {
                Ok(h) => threads.push(h),
                Err(e) => eprintln!("warn: could not spawn executor worker: {e}"),
            }
        }
    }

    fn worker_loop(&self) {
        loop {
            let task = {
                let mut q = sync::lock(&self.queue);
                loop {
                    if q.halt || (q.stop && q.tasks.is_empty()) {
                        return;
                    }
                    if let Some(t) = q.tasks.pop_front() {
                        break t;
                    }
                    q = sync::wait(&self.ready, q);
                }
            };
            self.active.fetch_add(1, Ordering::SeqCst);
            // Tasks carry their own panic containment (sweep workers wrap
            // the grid walk), but a belt-and-braces catch here keeps one
            // misbehaving closure from killing the worker thread.
            let _ = catch_unwind(AssertUnwindSafe(task));
            self.active.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Try to admit a task. Refuses with [`Admit::QueuedFull`] when the
    /// number of waiting tasks has reached the cap, or when the pool is
    /// stopping.
    pub fn submit(&self, task: Task) -> Admit {
        let cap = self.cap();
        let mut q = sync::lock(&self.queue);
        if q.stop || q.halt {
            return Admit::QueuedFull(q.tasks.len());
        }
        if q.tasks.len() >= cap {
            return Admit::QueuedFull(q.tasks.len());
        }
        q.tasks.push_back(task);
        drop(q);
        self.ready.notify_one();
        Admit::Accepted
    }

    /// Enqueue past the admission cap. For work that must not be refused
    /// once accepted: journal-recovered jobs, and submits the reactor
    /// already admitted (capacity was checked before the job was registered
    /// and journaled). Returns `false` only after a hard stop, when workers
    /// will no longer pick the task up.
    pub fn submit_unbounded(&self, task: Task) -> bool {
        let mut q = sync::lock(&self.queue);
        if q.halt {
            return false;
        }
        q.tasks.push_back(task);
        drop(q);
        self.ready.notify_one();
        true
    }

    /// Soft stop: workers drain the queue, then exit. New submissions are
    /// refused from this point on.
    pub fn request_stop(&self) {
        sync::lock(&self.queue).stop = true;
        self.ready.notify_all();
    }

    /// Hard stop + join. Workers exit without picking up further queued
    /// tasks; queued-but-never-run tasks are dropped (the journal re-queues
    /// their jobs on the next start). Joins each worker until `deadline`,
    /// then detaches stragglers (a task may be mid-sweep; the process is
    /// exiting anyway).
    pub fn shutdown(&self, deadline: Instant) {
        {
            let mut q = sync::lock(&self.queue);
            q.stop = true;
            q.halt = true;
            q.tasks.clear();
        }
        self.ready.notify_all();
        let handles = std::mem::take(&mut *sync::lock(&self.threads));
        for h in handles {
            while !h.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            if h.is_finished() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    #[test]
    fn admission_refuses_past_cap() {
        // No workers started: every submitted task stays queued, so the
        // admission decision is deterministic.
        let pool = Arc::new(Exec::new());
        pool.set_cap(2);
        assert_eq!(pool.submit(Box::new(|| {})), Admit::Accepted);
        assert_eq!(pool.submit(Box::new(|| {})), Admit::Accepted);
        assert_eq!(pool.submit(Box::new(|| {})), Admit::QueuedFull(2));
        assert_eq!(pool.queue_len(), 2);
        pool.shutdown(Instant::now());
    }

    #[test]
    fn workers_run_tasks_and_panics_are_contained() {
        let pool = Arc::new(Exec::new());
        pool.set_cap(16);
        pool.start(2);
        let (tx, rx) = mpsc::channel::<u32>();
        let t1 = tx.clone();
        assert_eq!(pool.submit(Box::new(move || panic!("contained"))), Admit::Accepted);
        assert_eq!(
            pool.submit(Box::new(move || {
                t1.send(7).unwrap();
            })),
            Admit::Accepted
        );
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), 7);
        // Pool still functional after the panic.
        let t2 = tx;
        assert_eq!(
            pool.submit(Box::new(move || {
                t2.send(9).unwrap();
            })),
            Admit::Accepted
        );
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), 9);
        pool.request_stop();
        pool.shutdown(Instant::now() + Duration::from_secs(5));
        assert_eq!(pool.active(), 0);
    }

    #[test]
    fn stop_refuses_new_work() {
        let pool = Arc::new(Exec::new());
        pool.request_stop();
        assert!(matches!(pool.submit(Box::new(|| {})), Admit::QueuedFull(_)));
    }
}
