//! Peer client + health tracking for the multi-host ring.
//!
//! One [`Peer`] per remote ring node. All traffic to a peer flows
//! through two entry points with different failure semantics:
//!
//! * [`forward`] — forward a client's work (or push a repair pack) to
//!   the pack owner. Bounded by the per-peer timeout
//!   (`CODR_PEER_TIMEOUT_MS`), guarded by the `peer.conn.fail` fault
//!   seam, and — when faults are armed — the request line runs through
//!   the `peer.forward.torn` seam (a torn forward never reaches the
//!   owner whole: the receiving reactor waits for the missing newline
//!   and this side's read times out, surfacing as a transport error the
//!   caller retries or degrades on).
//! * [`probe`] — the periodic health `ping` scheduled by the reactor's
//!   maintenance tick. Its latency (including any `peer.probe.stall`
//!   injection) lands in a per-peer histogram reported as `probe_p99_ms`.
//!
//! Health is a failure-threshold state machine: any success resets to
//! **Up**; the first consecutive failure demotes to **Suspect**; after
//! [`DOWN_AFTER`] consecutive failures the peer is **Down**. Forwarding
//! skips Down peers immediately (straight to degraded mode) instead of
//! burning the timeout per request; the probe keeps running so a
//! recovered peer is promoted back to Up within one maintenance tick.
//!
//! Counters use `SeqCst` ordering: they are low-rate (per forward /
//! per probe, not per sweep point), and the health state must be
//! totally ordered with the routing decisions that read it.

use super::metrics::Hist;
use super::proto;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::time::{Duration, Instant};

/// Default per-peer connect/read/write timeout (`CODR_PEER_TIMEOUT_MS`).
pub(crate) const DEFAULT_TIMEOUT_MS: u64 = 1000;

/// Consecutive failures that demote a peer from Suspect to Down.
pub(crate) const DOWN_AFTER: u32 = 3;

/// Per-peer timeout from `CODR_PEER_TIMEOUT_MS` (milliseconds, default
/// [`DEFAULT_TIMEOUT_MS`], clamped to at least 1ms). Applies to connect,
/// read, and write individually.
pub(crate) fn peer_timeout() -> Duration {
    let ms = crate::analysis::env_registry::var("CODR_PEER_TIMEOUT_MS")
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(DEFAULT_TIMEOUT_MS)
        .max(1);
    Duration::from_millis(ms)
}

/// Peer health: Up → Suspect (first failure) → Down ([`DOWN_AFTER`]
/// consecutive failures); any success resets to Up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Health {
    Up,
    Suspect,
    Down,
}

impl Health {
    pub(crate) fn name(self) -> &'static str {
        match self {
            Health::Up => "up",
            Health::Suspect => "suspect",
            Health::Down => "down",
        }
    }

    fn from_u8(v: u8) -> Health {
        match v {
            0 => Health::Up,
            1 => Health::Suspect,
            _ => Health::Down,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            Health::Up => 0,
            Health::Suspect => 1,
            Health::Down => 2,
        }
    }
}

/// One remote ring node: its address, health state machine, and the
/// per-peer gauges `status` reports.
pub(crate) struct Peer {
    pub(crate) addr: String,
    state: AtomicU8,
    consecutive_failures: AtomicU32,
    /// Submits successfully forwarded to this peer.
    pub(crate) forwards: AtomicU64,
    /// Forward attempts that failed (transport error, injected fault, or
    /// an owner-side error answer other than `queued-full`).
    pub(crate) forward_errors: AtomicU64,
    /// Misplaced packs successfully pushed to this peer by the
    /// anti-entropy repair pass.
    pub(crate) repairs: AtomicU64,
    probe: Hist,
}

impl Peer {
    pub(crate) fn new(addr: impl Into<String>) -> Peer {
        Peer {
            addr: addr.into(),
            state: AtomicU8::new(Health::Up.as_u8()),
            consecutive_failures: AtomicU32::new(0),
            forwards: AtomicU64::new(0),
            forward_errors: AtomicU64::new(0),
            repairs: AtomicU64::new(0),
            probe: Hist::new(),
        }
    }

    pub(crate) fn health(&self) -> Health {
        Health::from_u8(self.state.load(Ordering::SeqCst))
    }

    fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::SeqCst);
        self.state.store(Health::Up.as_u8(), Ordering::SeqCst);
    }

    fn record_failure(&self) {
        let fails = self.consecutive_failures.fetch_add(1, Ordering::SeqCst) + 1;
        let next = if fails >= DOWN_AFTER { Health::Down } else { Health::Suspect };
        self.state.store(next.as_u8(), Ordering::SeqCst);
    }

    /// The per-peer gauge object surfaced by `status` and the `ring` verb.
    pub(crate) fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("addr".into(), Json::str(&self.addr)),
            ("state".into(), Json::str(self.health().name())),
            ("forwards".into(), Json::u64(self.forwards.load(Ordering::SeqCst))),
            (
                "forward_errors".into(),
                Json::u64(self.forward_errors.load(Ordering::SeqCst)),
            ),
            ("repairs".into(), Json::u64(self.repairs.load(Ordering::SeqCst))),
            ("probe_p99_ms".into(), Json::f64(self.probe.quantile_ms(0.99))),
        ])
    }
}

/// One request/response exchange with a peer, every phase bounded by
/// `timeout`. `torn_seam` routes the request line through the
/// `peer.forward.torn` fault (forward traffic only — probes must stay
/// honest about what a healthy peer looks like).
fn call(addr: &str, msg: &Json, timeout: Duration, torn_seam: bool) -> Result<Json> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving peer address {addr}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("peer address {addr} resolves to nothing"))?;
    let stream = TcpStream::connect_timeout(&sock, timeout)
        .with_context(|| format!("connecting to peer {addr}"))?;
    stream.set_read_timeout(Some(timeout)).ok();
    stream.set_write_timeout(Some(timeout)).ok();
    let mut writer = stream.try_clone().context("cloning peer stream")?;
    let mut reader = BufReader::new(stream);
    let mut line = msg.to_string().into_bytes();
    line.push(b'\n');
    // Injection seam: a forward torn mid-write (sender dies between
    // connect and the newline landing). The receiving reactor never sees
    // a complete line, so nothing is enqueued there; this side's read
    // times out and the caller retries or degrades. The copy-free fast
    // path is preserved: the seam only runs when faults are armed.
    if torn_seam && crate::faults::armed() {
        crate::faults::torn_point("peer.forward.torn", &mut line);
    }
    writer
        .write_all(&line)
        .with_context(|| format!("sending to peer {addr}"))?;
    writer.flush().with_context(|| format!("flushing to peer {addr}"))?;
    proto::read_message(&mut reader)?
        .with_context(|| format!("peer {addr} closed the connection without replying"))
}

/// Forward one request (a routed submit or a repair push) to `peer`.
/// Transport failures — including the `peer.conn.fail` injection —
/// update the health state machine; the caller owns retry/degrade
/// policy and the forward/repair gauges.
pub(crate) fn forward(peer: &Peer, msg: &Json, timeout: Duration) -> Result<Json> {
    if crate::faults::point("peer.conn.fail") {
        peer.record_failure();
        anyhow::bail!("fault injected: peer.conn.fail ({})", peer.addr);
    }
    match call(&peer.addr, msg, timeout, true) {
        Ok(resp) => {
            peer.record_success();
            Ok(resp)
        }
        Err(e) => {
            peer.record_failure();
            Err(e)
        }
    }
}

/// One health probe: `ping` the peer and update its state machine. The
/// observed latency (including any injected `peer.probe.stall`) lands in
/// the per-peer histogram behind `probe_p99_ms`. Returns whether the
/// peer answered ok.
pub(crate) fn probe(peer: &Peer, timeout: Duration) -> bool {
    crate::faults::sleep_point("peer.probe.stall", Duration::from_secs(2));
    let t0 = Instant::now();
    let resp = call(
        &peer.addr,
        &Json::Obj(vec![("verb".into(), Json::str("ping"))]),
        timeout,
        false,
    );
    peer.probe.record(t0.elapsed());
    let ok = matches!(
        &resp,
        Ok(r) if matches!(r.get("ok").and_then(|o| o.as_bool().ok()), Some(true))
    );
    if ok {
        peer.record_success();
    } else {
        peer.record_failure();
    }
    ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_walks_up_suspect_down_and_recovers() {
        let p = Peer::new("127.0.0.1:1");
        assert_eq!(p.health(), Health::Up);
        p.record_failure();
        assert_eq!(p.health(), Health::Suspect);
        p.record_failure();
        assert_eq!(p.health(), Health::Suspect);
        p.record_failure();
        assert_eq!(p.health(), Health::Down);
        // Further failures keep it Down; one success fully recovers.
        p.record_failure();
        assert_eq!(p.health(), Health::Down);
        p.record_success();
        assert_eq!(p.health(), Health::Up);
        // The counter reset means the next single failure is Suspect again.
        p.record_failure();
        assert_eq!(p.health(), Health::Suspect);
    }

    #[test]
    fn probe_against_dead_port_marks_failure_and_records_latency() {
        let p = Peer::new("127.0.0.1:1");
        assert!(!probe(&p, Duration::from_millis(50)));
        assert_eq!(p.health(), Health::Suspect);
        let j = p.to_json();
        assert_eq!(j.get("state").unwrap().as_str().unwrap(), "suspect");
        // One sample recorded: the quantile reports a bucket bound > 0.
        assert!(j.get("probe_p99_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn forward_against_dead_port_surfaces_transport_error() {
        let p = Peer::new("127.0.0.1:1");
        let err = forward(
            &p,
            &Json::Obj(vec![("verb".into(), Json::str("ping"))]),
            Duration::from_millis(50),
        )
        .unwrap_err();
        assert!(err.to_string().contains("connecting to peer"), "{err:#}");
        assert_eq!(p.health(), Health::Suspect);
    }
}
