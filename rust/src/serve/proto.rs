//! `codr serve` wire protocol: one JSON object per line, both directions
//! (tokio is unavailable offline; blocking std::net + line framing keeps
//! the protocol trivially scriptable — `echo '{"verb":"status"}' | nc`).
//!
//! Requests name a verb plus grid fields; responses always carry an
//! `"ok"` bool, with `"error"` set when it is false.
//!
//! ```text
//! → {"verb":"warm","models":"tiny","groups":"Orig,D=50%","seed":42}
//! ← {"ok":true,"stats":{"requested":6,"cache_hits":0,...}}
//! → {"verb":"submit","models":"alexnet"}
//! ← {"ok":true,"job":1}
//! → {"verb":"status","job":1}
//! ← {"ok":true,"state":"running"}
//! → {"verb":"status"}
//! ← {"ok":true,"jobs":1,"running":0,"store_entries":6,
//!    "store":{"entries":6,"packed_files":2,"v1_files":0,"bytes":...,"cap_bytes":null},
//!    "memo":{"entries":...,"hits":...,"misses":...,"evictions":...,
//!            "lookups":...,"l1_hits":...,"l2_hits":...,"collision_verifies":...,
//!            "double_computes":...,"lock_waits":...,
//!            "arena":{"entries":...,"bytes":...}}}
//! → {"verb":"result","model":"tiny","group":"Orig","arch":"CoDR","seed":42}
//! ← {"ok":true,"cycles":...,"energy_uj":...,"bits_per_weight":...}
//! → {"verb":"map","model":"alexnet","layer":"conv1","quick":true}
//! ← {"ok":true,"job":2,"layer":"conv1","candidates":17}
//! → {"verb":"watch","job":1}
//! ← {"ok":true,"job":1,"watching":true,"total":3}
//! ← {"event":"point","job":1,"done":1,"total":3,"model":"alexnet",
//!    "group":"Orig","arch":"CoDR","cache_hit":false}
//! ← {"event":"point","job":1,"done":2,"total":3,...}
//! ← {"event":"point","job":1,"done":3,"total":3,...}
//! ← {"event":"end","job":1,"stats":{...}}
//! ```
//!
//! `watch` is the one verb that **streams**: after the `ok` ack the
//! server pushes one `point` event per completed sweep point (replaying
//! history first, so a late watcher sees the same sequence) and a
//! terminal `end` event whose `stats` equal the job's final `status`
//! stats (or an `error` field if the job failed / the server shut down
//! first). After `end`, the connection returns to request/response
//! framing.
//!
//! `map` submits a **mapping-space search** job (optional fields:
//! `layer` — defaults to the model's first conv layer; `group`, `seed`,
//! `max_candidates`, `quick`). Its progress streams through the same
//! `watch` channel, one `point` event per evaluated candidate (`group`
//! carries the candidate's tile label, `arch` is always CoDR), and the
//! terminal `end` event carries the search stats plus the full Pareto
//! front under `map` (the `codr map --json` report shape).
//!
//! **Backpressure.** Admission to the server's executor pool is bounded
//! (`--max-queued`): past the cap, `submit`/`map`/`warm` answer
//! `{"ok":false,"state":"queued-full","queued":N,"max_queued":C,
//! "error":...}` instead of stalling intake. `queued-full` is never a
//! success: clients retry it under their `--retries` backoff
//! ([`request_admitted`]) and exit nonzero when the budget runs out.
//!
//! The server-wide `status` reply keeps the flat `store_entries` field
//! for pre-v2 clients; the structured `store` / `memo` objects are the
//! forward surface (store occupancy in packed-v2 terms, the two-level
//! memo breakdown — L1/L2 hits, collision verifies, double computes,
//! lock waits, arena occupancy — and the open watcher count).

use crate::coordinator::{Arch, SweepStats};
use crate::models::{parse_group_list, parse_model_list, Model, SweepGroup};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fmt;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Longest accepted request/response line. Grid requests are tiny; the
/// cap only bounds memory against a misbehaving peer.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Default listen address of `codr serve`.
pub const DEFAULT_ADDR: &str = "127.0.0.1:7878";

/// A parsed grid request: which sweep points a client wants.
pub struct GridRequest {
    pub models: Vec<Model>,
    pub groups: Vec<SweepGroup>,
    pub archs: Vec<Arch>,
    pub seed: u64,
}

impl GridRequest {
    /// Parse the grid fields of a request, defaulting to the paper's
    /// evaluation grid (all models × all groups × all designs, seed 42).
    pub fn from_json(j: &Json) -> Result<GridRequest> {
        let models = match j.get("models") {
            Some(m) => parse_model_list(m.as_str()?)?,
            None => crate::models::all_models(),
        };
        let groups = match j.get("groups") {
            Some(g) => parse_group_list(g.as_str()?)?,
            None => SweepGroup::all(),
        };
        let archs = match j.get("archs") {
            Some(a) => Arch::parse_list(a.as_str()?)?,
            None => Arch::all().to_vec(),
        };
        let seed = match j.get("seed") {
            Some(s) => s.as_u64().context("seed must be a non-negative integer")?,
            None => 42,
        };
        Ok(GridRequest {
            models,
            groups,
            archs,
            seed,
        })
    }

    pub fn points(&self) -> usize {
        self.models.len() * self.groups.len() * self.archs.len()
    }

    /// Serialize back to the request shape [`Self::from_json`] parses —
    /// the job journal stores this, so a re-queued job is re-parsed by
    /// the exact code path a fresh submit takes.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "models".into(),
                Json::str(
                    self.models
                        .iter()
                        .map(|m| m.name)
                        .collect::<Vec<_>>()
                        .join(","),
                ),
            ),
            (
                "groups".into(),
                Json::str(
                    self.groups
                        .iter()
                        .map(|g| g.label())
                        .collect::<Vec<_>>()
                        .join(","),
                ),
            ),
            (
                "archs".into(),
                Json::str(
                    self.archs
                        .iter()
                        .map(|a| a.name())
                        .collect::<Vec<_>>()
                        .join(","),
                ),
            ),
            ("seed".into(), Json::u64(self.seed)),
        ])
    }
}

/// Serialize sweep stats for a response.
pub fn stats_to_json(s: &SweepStats) -> Json {
    Json::Obj(vec![
        ("requested".into(), Json::usize(s.requested)),
        ("cache_hits".into(), Json::usize(s.cache_hits)),
        ("computed".into(), Json::usize(s.computed)),
        ("deduped".into(), Json::usize(s.deduped)),
        ("corrupt".into(), Json::usize(s.corrupt)),
        ("simulated_layers".into(), Json::usize(s.simulated_layers)),
        ("memo_hits".into(), Json::usize(s.memo_hits)),
        ("memo_misses".into(), Json::usize(s.memo_misses)),
        ("l1_hits".into(), Json::usize(s.l1_hits)),
        ("l2_hits".into(), Json::usize(s.l2_hits)),
        ("collision_verifies".into(), Json::usize(s.collision_verifies)),
        ("lock_waits".into(), Json::usize(s.lock_waits)),
        ("failed".into(), Json::usize(s.failed)),
        ("wall_ms".into(), Json::u64(s.wall_ms)),
    ])
}

/// Parse stats back out of a response (client side). The memo/wall
/// fields (including the two-level breakdown added with the
/// fingerprint memo) default to zero so an upgraded client still reads
/// responses from a pre-upgrade server that has been running since
/// before they existed.
pub fn stats_from_json(j: &Json) -> Result<SweepStats> {
    let opt_usize = |key: &str| -> Result<usize> {
        match j.get(key) {
            Some(v) => v.as_usize(),
            None => Ok(0),
        }
    };
    Ok(SweepStats {
        requested: j.field("requested")?.as_usize()?,
        cache_hits: j.field("cache_hits")?.as_usize()?,
        computed: j.field("computed")?.as_usize()?,
        deduped: j.field("deduped")?.as_usize()?,
        corrupt: j.field("corrupt")?.as_usize()?,
        simulated_layers: j.field("simulated_layers")?.as_usize()?,
        memo_hits: opt_usize("memo_hits")?,
        memo_misses: opt_usize("memo_misses")?,
        l1_hits: opt_usize("l1_hits")?,
        l2_hits: opt_usize("l2_hits")?,
        collision_verifies: opt_usize("collision_verifies")?,
        lock_waits: opt_usize("lock_waits")?,
        failed: opt_usize("failed")?,
        wall_ms: match j.get("wall_ms") {
            Some(v) => v.as_u64()?,
            None => 0,
        },
    })
}

pub fn ok_response(mut fields: Vec<(String, Json)>) -> Json {
    let mut pairs = vec![("ok".into(), Json::Bool(true))];
    pairs.append(&mut fields);
    Json::Obj(pairs)
}

pub fn error_response(msg: impl Into<String>) -> Json {
    Json::Obj(vec![
        ("ok".into(), Json::Bool(false)),
        ("error".into(), Json::Str(msg.into())),
    ])
}

/// The backpressure refusal: the executor's admission queue is at the
/// cap. Carries `state:"queued-full"` so clients can distinguish "server
/// busy, retry later" from a hard error, plus the observed queue depth.
pub fn queued_full_response(queued: usize, cap: usize) -> Json {
    Json::Obj(vec![
        ("ok".into(), Json::Bool(false)),
        ("state".into(), Json::str("queued-full")),
        ("queued".into(), Json::usize(queued)),
        ("max_queued".into(), Json::usize(cap)),
        (
            "error".into(),
            Json::str(format!(
                "admission queue full ({queued}/{cap} tasks queued); back off and retry"
            )),
        ),
    ])
}

/// Is this response the server's bounded-admission refusal?
pub fn is_queued_full(resp: &Json) -> bool {
    matches!(resp.get("state").map(|s| s.as_str()), Some(Ok("queued-full")))
}

/// Read one line-delimited JSON value from a buffered reader. Returns
/// `Ok(None)` on clean EOF.
pub fn read_message(reader: &mut impl BufRead) -> Result<Option<Json>> {
    use std::io::Read;
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader
            .by_ref()
            .take(MAX_LINE_BYTES as u64)
            .read_line(&mut line)
            .context("reading message line")?;
        if n == 0 {
            return Ok(None);
        }
        if n >= MAX_LINE_BYTES && !line.ends_with('\n') {
            anyhow::bail!("message exceeds {MAX_LINE_BYTES} bytes");
        }
        if line.trim().is_empty() {
            continue; // tolerate blank keep-alive lines
        }
        return Json::parse(line.trim()).map(Some);
    }
}

/// Write one value as a line.
pub fn write_message(writer: &mut impl Write, msg: &Json) -> Result<()> {
    writeln!(writer, "{msg}").context("writing message line")?;
    writer.flush().context("flushing message")?;
    Ok(())
}

/// Client-side retry policy: `attempts` extra tries after the first
/// failure, exponential backoff from `base` doubling per attempt, plus
/// seeded jitter in `[0, base)` so a thundering herd of reconnecting
/// clients decorrelates. `Retry::none()` (zero attempts) is the
/// default — behavior is bit-for-bit the pre-retry client.
#[derive(Clone, Debug)]
pub struct Retry {
    pub attempts: u32,
    pub base: std::time::Duration,
    pub jitter_seed: u64,
}

impl Retry {
    pub fn none() -> Retry {
        Retry::attempts(0)
    }

    /// `n` retries with the standard base backoff (250 ms), seeded from
    /// the process id so two clients launched together jitter apart.
    pub fn attempts(n: u32) -> Retry {
        Retry {
            attempts: n,
            base: std::time::Duration::from_millis(250),
            jitter_seed: std::process::id() as u64,
        }
    }

    /// Backoff before retry number `attempt` (1-based): exponential in
    /// the attempt with one seeded jitter draw added. Crate-visible so
    /// the server's peer-forward path retries under the same curve
    /// clients use.
    pub(crate) fn backoff(&self, attempt: u32) -> std::time::Duration {
        let exp = self.base.saturating_mul(1u32 << (attempt - 1).min(10));
        let base_ms = self.base.as_millis().max(1) as u64;
        let jitter = crate::util::rng::Rng::new(self.jitter_seed)
            .fork(&format!("retry.{attempt}"))
            .below(base_ms);
        exp + std::time::Duration::from_millis(jitter)
    }
}

/// Client helper: open a fresh connection, send one request, read one
/// response. Errors if the server reports `ok:false`? No — transport
/// errors only; callers inspect `ok` themselves so they can surface the
/// server's error text.
pub fn request(addr: &str, msg: &Json) -> Result<Json> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to codr serve at {addr}"))?;
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(600)))
        .ok();
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    write_message(&mut writer, msg)?;
    read_message(&mut reader)?.context("server closed the connection without replying")
}

/// [`request`] with retries: transport failures (connect refused, reset,
/// timeout, truncated reply) back off and retry; an `ok:false` response
/// returns immediately — the server answered, retrying won't change its
/// mind. Safe for the verbs the CLI retries (`status` and `watch` are
/// read-only; `submit`/`map` ONLY retry the connect-and-send when no
/// response arrived, which can at worst enqueue a duplicate grid — the
/// store dedups its points, so the cost is bounded).
pub fn request_retry(addr: &str, msg: &Json, retry: &Retry) -> Result<Json> {
    let mut attempt = 0u32;
    loop {
        match request(addr, msg) {
            Ok(resp) => return Ok(resp),
            Err(e) => {
                attempt += 1;
                if attempt > retry.attempts {
                    return Err(e);
                }
                let pause = retry.backoff(attempt);
                eprintln!(
                    "retry {attempt}/{}: {e:#} — backing off {}ms",
                    retry.attempts,
                    pause.as_millis()
                );
                std::thread::sleep(pause);
            }
        }
    }
}

/// [`request_retry`] that also treats the server's `queued-full`
/// backpressure refusal as retryable: transport failures and
/// `state:"queued-full"` answers share one attempt budget with the same
/// exponential backoff + seeded jitter, so a flooded server sheds load
/// without clients hammering it in lockstep. Any other answered response
/// (ok or error) returns immediately. When the budget runs out on
/// `queued-full`, this fails — a refused submit is never a success.
pub fn request_admitted(addr: &str, msg: &Json, retry: &Retry) -> Result<Json> {
    admitted_with(retry, || request(addr, msg), std::thread::sleep)
}

/// The admission-retry state machine behind [`request_admitted`],
/// parameterized over the transport (`transact`) and the clock (`pause`)
/// so tests can drive mixed failure sequences and pin the exact backoff
/// schedule. The invariant the pinning test protects: transport failures
/// and `queued-full` refusals share ONE attempt counter — neither kind
/// resets the other's budget — so the backoff curve stays monotone
/// across mixed failures instead of restarting from `base`.
fn admitted_with(
    retry: &Retry,
    mut transact: impl FnMut() -> Result<Json>,
    mut pause: impl FnMut(std::time::Duration),
) -> Result<Json> {
    let mut attempt = 0u32;
    loop {
        let failure = match transact() {
            Ok(resp) if !is_queued_full(&resp) => return Ok(resp),
            Ok(resp) => {
                let busy = resp
                    .get("error")
                    .and_then(|e| e.as_str().ok())
                    .unwrap_or("admission queue full")
                    .to_string();
                if attempt >= retry.attempts {
                    anyhow::bail!("{busy} (still queued-full after {} attempt(s))", attempt + 1);
                }
                busy
            }
            Err(e) => {
                if attempt >= retry.attempts {
                    return Err(e);
                }
                format!("{e:#}")
            }
        };
        attempt += 1;
        let wait = retry.backoff(attempt);
        eprintln!(
            "retry {attempt}/{}: {failure} — backing off {}ms",
            retry.attempts,
            wait.as_millis()
        );
        pause(wait);
    }
}

/// Client helper: attach to a submitted job and stream its progress.
/// `on_event` fires for every event (including the terminal `end`,
/// which is also returned). Errors on transport failure — including a
/// server EOF before the terminal `end` event ("stream truncated": the
/// job is NOT known to have finished) — or if the server refuses the
/// attach (unknown/expired job).
pub fn watch(addr: &str, job: u64, on_event: impl FnMut(&Json)) -> Result<Json> {
    watch_retry(addr, job, &Retry::none(), on_event)
}

/// [`watch`] with reconnect-with-replay. A truncated stream (server
/// EOF, reset, read timeout before `end`) reconnects after backoff and
/// re-attaches: the server replays the job's full event history, and
/// `skip` suppresses the events this client already delivered, so
/// `on_event` sees every event exactly once even across reconnects
/// (replay is byte-identical — the job channel records history). A
/// refused attach (unknown/expired job) is not retried.
pub fn watch_retry(
    addr: &str,
    job: u64,
    retry: &Retry,
    mut on_event: impl FnMut(&Json),
) -> Result<Json> {
    let mut delivered = 0usize;
    let mut attempt = 0u32;
    loop {
        match watch_once(addr, job, &mut delivered, &mut on_event) {
            Ok(end) => return Ok(end),
            Err(e) => {
                // Protocol-level refusals are final; only transport
                // failures reconnect.
                if e.downcast_ref::<WatchRefused>().is_some() {
                    return Err(e);
                }
                attempt += 1;
                if attempt > retry.attempts {
                    return Err(e);
                }
                let pause = retry.backoff(attempt);
                eprintln!(
                    "watch retry {attempt}/{}: {e:#} — backing off {}ms",
                    retry.attempts,
                    pause.as_millis()
                );
                std::thread::sleep(pause);
            }
        }
    }
}

/// Marker for a server-side attach refusal (vs a transport failure):
/// retrying an unknown/expired job cannot succeed.
#[derive(Debug)]
struct WatchRefused;

impl fmt::Display for WatchRefused {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("watch refused")
    }
}

impl std::error::Error for WatchRefused {}

/// One watch attach. The first `*delivered` events of the stream were
/// already handed to `on_event` on a previous connection (the server
/// replays history from the start) and are suppressed; the counter
/// advances per delivered event, so a reconnect resumes exactly where
/// this attach died.
fn watch_once(
    addr: &str,
    job: u64,
    delivered: &mut usize,
    on_event: &mut impl FnMut(&Json),
) -> Result<Json> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to codr serve at {addr}"))?;
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(600)))
        .ok();
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    write_message(
        &mut writer,
        &Json::Obj(vec![
            ("verb".into(), Json::str("watch")),
            ("job".into(), Json::u64(job)),
        ]),
    )?;
    let ack = read_message(&mut reader)?.context("server closed without acking the watch")?;
    if !matches!(ack.get("ok").and_then(|o| o.as_bool().ok()), Some(true)) {
        let err = ack
            .get("error")
            .and_then(|e| e.as_str().ok().map(|s| s.to_string()))
            .unwrap_or_else(|| ack.to_string());
        return Err(anyhow::Error::new(WatchRefused).context(format!("watch refused: {err}")));
    }
    let mut seen = 0usize;
    loop {
        let event = read_message(&mut reader)?.with_context(|| {
            format!(
                "stream truncated: server closed after {seen} events without \
                 a terminal `end` — job {job} is not known to have finished"
            )
        })?;
        let is_end = matches!(event.get("event").map(|e| e.as_str()), Some(Ok("end")));
        seen += 1;
        if seen > *delivered {
            on_event(&event);
            *delivered = seen;
        }
        if is_end {
            return Ok(event);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_defaults_to_paper_evaluation() {
        let g = GridRequest::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(g.models.len(), 3);
        assert_eq!(g.groups.len(), 6);
        assert_eq!(g.archs.len(), 3);
        assert_eq!(g.seed, 42);
        assert_eq!(g.points(), 54);
    }

    #[test]
    fn grid_parses_explicit_fields() {
        let j = Json::parse(
            r#"{"models":"tiny","groups":"Orig,D=50%","archs":"codr,scnn","seed":7}"#,
        )
        .unwrap();
        let g = GridRequest::from_json(&j).unwrap();
        assert_eq!(g.models[0].name, "tiny");
        assert_eq!(g.groups, vec![SweepGroup::Original, SweepGroup::Density(50)]);
        assert_eq!(g.archs, vec![Arch::Codr, Arch::Scnn]);
        assert_eq!(g.seed, 7);
    }

    #[test]
    fn grid_rejects_unknown_names() {
        for bad in [
            r#"{"models":"resnet"}"#,
            r#"{"groups":"X=3"}"#,
            r#"{"archs":"tpu"}"#,
            r#"{"seed":-1}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(GridRequest::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn stats_roundtrip() {
        let s = SweepStats {
            requested: 10,
            cache_hits: 4,
            computed: 5,
            deduped: 1,
            corrupt: 2,
            simulated_layers: 37,
            memo_hits: 120,
            memo_misses: 30,
            l1_hits: 90,
            l2_hits: 30,
            collision_verifies: 0,
            lock_waits: 3,
            failed: 1,
            wall_ms: 251,
        };
        let back = stats_from_json(&stats_to_json(&s)).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.memo_hit_rate(), Some(0.8));
        // Pre-upgrade servers omit the breakdown fields: default zero.
        let legacy = Json::parse(
            r#"{"requested":1,"cache_hits":1,"computed":0,"deduped":0,"corrupt":0,
                "simulated_layers":0}"#
                .replace('\n', " ")
                .as_str(),
        )
        .unwrap();
        let back = stats_from_json(&legacy).unwrap();
        assert_eq!(back.l1_hits, 0);
        assert_eq!(back.lock_waits, 0);
        assert_eq!(back.failed, 0);
    }

    #[test]
    fn grid_request_json_roundtrip() {
        let j = Json::parse(
            r#"{"models":"tiny","groups":"Orig,D=50%","archs":"CoDR,SCNN","seed":7}"#,
        )
        .unwrap();
        let g = GridRequest::from_json(&j).unwrap();
        let back = GridRequest::from_json(&g.to_json()).unwrap();
        assert_eq!(back.models[0].name, "tiny");
        assert_eq!(back.groups, g.groups);
        assert_eq!(back.archs, g.archs);
        assert_eq!(back.seed, 7);
        // The round trip is a fixed point: journaled jobs re-serialize
        // identically however often they are recovered.
        assert_eq!(back.to_json().to_string(), g.to_json().to_string());
    }

    #[test]
    fn retry_backoff_grows_and_jitters_deterministically() {
        let r = Retry {
            attempts: 3,
            base: std::time::Duration::from_millis(100),
            jitter_seed: 9,
        };
        let b1 = r.backoff(1);
        let b2 = r.backoff(2);
        let b3 = r.backoff(3);
        // Exponential base with jitter bounded by one base unit.
        assert!((100..200).contains(&(b1.as_millis() as u64)), "{b1:?}");
        assert!((200..300).contains(&(b2.as_millis() as u64)), "{b2:?}");
        assert!((400..500).contains(&(b3.as_millis() as u64)), "{b3:?}");
        // Same seed, same schedule; a different seed jitters apart.
        assert_eq!(b1, r.backoff(1));
        let other = Retry { jitter_seed: 10, ..r.clone() };
        assert_ne!(
            (b1, b2, b3),
            (other.backoff(1), other.backoff(2), other.backoff(3))
        );
    }

    #[test]
    fn admitted_retry_shares_one_attempt_counter_across_failure_kinds() {
        let r = Retry {
            attempts: 3,
            base: std::time::Duration::from_millis(100),
            jitter_seed: 9,
        };
        let mut script: std::collections::VecDeque<Result<Json>> = [
            Err(anyhow::anyhow!("connection refused")),
            Ok(queued_full_response(4, 4)),
            Ok(queued_full_response(4, 4)),
            Ok(ok_response(vec![("job".into(), Json::u64(7))])),
        ]
        .into_iter()
        .collect();
        let mut pauses: Vec<std::time::Duration> = Vec::new();
        let resp = admitted_with(
            &r,
            || script.pop_front().expect("script exhausted"),
            |d| pauses.push(d),
        )
        .unwrap();
        assert_eq!(resp.get("job").unwrap().as_u64().unwrap(), 7);
        // One shared counter: the transport failure consumed attempt 1,
        // so the queued-full refusals continue at attempts 2 and 3 — the
        // schedule never resets to `base` when the failure kind changes.
        assert_eq!(pauses, vec![r.backoff(1), r.backoff(2), r.backoff(3)]);

        // Exhausting the budget on queued-full is a hard error.
        let mut script: std::collections::VecDeque<Result<Json>> =
            std::iter::repeat_with(|| Ok(queued_full_response(9, 4)))
                .take(2)
                .collect();
        let short = Retry { attempts: 1, ..r.clone() };
        let err = admitted_with(&short, || script.pop_front().unwrap(), |_| {}).unwrap_err();
        assert!(err.to_string().contains("queued-full"), "{err:#}");
    }

    #[test]
    fn request_retry_gives_up_after_its_budget() {
        // Port 1 never listens: every attempt fails at connect. Zero
        // retries must fail immediately; the backoff schedule is unit-
        // tested above (not exercised here to keep the test fast).
        let t0 = std::time::Instant::now();
        let err = request_retry(
            "127.0.0.1:1",
            &Json::parse(r#"{"verb":"status"}"#).unwrap(),
            &Retry::none(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("connecting"), "{err:#}");
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn queued_full_shape_is_distinguishable() {
        let resp = queued_full_response(3, 4);
        assert!(is_queued_full(&resp));
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(resp.get("queued").unwrap().as_usize().unwrap(), 3);
        assert_eq!(resp.get("max_queued").unwrap().as_usize().unwrap(), 4);
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("3/4"));
        assert!(!is_queued_full(&error_response("nope")));
        assert!(!is_queued_full(&ok_response(vec![])));
        // Wire roundtrip preserves the marker.
        let back = Json::parse(&resp.to_string()).unwrap();
        assert!(is_queued_full(&back));
    }

    #[test]
    fn request_admitted_fails_fast_on_transport_with_no_budget() {
        // Port 1 never listens; zero retries must surface the connect
        // error immediately (queued-full handling shares this budget).
        let err = request_admitted(
            "127.0.0.1:1",
            &Json::parse(r#"{"verb":"status"}"#).unwrap(),
            &Retry::none(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("connecting"), "{err:#}");
    }

    #[test]
    fn messages_frame_on_lines() {
        let mut buf = Vec::new();
        write_message(&mut buf, &ok_response(vec![])).unwrap();
        write_message(&mut buf, &error_response("nope")).unwrap();
        let mut r = std::io::BufReader::new(&buf[..]);
        let a = read_message(&mut r).unwrap().unwrap();
        assert!(a.get("ok").unwrap().as_bool().unwrap());
        let b = read_message(&mut r).unwrap().unwrap();
        assert!(!b.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(b.get("error").unwrap().as_str().unwrap(), "nope");
        assert!(read_message(&mut r).unwrap().is_none());
    }
}
