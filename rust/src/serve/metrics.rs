//! Per-verb latency/throughput counters for the serve layer.
//!
//! Every request dispatched by the reactor bumps a `requests` counter for
//! its verb; the eventual answer bumps `answers` (ok) or `errors`
//! (`ok:false`, including `queued-full` refusals), and the elapsed wall
//! time lands in a fixed 16-bucket log-scale histogram from which `status`
//! reports p50/p99. Conservation holds by construction:
//! `requests == answers + errors` once the server is quiescent — the soak
//! test pins this.
//!
//! Counters are monotonic and independent, so `Ordering::Relaxed` is
//! sufficient; all relaxed accesses are funneled through the [`bump`] /
//! [`read`] helpers, which carry the analyzer's allowlist entry.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Verbs tracked individually; anything unrecognized lands in `other`.
pub const VERB_NAMES: [&str; 11] = [
    "ping", "warm", "submit", "map", "watch", "status", "result", "shutdown", "ring", "repair",
    "other",
];

/// Upper bounds (inclusive) of the latency buckets, in microseconds.
/// The last bucket is the overflow bucket.
const BUCKET_BOUNDS_US: [u64; 15] = [
    250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 500_000, 1_000_000,
    2_500_000, 5_000_000, 10_000_000,
];

const BUCKETS: usize = BUCKET_BOUNDS_US.len() + 1;

/// Map a verb string to its slot in [`VERB_NAMES`].
pub fn verb_index(verb: &str) -> usize {
    VERB_NAMES.iter().position(|v| *v == verb).unwrap_or(VERB_NAMES.len() - 1)
}

fn bucket_index(elapsed_us: u64) -> usize {
    BUCKET_BOUNDS_US.iter().position(|b| elapsed_us <= *b).unwrap_or(BUCKETS - 1)
}

/// Increment a monotonic metrics counter. The single funnel for relaxed
/// atomics in this module (see the module docs and the analyzer allowlist).
fn bump(counter: &AtomicU64) {
    counter.fetch_add(1, Ordering::Relaxed);
}

/// Read a monotonic metrics counter (relaxed; see [`bump`]).
fn read(counter: &AtomicU64) -> u64 {
    counter.load(Ordering::Relaxed)
}

#[derive(Default)]
struct VerbStat {
    requests: AtomicU64,
    answers: AtomicU64,
    errors: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

/// Upper bound (ms) of the bucket where the cumulative count first
/// reaches `q` of the total, or 0.0 when no samples were recorded.
fn quantile_from(counts: &[u64; BUCKETS], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (q * total as f64).ceil().max(1.0) as u64;
    let mut cum = 0u64;
    for (i, c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return if i < BUCKET_BOUNDS_US.len() {
                BUCKET_BOUNDS_US[i] as f64 / 1000.0
            } else {
                // Overflow bucket: report the last finite bound.
                BUCKET_BOUNDS_US[BUCKET_BOUNDS_US.len() - 1] as f64 / 1000.0
            };
        }
    }
    BUCKET_BOUNDS_US[BUCKET_BOUNDS_US.len() - 1] as f64 / 1000.0
}

impl VerbStat {
    fn to_json(&self) -> Json {
        let counts: [u64; BUCKETS] = std::array::from_fn(|i| read(&self.buckets[i]));
        Json::Obj(vec![
            ("requests".into(), Json::u64(read(&self.requests))),
            ("answers".into(), Json::u64(read(&self.answers))),
            ("errors".into(), Json::u64(read(&self.errors))),
            ("p50_ms".into(), Json::f64(quantile_from(&counts, 0.50))),
            ("p99_ms".into(), Json::f64(quantile_from(&counts, 0.99))),
        ])
    }
}

/// A standalone log-scale latency histogram on the same bucket bounds
/// as the verb table — used for per-peer probe latency, where a full
/// [`VerbStat`] (request/answer accounting) does not apply. Shares the
/// [`bump`]/[`read`] relaxed-counter funnel.
#[derive(Default)]
pub(crate) struct Hist {
    buckets: [AtomicU64; BUCKETS],
}

impl Hist {
    pub(crate) fn new() -> Hist {
        Hist::default()
    }

    pub(crate) fn record(&self, elapsed: std::time::Duration) {
        let elapsed_us = elapsed.as_micros().min(u64::MAX as u128) as u64;
        bump(&self.buckets[bucket_index(elapsed_us)]);
    }

    pub(crate) fn quantile_ms(&self, q: f64) -> f64 {
        let counts: [u64; BUCKETS] = std::array::from_fn(|i| read(&self.buckets[i]));
        quantile_from(&counts, q)
    }
}

/// Per-verb counters for the whole server; one instance lives in
/// `serve::Shared` and is reported by the `status` verb.
#[derive(Default)]
pub struct Metrics {
    verbs: [VerbStat; VERB_NAMES.len()],
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record a dispatched request; returns the verb slot for `finish`.
    pub fn begin(&self, verb: &str) -> usize {
        let idx = verb_index(verb);
        bump(&self.verbs[idx].requests);
        idx
    }

    /// Record the answer for a request begun at `started`. `ok` mirrors the
    /// response's `ok` field (`queued-full` counts as an error).
    pub fn finish(&self, idx: usize, started: Instant, ok: bool) {
        let stat = &self.verbs[idx.min(VERB_NAMES.len() - 1)];
        if ok {
            bump(&stat.answers);
        } else {
            bump(&stat.errors);
        }
        let elapsed_us = started.elapsed().as_micros().min(u64::MAX as u128) as u64;
        bump(&stat.buckets[bucket_index(elapsed_us)]);
    }

    /// The `verbs` object surfaced by `status`.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            VERB_NAMES
                .iter()
                .enumerate()
                .map(|(i, name)| ((*name).to_string(), self.verbs[i].to_json()))
                .collect(),
        )
    }

    /// Totals across all verbs: (requests, answers, errors).
    pub fn totals(&self) -> (u64, u64, u64) {
        let mut req = 0;
        let mut ans = 0;
        let mut err = 0;
        for stat in &self.verbs {
            req += read(&stat.requests);
            ans += read(&stat.answers);
            err += read(&stat.errors);
        }
        (req, ans, err)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verb_index_maps_known_and_other() {
        assert_eq!(verb_index("ping"), 0);
        assert_eq!(verb_index("shutdown"), 7);
        assert_eq!(verb_index("ring"), 8);
        assert_eq!(verb_index("repair"), 9);
        assert_eq!(verb_index("frobnicate"), VERB_NAMES.len() - 1);
    }

    #[test]
    fn hist_records_and_reports_quantiles() {
        let h = Hist::new();
        assert_eq!(h.quantile_ms(0.99), 0.0, "empty histogram reports zero");
        for _ in 0..99 {
            h.record(std::time::Duration::from_micros(100));
        }
        h.record(std::time::Duration::from_millis(700));
        // 99% of samples land in the first bucket (bound 250us)...
        assert_eq!(h.quantile_ms(0.50), 0.25);
        // ...and the p99 target (ceil(0.99*100)=99) still sits there;
        // anything above it reaches the outlier's bucket (bound 1s).
        assert_eq!(h.quantile_ms(0.99), 0.25);
        assert_eq!(h.quantile_ms(1.0), 1000.0);
    }

    #[test]
    fn counters_conserve_and_quantiles_report() {
        let m = Metrics::new();
        let t = Instant::now();
        for _ in 0..9 {
            let idx = m.begin("submit");
            m.finish(idx, t, true);
        }
        let idx = m.begin("submit");
        m.finish(idx, t, false);
        let (req, ans, err) = m.totals();
        assert_eq!(req, 10);
        assert_eq!(ans + err, 10);
        assert_eq!(err, 1);
        let json = m.to_json();
        let submit = json.get("submit").expect("submit verb present");
        assert_eq!(submit.get("requests").unwrap().as_u64().unwrap(), 10);
        assert!(submit.get("p50_ms").unwrap().as_f64().is_ok());
        assert!(submit.get("p99_ms").unwrap().as_f64().is_ok());
    }

    #[test]
    fn bucket_index_is_monotonic() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(250), 0);
        assert_eq!(bucket_index(251), 1);
        assert_eq!(bucket_index(10_000_000), BUCKETS - 2);
        assert_eq!(bucket_index(10_000_001), BUCKETS - 1);
    }
}
