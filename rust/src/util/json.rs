//! Minimal JSON value model, parser and writer (the offline registry has
//! no `serde`/`serde_json`). Used by the result store and the `codr serve`
//! wire protocol.
//!
//! Integers and floats are kept apart: `Int` round-trips u64 counters
//! (cycles, access counts, bit totals) exactly, where a single f64 lane
//! would silently lose precision past 2^53. Floats are written with
//! Rust's shortest-roundtrip `Display`, so `f64 → text → f64` is the
//! identity for every finite value — the store relies on this for
//! byte-identical figure output from cached results.

use anyhow::{bail, Context, Result};
use std::fmt;

/// Maximum nesting depth accepted by the parser. Store files are ~5 deep;
/// the limit only exists so hostile input on the serve socket cannot
/// overflow the stack.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Number written without `.`/`e` — exact for the full u64/i64 range.
    Int(i128),
    /// Number written with a fraction or exponent.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs (no hashing, stable output).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn u64(v: u64) -> Json {
        Json::Int(v as i128)
    }

    pub fn usize(v: usize) -> Json {
        Json::Int(v as i128)
    }

    pub fn f64(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the field name on absence.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing field `{key}`"))
    }

    /// Consume an object and extract one field *by value* (first match).
    /// The packed result store rewrites multi-megabyte group files on
    /// every save; moving the `entries` subtree out of the parse instead
    /// of cloning it keeps the read-modify-write cycle allocation-flat.
    pub fn take(self, key: &str) -> Result<Json> {
        match self {
            Json::Obj(pairs) => pairs
                .into_iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .with_context(|| format!("missing field `{key}`")),
            other => bail!("expected object with field `{key}`, got {other}"),
        }
    }

    /// Consume an array into its elements (by value, no clone).
    pub fn into_arr(self) -> Result<Vec<Json>> {
        match self {
            Json::Arr(xs) => Ok(xs),
            other => bail!("expected array, got {other}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Int(i) => Ok(*i as f64),
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Json::Int(i) => u64::try_from(*i).map_err(|_| anyhow::anyhow!("{i} out of u64 range")),
            other => bail!("expected integer, got {other}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_u32(&self) -> Result<u32> {
        u32::try_from(self.as_u64()?).context("out of u32 range")
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(xs) => Ok(xs),
            other => bail!("expected array, got {other}"),
        }
    }

    /// Render with two-space indentation. Committed artifacts (e.g.
    /// `BENCH_hotpath.json`) are diffed by humans; the wire and store
    /// formats stay compact via `Display`.
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    pad(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                    out.push_str(if i + 1 < xs.len() { ",\n" } else { "\n" });
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    pad(out, indent + 1);
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    out.push_str(if i + 1 < pairs.len() { ",\n" } else { "\n" });
                }
                pad(out, indent);
                out.push('}');
            }
            leaf => out.push_str(&leaf.to_string()),
        }
    }

    /// Parse one JSON document (trailing whitespace allowed, nothing else).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Int(i) => write!(f, "{i}"),
            Json::Num(n) => {
                if n.is_finite() {
                    // Shortest roundtrip; force a fraction marker so the
                    // value re-parses into the Num (not Int) lane.
                    let s = format!("{n}");
                    if s.contains(['.', 'e', 'E']) {
                        f.write_str(&s)
                    } else {
                        write!(f, "{s}.0")
                    }
                } else {
                    f.write_str("null")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(xs) => {
                f.write_str("[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(pairs) => {
                f.write_str("{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_fmt(format_args!("{c}"))?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected `{}` at byte {}, got `{}`",
                b as char,
                self.pos,
                self.peek().map(|c| c as char).unwrap_or('∅')
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH}");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut xs = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    xs.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => bail!("expected `,` or `]` at byte {}", self.pos),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut pairs = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    let v = self.value(depth + 1)?;
                    pairs.push((k, v));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(pairs));
                        }
                        _ => bail!("expected `,` or `}}` at byte {}", self.pos),
                    }
                }
            }
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => bail!("unexpected byte {} in JSON", self.pos),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut fractional = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    fractional = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let tok = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if fractional {
            let v: f64 = tok.parse().with_context(|| format!("bad number `{tok}`"))?;
            if !v.is_finite() {
                bail!("non-finite number `{tok}`");
            }
            Ok(Json::Num(v))
        } else {
            let v: i128 = tok.parse().with_context(|| format!("bad integer `{tok}`"))?;
            Ok(Json::Int(v))
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                bail!("unterminated string");
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: expect \uDC00..\uDFFF.
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate");
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code)
                                    .with_context(|| format!("invalid codepoint {code:#x}"))?,
                            );
                        }
                        other => bail!("unknown escape `\\{}`", other as char),
                    }
                }
                _ => {
                    // Re-decode from the byte position: strings are UTF-8.
                    let rest = std::str::from_utf8(&self.bytes[self.pos - 1..])
                        .context("invalid UTF-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8() - 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let tok = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .context("invalid \\u escape")?;
        self.pos += 4;
        u32::from_str_radix(tok, 16).context("invalid \\u escape")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip() {
        for text in ["null", "true", "false", "0", "-7", "42"] {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.to_string(), text);
        }
    }

    #[test]
    fn integers_are_exact_across_u64() {
        let big = u64::MAX - 3;
        let v = Json::parse(&big.to_string()).unwrap();
        assert_eq!(v.as_u64().unwrap(), big);
    }

    #[test]
    fn floats_roundtrip_exactly() {
        for x in [0.1, 1.0 / 3.0, 2.5e-7, 1.6e9, f64::MIN_POSITIVE] {
            let v = Json::f64(x);
            let back = Json::parse(&v.to_string()).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
    }

    #[test]
    fn float_lane_survives_whole_values() {
        // 2.0 must not re-parse as Int (which would change the encoding
        // on a second save).
        let v = Json::f64(2.0);
        assert_eq!(v.to_string(), "2.0");
        assert_eq!(Json::parse("2.0").unwrap(), Json::Num(2.0));
    }

    #[test]
    fn strings_escape_and_unescape() {
        let s = "a\"b\\c\nd\tπ\u{1}";
        let text = Json::str(s).to_string();
        assert_eq!(Json::parse(&text).unwrap().as_str().unwrap(), s);
        // Unicode escapes, including a surrogate pair.
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn nested_structures() {
        let text = r#" {"a": [1, 2.5, {"b": null}], "c": "x"} "#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn take_and_into_arr_move_subtrees() {
        let v = Json::parse(r#"{"entries":[{"a":1},{"a":2}],"version":2}"#).unwrap();
        let entries = v.clone().take("entries").unwrap().into_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[1].get("a").unwrap().as_u64().unwrap(), 2);
        assert!(v.clone().take("absent").is_err());
        assert!(Json::Null.take("x").is_err());
        assert!(Json::parse("3").unwrap().into_arr().is_err());
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\"}",
            "nul",
            "1 2",
            "\"unterminated",
            "01a",
            "{\"a\":1,}",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn depth_limit_blocks_stack_abuse() {
        let arrays = |n: usize| "[".repeat(n) + &"]".repeat(n);
        assert!(Json::parse(&arrays(100)).is_err());
        // Objects nest through the same guard as arrays.
        let objects = "{\"k\":".repeat(100) + "1" + &"}".repeat(100);
        assert!(Json::parse(&objects).is_err());
        // The boundary is exact: the root sits at depth 0 and the guard
        // rejects depth > MAX_DEPTH, so MAX_DEPTH + 1 nested containers
        // parse and one more does not.
        assert!(Json::parse(&arrays(MAX_DEPTH + 1)).is_ok());
        assert!(Json::parse(&arrays(MAX_DEPTH + 2)).is_err());
        // Burying the deep subtree inside a shallow wrapper must not
        // reset the count — depth is absolute, not per-container.
        let wrapped = format!("{{\"a\":{}}}", arrays(MAX_DEPTH + 1));
        assert!(Json::parse(&wrapped).is_err());
        // A rejected document reports the limit, not a parser crash.
        let err = Json::parse(&arrays(500)).unwrap_err().to_string();
        assert!(err.contains("nested deeper"), "{err}");
    }

    #[test]
    fn pretty_output_reparses_identically() {
        let v = Json::parse(r#"{"a":[1,2.5,{"b":null}],"c":"x","empty":[],"o":{}}"#).unwrap();
        let pretty = v.to_pretty_string();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        // Leaves render exactly as the compact form.
        assert_eq!(Json::f64(2.0).to_pretty_string(), "2.0");
        assert_eq!(Json::parse("[]").unwrap().to_pretty_string(), "[]");
    }

    #[test]
    fn non_finite_serializes_to_null() {
        assert_eq!(Json::f64(f64::NAN).to_string(), "null");
        assert_eq!(Json::f64(f64::INFINITY).to_string(), "null");
    }
}
