//! Fast non-cryptographic hashing for in-memory caches (the offline
//! registry has no `rustc-hash`/`fxhash`).
//!
//! * [`Fp128`] — a 128-bit content fingerprint built from two
//!   *independent* streams (a byte-wise FNV-1a and a word-wise Fx mixer)
//!   over the same bytes. The weight-vector memo keys on it: shard
//!   selection, map bucketing, and equality all reuse the one
//!   fingerprint computed when a vector is linearized, so the hot lookup
//!   path hashes each vector exactly once and never compares bytes
//!   (collisions between the two independent 64-bit streams are the only
//!   aliasing risk, caught by the memo's length guard + counted
//!   byte-verify fallback; a same-length double collision is ~2⁻¹²⁸ per
//!   pair and accepted).
//! * [`FxHasher`] — the rotate · xor · multiply word mixer rustc uses
//!   for its interning tables; weak against adversarial keys, fine for
//!   in-memory tables.
//! * [`fnv1a64`] — the *stable* companion: a published algorithm with
//!   fixed test vectors, safe to persist (store fingerprints,
//!   packed-entry checks, memo-snapshot checksums) and compare across
//!   processes and releases.

use std::hash::{BuildHasher, Hasher};

/// A 128-bit content fingerprint: `lo` is a byte-wise FNV-1a stream,
/// `hi` an Fx-style word mixer over the same bytes with the length
/// folded in. The two halves are computed by unrelated mixing functions,
/// so consumers can slice independent bit regions out of each half
/// (the memo uses `lo` for map bucketing and `hi` for shard/L1
/// selection) without correlating their indexes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct Fp128 {
    pub lo: u64,
    pub hi: u64,
}

impl Fp128 {
    /// Fingerprint a linearized weight vector in one pass.
    pub fn of_i8(bytes: &[i8]) -> Fp128 {
        // Stream 1: byte-wise FNV-1a (same constants as `fnv1a64`).
        let mut lo: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in bytes {
            lo ^= b as u8 as u64;
            lo = lo.wrapping_mul(0x0000_0100_0000_01b3);
        }
        // Stream 2: Fx word mixer over little-endian 8-byte windows,
        // zero-padded tail, with the length mixed first so zero-tailed
        // vectors of different lengths cannot alias in this half either.
        let mut hi = FxHasher::default();
        hi.add(bytes.len() as u64);
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            let mut w = [0u8; 8];
            for (d, &s) in w.iter_mut().zip(c) {
                *d = s as u8;
            }
            hi.add(u64::from_le_bytes(w));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut w = [0u8; 8];
            for (d, &s) in w.iter_mut().zip(rem) {
                *d = s as u8;
            }
            hi.add(u64::from_le_bytes(w));
        }
        Fp128 {
            lo,
            hi: hi.finish(),
        }
    }
}

/// 64-bit FNV-1a — stable, dependency-free content hash. Used for store
/// cache-key fingerprints, packed-entry integrity checks, and memo
/// snapshot checksums; never change the constants (on-disk data depends
/// on them).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style streaming hasher over 64-bit words.
#[derive(Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            // Zero-pad the tail; slice hashing already mixes the length,
            // so trailing-zero ambiguity cannot alias keys.
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `BuildHasher` plugging [`FxHasher`] into `std::collections::HashMap`.
#[derive(Clone, Copy, Default)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of(bytes: &[i8]) -> u64 {
        let mut h = FxBuildHasher.build_hasher();
        bytes.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_content_sensitive() {
        let a = [1i8, 2, 3, 0, -5, 7, 9, 11, 13];
        assert_eq!(hash_of(&a), hash_of(&a));
        let mut b = a;
        b[4] = -6;
        assert_ne!(hash_of(&a), hash_of(&b));
    }

    #[test]
    fn length_disambiguates_zero_tails() {
        // [1, 0] vs [1, 0, 0]: the slice-length prefix must keep these
        // distinct even though the padded tail words agree.
        assert_ne!(hash_of(&[1, 0]), hash_of(&[1, 0, 0]));
        assert_ne!(hash_of(&[]), hash_of(&[0]));
    }

    #[test]
    fn fnv_reference_vectors() {
        // Published FNV-1a 64 test vectors — pins the constants that the
        // on-disk store fingerprints and snapshot checksums depend on.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fp128_deterministic_and_content_sensitive() {
        let a = [3i8, 0, 1, 3, 0, 1, 1, 4, -7, 22, 0, 0, 5];
        assert_eq!(Fp128::of_i8(&a), Fp128::of_i8(&a));
        let mut b = a;
        b[9] = 23;
        let (fa, fb) = (Fp128::of_i8(&a), Fp128::of_i8(&b));
        // A single-byte flip must change *both* independent halves.
        assert_ne!(fa.lo, fb.lo);
        assert_ne!(fa.hi, fb.hi);
    }

    #[test]
    fn fp128_length_disambiguates_zero_tails() {
        // [1, 0] vs [1, 0, 0]: the padded tail words agree, so only the
        // length mixing keeps the halves distinct.
        let a = Fp128::of_i8(&[1, 0]);
        let b = Fp128::of_i8(&[1, 0, 0]);
        assert_ne!(a, b);
        assert_ne!(a.hi, b.hi, "length must be folded into the hi stream");
        let e = Fp128::of_i8(&[]);
        let z = Fp128::of_i8(&[0]);
        assert_ne!(e, z);
    }

    #[test]
    fn fp128_lo_is_exactly_fnv1a64() {
        // `of_i8` inlines the FNV-1a loop over i8 (avoiding a u8 copy on
        // the hot path); this pin catches any drift between that inline
        // copy and the canonical `fnv1a64`.
        for v in [
            vec![],
            vec![0i8],
            vec![1i8, -2, 3, 0, 127, -128, 9, 9, 9, -1, 64],
        ] {
            let bytes: Vec<u8> = v.iter().map(|&b| b as u8).collect();
            assert_eq!(Fp128::of_i8(&v).lo, fnv1a64(&bytes), "{v:?}");
        }
    }

    #[test]
    fn fp128_halves_are_independent_mixers() {
        // Distinct inputs whose FNV half collides would still differ in
        // the Fx half (and vice versa). We can't manufacture a real
        // collision here; instead check that the halves are not related
        // by any fixed mapping over a spread of inputs.
        let mut rels = std::collections::HashSet::new();
        for i in 0..64i8 {
            let f = Fp128::of_i8(&[i, -i, i ^ 3]);
            rels.insert(f.lo ^ f.hi);
            rels.insert(f.lo.wrapping_sub(f.hi));
        }
        assert!(rels.len() > 100, "halves look correlated: {}", rels.len());
    }

    #[test]
    fn works_as_hashmap_hasher() {
        let mut m: std::collections::HashMap<Box<[i8]>, u32, FxBuildHasher> =
            std::collections::HashMap::with_hasher(FxBuildHasher);
        m.insert(vec![3i8, 1, 4].into_boxed_slice(), 1);
        m.insert(vec![1i8, 5, 9].into_boxed_slice(), 2);
        let probe: &[i8] = &[3, 1, 4];
        assert_eq!(m.get(probe), Some(&1));
        let missing: &[i8] = &[3, 1, 5];
        assert_eq!(m.get(missing), None);
    }
}
