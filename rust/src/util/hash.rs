//! Fast non-cryptographic hashing for in-memory caches (the offline
//! registry has no `rustc-hash`/`fxhash`). The algorithm is the rotate ·
//! xor · multiply word mixer rustc uses for its interning tables — weak
//! against adversarial keys, which is fine here: the only user is the
//! weight-vector memo, whose keys are verified byte-for-byte by the map's
//! `Eq` on lookup, so a collision can never alias two different vectors.
//!
//! [`fnv1a64`] is the *stable* companion: unlike the Fx mixer it is a
//! published algorithm with fixed test vectors, so it is safe to persist
//! (store fingerprints, packed-entry checks, memo-snapshot checksums)
//! and compare across processes and releases.

use std::hash::{BuildHasher, Hasher};

/// 64-bit FNV-1a — stable, dependency-free content hash. Used for store
/// cache-key fingerprints, packed-entry integrity checks, and memo
/// snapshot checksums; never change the constants (on-disk data depends
/// on them).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style streaming hasher over 64-bit words.
#[derive(Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            // Zero-pad the tail; slice hashing already mixes the length,
            // so trailing-zero ambiguity cannot alias keys.
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `BuildHasher` plugging [`FxHasher`] into `std::collections::HashMap`.
#[derive(Clone, Copy, Default)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of(bytes: &[i8]) -> u64 {
        let mut h = FxBuildHasher.build_hasher();
        bytes.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_content_sensitive() {
        let a = [1i8, 2, 3, 0, -5, 7, 9, 11, 13];
        assert_eq!(hash_of(&a), hash_of(&a));
        let mut b = a;
        b[4] = -6;
        assert_ne!(hash_of(&a), hash_of(&b));
    }

    #[test]
    fn length_disambiguates_zero_tails() {
        // [1, 0] vs [1, 0, 0]: the slice-length prefix must keep these
        // distinct even though the padded tail words agree.
        assert_ne!(hash_of(&[1, 0]), hash_of(&[1, 0, 0]));
        assert_ne!(hash_of(&[]), hash_of(&[0]));
    }

    #[test]
    fn fnv_reference_vectors() {
        // Published FNV-1a 64 test vectors — pins the constants that the
        // on-disk store fingerprints and snapshot checksums depend on.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn works_as_hashmap_hasher() {
        let mut m: std::collections::HashMap<Box<[i8]>, u32, FxBuildHasher> =
            std::collections::HashMap::with_hasher(FxBuildHasher);
        m.insert(vec![3i8, 1, 4].into_boxed_slice(), 1);
        m.insert(vec![1i8, 5, 9].into_boxed_slice(), 2);
        let probe: &[i8] = &[3, 1, 4];
        assert_eq!(m.get(probe), Some(&1));
        let missing: &[i8] = &[3, 1, 5];
        assert_eq!(m.get(missing), None);
    }
}
