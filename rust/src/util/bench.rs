//! In-tree micro/macro benchmark harness (the offline registry has no
//! `criterion`). Used by every target under `benches/` via
//! `[[bench]] harness = false`.
//!
//! Measures wall-clock over repeated runs with warmup, reports
//! min / median / mean / p95 and a robust MAD-based noise estimate, and
//! renders the one-line summary format the benches print for
//! EXPERIMENTS.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Process-wide per-phase wall-time accumulators for the simulation hot
/// path, summed across threads (CPU-time style: two threads extracting
/// for 1 ms each record 2 ms). The phases are recorded at layer / miss
/// granularity, so the clock costs a handful of `Instant` reads per
/// layer simulation — negligible against the work it measures.
///
/// `transform` is a *subset* of `extract`: vector transforms happen
/// inside the extraction loop on memo misses, and both spans record
/// them. `codr bench` v2 reports all three so a regression is
/// attributable — lookup-bound (extract up, transform flat),
/// transform-bound (both up), or pricing-bound (price up).
#[derive(Debug, Default)]
pub struct PhaseClock {
    /// Linearize + fingerprint + memo lookup loops (includes transform).
    extract_ns: AtomicU64,
    /// Inside `UcrVector` transforms on memo misses (⊂ extract).
    transform_ns: AtomicU64,
    /// Parameter search, histogram pricing, and the dataflow loop nest.
    price_ns: AtomicU64,
}

/// One point-in-time reading of the [`PhaseClock`] (cumulative nanos).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseSnapshot {
    pub extract_ns: u64,
    pub transform_ns: u64,
    pub price_ns: u64,
}

impl PhaseSnapshot {
    /// Nanos accumulated since an `earlier` snapshot.
    pub fn since(&self, earlier: &PhaseSnapshot) -> PhaseSnapshot {
        PhaseSnapshot {
            extract_ns: self.extract_ns - earlier.extract_ns,
            transform_ns: self.transform_ns - earlier.transform_ns,
            price_ns: self.price_ns - earlier.price_ns,
        }
    }
}

impl PhaseClock {
    pub fn add_extract(&self, d: Duration) {
        self.extract_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_transform(&self, d: Duration) {
        self.transform_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_price(&self, d: Duration) {
        self.price_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> PhaseSnapshot {
        PhaseSnapshot {
            extract_ns: self.extract_ns.load(Ordering::Relaxed),
            transform_ns: self.transform_ns.load(Ordering::Relaxed),
            price_ns: self.price_ns.load(Ordering::Relaxed),
        }
    }
}

/// The process-wide phase clock every simulator path records into.
pub fn phases() -> &'static PhaseClock {
    static CLOCK: OnceLock<PhaseClock> = OnceLock::new();
    CLOCK.get_or_init(PhaseClock::default)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchStats {
    fn sorted_nanos(&self) -> Vec<u128> {
        let mut v: Vec<u128> = self.samples.iter().map(|d| d.as_nanos()).collect();
        v.sort_unstable();
        v
    }

    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }

    pub fn median(&self) -> Duration {
        let s = self.sorted_nanos();
        Duration::from_nanos(s[s.len() / 2] as u64)
    }

    pub fn mean(&self) -> Duration {
        let total: u128 = self.samples.iter().map(|d| d.as_nanos()).sum();
        Duration::from_nanos((total / self.samples.len() as u128) as u64)
    }

    pub fn p95(&self) -> Duration {
        let s = self.sorted_nanos();
        Duration::from_nanos(s[((s.len() * 95) / 100).min(s.len() - 1)] as u64)
    }

    /// Median absolute deviation, as a fraction of the median — a robust
    /// "noise" figure (0.02 = ±2%).
    pub fn noise(&self) -> f64 {
        let s = self.sorted_nanos();
        let med = s[s.len() / 2] as i128;
        let mut dev: Vec<i128> = s.iter().map(|&x| (x as i128 - med).abs()).collect();
        dev.sort_unstable();
        let mad = dev[dev.len() / 2] as f64;
        if med == 0 {
            0.0
        } else {
            mad / med as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} median {:>12?}  mean {:>12?}  min {:>12?}  noise ±{:.1}%  (n={})",
            self.name,
            self.median(),
            self.mean(),
            self.min(),
            self.noise() * 100.0,
            self.samples.len()
        )
    }
}

/// Benchmark runner with warmup and a time budget.
pub struct Bencher {
    /// Minimum number of measured iterations.
    pub min_iters: usize,
    /// Maximum number of measured iterations.
    pub max_iters: usize,
    /// Stop sampling after roughly this much measured time.
    pub budget: Duration,
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_iters: 10,
            max_iters: 200,
            budget: Duration::from_secs(3),
            warmup: 2,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick configuration for heavyweight end-to-end benches.
    pub fn heavy() -> Self {
        Bencher {
            min_iters: 3,
            max_iters: 20,
            budget: Duration::from_secs(10),
            warmup: 1,
            results: Vec::new(),
        }
    }

    /// Fully custom configuration.
    pub fn with(min_iters: usize, max_iters: usize, budget: Duration, warmup: usize) -> Self {
        Bencher {
            min_iters,
            max_iters,
            budget,
            warmup,
            results: Vec::new(),
        }
    }

    /// Time `f`, preventing the compiler from discarding its result via
    /// `std::hint::black_box`.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let stats = BenchStats {
            name: name.to_string(),
            samples,
        };
        println!("{}", stats.summary());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results recorded so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Render a closing table (printed by each bench binary's footer).
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        for r in &self.results {
            println!("  {}", r.summary());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_clock_accumulates_and_deltas() {
        let c = PhaseClock::default();
        let s0 = c.snapshot();
        c.add_extract(Duration::from_micros(5));
        c.add_extract(Duration::from_micros(7));
        c.add_transform(Duration::from_micros(3));
        c.add_price(Duration::from_micros(11));
        let d = c.snapshot().since(&s0);
        assert_eq!(d.extract_ns, 12_000);
        assert_eq!(d.transform_ns, 3_000);
        assert_eq!(d.price_ns, 11_000);
        // The global clock is a singleton and always usable.
        let g0 = phases().snapshot();
        phases().add_price(Duration::from_nanos(1));
        assert!(phases().snapshot().price_ns > g0.price_ns);
    }

    #[test]
    fn records_samples_and_stats() {
        let mut b = Bencher {
            min_iters: 5,
            max_iters: 5,
            budget: Duration::from_millis(100),
            warmup: 1,
            results: Vec::new(),
        };
        let s = b.bench("noop", || 1 + 1).clone();
        assert_eq!(s.samples.len(), 5);
        assert!(s.min() <= s.median());
        assert!(s.median() <= s.p95().max(s.median()));
    }

    #[test]
    fn mean_of_constant_workload_is_positive() {
        let mut b = Bencher {
            min_iters: 3,
            max_iters: 3,
            budget: Duration::from_millis(50),
            warmup: 0,
            results: Vec::new(),
        };
        let s = b
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..10_000 {
                    acc = acc.wrapping_add(i);
                }
                acc
            })
            .clone();
        assert!(s.mean() > Duration::ZERO);
    }
}
