//! Minimal property-based testing support (the offline registry has no
//! `proptest`), used by the unit tests across the crate.
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` random inputs produced
//! by `gen`. On failure it re-runs the generator deterministically to
//! report the failing seed so the case can be replayed, and performs a
//! simple halving "shrink" over the generator's size hint when the
//! generator supports it (via [`Sized`]-style closures taking a budget).

use super::rng::Rng;

/// Run `prop` against `cases` random values from `gen`.
///
/// `gen` receives an [`Rng`] plus a *size budget* in `[1, 100]` that grows
/// over the run, so early cases are small (easy to debug) and later cases
/// stress larger structures. Panics with the failing seed on the first
/// counterexample.
pub fn check<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    check_seeded(0xC0D2_2024, cases, &mut gen, &mut prop);
}

/// Like [`check`] but with an explicit base seed (for replaying failures).
pub fn check_seeded<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: &mut impl FnMut(&mut Rng, usize) -> T,
    prop: &mut impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        // Budget ramps 1..=100 over the run.
        let size = 1 + (case * 100) / cases.max(1);
        let value = gen(&mut rng, size);
        if !prop(&value) {
            // Try to find a smaller failing budget for a friendlier report.
            let mut best: Option<(usize, T)> = None;
            let mut lo = 1usize;
            let mut hi = size;
            while lo < hi {
                let mid = (lo + hi) / 2;
                let mut r2 = Rng::new(case_seed);
                let v2 = gen(&mut r2, mid);
                if !prop(&v2) {
                    hi = mid;
                    best = Some((mid, v2));
                } else {
                    lo = mid + 1;
                }
            }
            let (fsize, fval) = best.map(|(s, v)| (s, format!("{v:?}"))).unwrap_or((
                size,
                format!("{value:?}"),
            ));
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, size {fsize}):\n{fval}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |r, size| r.index(size.max(1)), |&v| v < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_counterexample() {
        check(50, |r, size| r.index(size.max(1)), |&v| v < 3);
    }

    #[test]
    fn size_budget_ramps() {
        let mut max_seen = 0usize;
        check(
            100,
            |_, size| size,
            |&s| {
                max_seen = max_seen.max(s);
                s <= 100
            },
        );
        assert!(max_seen >= 99);
    }
}
