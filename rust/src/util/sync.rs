//! Poison-tolerant mutex helpers.
//!
//! The serve stack isolates per-point panics (`coordinator::pool`
//! catches them and reports `state:"partial"`), so a panicked worker
//! must not poison-cascade every later request into its own panic.
//! These helpers recover the guard from a [`PoisonError`] — the data a
//! panicking holder left behind is either a statistic, an idempotent
//! map entry, or re-validated by the caller, so continuing is always
//! safer here than propagating the panic. They are also what keeps the
//! `panic_policy` analyzer check honest: request paths call
//! `sync::lock(&m)` instead of sprinkling `.lock().unwrap()`.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering from poison.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv`, recovering from poison.
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Consume a mutex, recovering from poison.
pub fn into_inner<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(PoisonError::into_inner)
}

/// Borrow the contents of an exclusively-held mutex, recovering from
/// poison.
pub fn get_mut<T>(m: &mut Mutex<T>) -> &mut T {
    m.get_mut().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_recovers_from_poison() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        let mut owned = Mutex::new(1u32);
        *get_mut(&mut owned) = 2;
        assert_eq!(into_inner(owned), 2);
    }

    #[test]
    fn wait_passes_through() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = lock(&m);
        let (g, timed_out) = cv
            .wait_timeout(g, std::time::Duration::from_millis(1))
            .unwrap_or_else(PoisonError::into_inner);
        assert!(timed_out.timed_out());
        drop(g);
    }
}
