//! In-tree utilities that replace crates unavailable in the offline
//! registry: deterministic RNG (`rand`), property testing (`proptest`),
//! and a benchmark harness (`criterion`).

pub mod bench;
pub mod check;
pub mod rng;
