//! In-tree utilities that replace crates unavailable in the offline
//! registry: deterministic RNG (`rand`), property testing (`proptest`),
//! a benchmark harness (`criterion`), and JSON (`serde_json`).

pub mod bench;
pub mod check;
pub mod hash;
pub mod json;
pub mod rng;
pub mod sync;
