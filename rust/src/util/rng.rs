//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so we carry a small,
//! well-understood generator in-tree: xoshiro256** (Blackman & Vigna),
//! seeded through SplitMix64. Every experiment in this repository is
//! seeded, so figures regenerate bit-identically run to run.

/// SplitMix64 — used to expand a single `u64` seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Not cryptographic; excellent statistical quality for
/// simulation workloads and trivially reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named sub-experiment. Mixing the
    /// label through FNV-1a keeps layer/model streams decorrelated.
    pub fn fork(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Rng::new(h ^ self.s[0].rotate_left(17) ^ self.s[2])
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (polar rejection avoided for
    /// simplicity; the tails here are plenty for weight synthesis).
    pub fn normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices out of `n` (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_is_decorrelated_and_stable() {
        let r = Rng::new(7);
        let mut x = r.fork("alexnet/conv1");
        let mut y = r.fork("alexnet/conv2");
        let mut x2 = r.fork("alexnet/conv1");
        assert_eq!(x.next_u64(), x2.next_u64());
        assert_ne!(x.next_u64(), y.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.choose_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }
}
