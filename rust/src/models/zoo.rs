//! Layer tables for the three paper benchmarks.
//!
//! Shapes follow the original publications (AlexNet [7], VGG16-D [13],
//! GoogleNet/Inception-v1 [14]); grouping in AlexNet conv2/4/5 is ignored
//! (as is conventional in accelerator studies — it does not change weight
//! statistics). σ_q / zero_frac calibrations per DESIGN.md reproduce the
//! Fig 2 per-model sparsity and repetition profiles; VGG16's deep 3×3
//! layers are the sparsest (the paper notes VGG16 sparsity "can reach
//! 94%"), GoogleNet's weight distribution is the most concentrated
//! (highest repetition: Δ=0 up to 39% of non-zeros).

use super::{LayerKind, LayerSpec, Model};

fn conv(
    name: String,
    n: usize,
    m: usize,
    r_i: usize,
    r_k: usize,
    stride: usize,
    pad: usize,
    sigma_q: f64,
    zero_frac: f64,
) -> LayerSpec {
    LayerSpec {
        name,
        kind: LayerKind::Conv,
        n,
        m,
        r_i,
        r_k,
        stride,
        pad,
        groups: 1,
        sigma_q,
        zero_frac,
    }
}

/// Grouped convolution: `g` independent filter banks of `n/g → m/g`
/// channels each (`g = n = m` is depthwise).
#[allow(clippy::too_many_arguments)]
fn gconv(
    name: String,
    n: usize,
    m: usize,
    g: usize,
    r_i: usize,
    r_k: usize,
    stride: usize,
    pad: usize,
    sigma_q: f64,
    zero_frac: f64,
) -> LayerSpec {
    assert!(g >= 1 && n % g == 0 && m % g == 0, "groups must divide N and M");
    LayerSpec {
        groups: g,
        ..conv(name, n, m, r_i, r_k, stride, pad, sigma_q, zero_frac)
    }
}

fn fc(name: String, n: usize, m: usize, sigma_q: f64, zero_frac: f64) -> LayerSpec {
    LayerSpec {
        name,
        kind: LayerKind::FullyConnected,
        n,
        m,
        r_i: 1,
        r_k: 1,
        stride: 1,
        pad: 0,
        groups: 1,
        sigma_q,
        zero_frac,
    }
}

/// AlexNet [7]: 5 conv + 3 FC. Average 8-bit sparsity calibrated ≈ 0.50
/// with moderate weight spread.
pub fn alexnet() -> Model {
    let s = 10.0; // σ_q (concentrated, high-kurtosis quantized weights)
    Model {
        name: "alexnet",
        layers: vec![
            conv("conv1".into(), 3, 96, 227, 11, 4, 0, s, 0.45),
            conv("conv2".into(), 96, 256, 27, 5, 1, 2, s, 0.60),
            conv("conv3".into(), 256, 384, 13, 3, 1, 1, s, 0.62),
            conv("conv4".into(), 384, 384, 13, 3, 1, 1, s, 0.65),
            conv("conv5".into(), 384, 256, 13, 3, 1, 1, s, 0.65),
            fc("fc6".into(), 9216, 4096, s, 0.64),
            fc("fc7".into(), 4096, 4096, s, 0.64),
            fc("fc8".into(), 4096, 1000, s, 0.50),
        ],
    }
}

/// VGG16 configuration D [13]: 13 conv (all 3×3, pad 1) + 3 FC.
/// The deepest layers are the sparsest — per-layer zero_frac ramps toward
/// the paper's "can reach 94%".
pub fn vgg16() -> Model {
    let s = 10.0;
    let cfg: &[(usize, usize, usize, f64)] = &[
        // (n, m, r_i, zero_frac)
        (3, 64, 224, 0.42),
        (64, 64, 224, 0.55),
        (64, 128, 112, 0.60),
        (128, 128, 112, 0.66),
        (128, 256, 56, 0.70),
        (256, 256, 56, 0.74),
        (256, 256, 56, 0.76),
        (256, 512, 28, 0.80),
        (512, 512, 28, 0.84),
        (512, 512, 28, 0.86),
        (512, 512, 14, 0.90),
        (512, 512, 14, 0.92),
        (512, 512, 14, 0.94),
    ];
    let mut layers: Vec<LayerSpec> = cfg
        .iter()
        .enumerate()
        .map(|(i, &(n, m, r_i, z))| conv(format!("conv{}", i + 1), n, m, r_i, 3, 1, 1, s, z))
        .collect();
    layers.push(fc("fc14".into(), 25088, 4096, s, 0.90));
    layers.push(fc("fc15".into(), 4096, 4096, s, 0.90));
    layers.push(fc("fc16".into(), 4096, 1000, s, 0.75));
    Model {
        name: "vgg16",
        layers,
    }
}

/// One GoogleNet inception module: 1×1, 3×3-reduce, 3×3, 5×5-reduce, 5×5,
/// pool-proj (1×1).
#[allow(clippy::too_many_arguments)]
fn inception(
    name: &str,
    r_i: usize,
    n_in: usize,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    pp: usize,
    sigma: f64,
    zero: f64,
) -> Vec<LayerSpec> {
    vec![
        conv(format!("{name}/1x1"), n_in, c1, r_i, 1, 1, 0, sigma, zero),
        conv(format!("{name}/3x3r"), n_in, c3r, r_i, 1, 1, 0, sigma, zero),
        conv(format!("{name}/3x3"), c3r, c3, r_i, 3, 1, 1, sigma, zero),
        conv(format!("{name}/5x5r"), n_in, c5r, r_i, 1, 1, 0, sigma, zero),
        conv(format!("{name}/5x5"), c5r, c5, r_i, 5, 1, 2, sigma, zero),
        conv(format!("{name}/pool_proj"), n_in, pp, r_i, 1, 1, 0, sigma, zero),
    ]
}

/// GoogleNet / Inception-v1 [14]: 3 stem convs + 9 inception modules
/// (57 conv layers) + classifier FC. σ_q is small — GoogleNet's quantized
/// weight distribution is concentrated, which is what gives it the
/// paper's highest repetition (Δ=0 ≈ 39% of non-zeros in Fig 2).
pub fn googlenet() -> Model {
    let s = 1.5;
    let z = 0.55;
    let mut layers = vec![
        conv("conv1/7x7".into(), 3, 64, 224, 7, 2, 3, s, 0.45),
        conv("conv2/3x3r".into(), 64, 64, 56, 1, 1, 0, s, 0.50),
        conv("conv2/3x3".into(), 64, 192, 56, 3, 1, 1, s, 0.52),
    ];
    // (name, r_i, in, 1x1, 3x3r, 3x3, 5x5r, 5x5, pool_proj)
    let modules: &[(&str, usize, usize, usize, usize, usize, usize, usize, usize)] = &[
        ("inception_3a", 28, 192, 64, 96, 128, 16, 32, 32),
        ("inception_3b", 28, 256, 128, 128, 192, 32, 96, 64),
        ("inception_4a", 14, 480, 192, 96, 208, 16, 48, 64),
        ("inception_4b", 14, 512, 160, 112, 224, 24, 64, 64),
        ("inception_4c", 14, 512, 128, 128, 256, 24, 64, 64),
        ("inception_4d", 14, 512, 112, 144, 288, 32, 64, 64),
        ("inception_4e", 14, 528, 256, 160, 320, 32, 128, 128),
        ("inception_5a", 7, 832, 256, 160, 320, 32, 128, 128),
        ("inception_5b", 7, 832, 384, 192, 384, 48, 128, 128),
    ];
    for &(name, r_i, n_in, c1, c3r, c3, c5r, c5, pp) in modules {
        layers.extend(inception(name, r_i, n_in, c1, c3r, c3, c5r, c5, pp, s, z));
    }
    layers.push(fc("fc".into(), 1024, 1000, s, 0.55));
    Model {
        name: "googlenet",
        layers,
    }
}

/// A small post-AlexNet-era block (MobileNet-style): a dense stem, a
/// depthwise 3×3, its pointwise expansion, and a 4-way grouped 3×3.
/// Not part of the paper's evaluation grid — it exists so the mapping
/// search and the group-boundary legality checks see depthwise and
/// grouped shapes (the paper models are all dense).
pub fn mobile() -> Model {
    let s = 8.0;
    Model {
        name: "mobile",
        layers: vec![
            conv("conv1".into(), 3, 32, 32, 3, 2, 1, s, 0.45),
            gconv("dw2".into(), 32, 32, 32, 16, 3, 1, 1, s, 0.55),
            conv("pw2".into(), 32, 64, 16, 1, 1, 0, s, 0.60),
            gconv("g3".into(), 64, 64, 4, 16, 3, 1, 1, s, 0.60),
        ],
    }
}

/// All three paper benchmarks.
pub fn all_models() -> Vec<Model> {
    vec![alexnet(), vgg16(), googlenet()]
}

/// Look a model up by (case-insensitive) name.
pub fn model_by_name(name: &str) -> Option<Model> {
    match name.to_ascii_lowercase().as_str() {
        "alexnet" => Some(alexnet()),
        "vgg16" | "vgg" => Some(vgg16()),
        "googlenet" | "inception" => Some(googlenet()),
        "mobile" => Some(mobile()),
        _ => None,
    }
}

/// A deliberately small synthetic network for tests, examples, and the
/// end-to-end golden check against the XLA artifacts.
pub fn tiny_cnn() -> Model {
    Model {
        name: "tiny",
        layers: vec![
            conv("conv1".into(), 4, 8, 16, 3, 1, 1, 6.0, 0.50),
            conv("conv2".into(), 8, 16, 8, 3, 1, 1, 6.0, 0.60),
            fc("fc".into(), 16 * 4 * 4, 10, 6.0, 0.5),
        ],
    }
}
