//! CNN model zoo and synthetic weight synthesis (paper §V-A).
//!
//! The paper evaluates AlexNet [7], VGG16 [13], and GoogleNet [14]
//! quantized to 8-bit fixed point. Pretrained ImageNet weights are not
//! available in this environment, so we synthesize weights per layer from
//! a seeded, zero-inflated discretized Gaussian calibrated to the paper's
//! Fig 2 statistics (per-model sparsity and Δ-distribution); see
//! DESIGN.md "Weight statistics calibration". Every figure in the paper is
//! a function of these *statistics* — density, repetition, Δ magnitudes —
//! not of the specific weight values.

mod zoo;

pub use zoo::{alexnet, all_models, googlenet, mobile, model_by_name, tiny_cnn, vgg16};

use crate::quant;
use crate::tensor::{Tensor, Weights};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

/// Look up one model by name — the zoo plus the `tiny` test CNN.
pub fn parse_model(name: &str) -> Result<Model> {
    let name = name.trim();
    model_by_name(name)
        .or_else(|| (name == "tiny").then(tiny_cnn))
        .with_context(|| {
            format!("unknown model `{name}` (alexnet | vgg16 | googlenet | mobile | tiny)")
        })
}

/// Parse a comma-separated model list.
pub fn parse_model_list(spec: &str) -> Result<Vec<Model>> {
    spec.split(',').map(parse_model).collect()
}

/// Parse a comma-separated sweep-group list: `U=16,Orig,D=50%`.
pub fn parse_group_list(spec: &str) -> Result<Vec<SweepGroup>> {
    spec.split(',')
        .map(|g| {
            let g = g.trim();
            if g.eq_ignore_ascii_case("orig") {
                Ok(SweepGroup::Original)
            } else if let Some(u) = g.strip_prefix("U=") {
                Ok(SweepGroup::Unique(u.parse().context("bad U group")?))
            } else if let Some(d) = g.strip_prefix("D=") {
                let d = d.trim_end_matches('%');
                Ok(SweepGroup::Density(d.parse().context("bad D group")?))
            } else {
                bail!("unknown group `{g}` (use U=16 / Orig / D=50%)")
            }
        })
        .collect()
}

/// Kind of layer (the accelerators evaluate convolutional layers;
/// FC layers are kept for the end-to-end functional model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    FullyConnected,
}

/// Static description of one layer.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: String,
    pub kind: LayerKind,
    /// Input channels (N).
    pub n: usize,
    /// Output channels (M).
    pub m: usize,
    /// Input feature map spatial size (R_I = C_I; all paper models are square).
    pub r_i: usize,
    /// Kernel spatial size (R_K = C_K).
    pub r_k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Convolution groups (1 = dense conv; `n` = depthwise). Each group
    /// connects `n/groups` input channels to `m/groups` output channels,
    /// so the weight tensor is `[m, n/groups, r_k, r_k]` and channels
    /// never mix across a group boundary.
    pub groups: usize,
    /// Gaussian σ of non-zero weights in quantized (int8) units.
    pub sigma_q: f64,
    /// Probability that a weight is exactly zero (sparsity calibration).
    pub zero_frac: f64,
}

impl LayerSpec {
    /// Output feature map size (square).
    pub fn r_o(&self) -> usize {
        (self.r_i + 2 * self.pad - self.r_k) / self.stride + 1
    }

    /// Input channels seen by one group's filters.
    pub fn n_per_group(&self) -> usize {
        self.n / self.groups.max(1)
    }

    /// Output channels produced by one group.
    pub fn m_per_group(&self) -> usize {
        self.m / self.groups.max(1)
    }

    /// Number of weights in this layer (grouping shrinks the filter depth).
    pub fn num_weights(&self) -> usize {
        self.m * self.n_per_group() * self.r_k * self.r_k
    }

    /// Number of multiply-accumulates in a dense direct convolution.
    pub fn macs(&self) -> u64 {
        (self.num_weights() as u64) * (self.r_o() as u64) * (self.r_o() as u64)
    }

    /// Input feature count.
    pub fn input_features(&self) -> usize {
        self.n * self.r_i * self.r_i
    }

    /// Output feature count.
    pub fn output_features(&self) -> usize {
        self.m * self.r_o() * self.r_o()
    }
}

/// A named network: an ordered list of conv layers (the unit of the
/// paper's evaluation) plus metadata.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: &'static str,
    pub layers: Vec<LayerSpec>,
}

impl Model {
    pub fn conv_layers(&self) -> impl Iterator<Item = &LayerSpec> {
        self.layers.iter().filter(|l| l.kind == LayerKind::Conv)
    }

    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.num_weights()).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }
}

/// Per-layer weight sampler: zero-inflated discretized Gaussian drawn via
/// an inverse-CDF table (one table per layer, two u64 draws per weight —
/// much faster than per-weight Box–Muller over a 15 M-weight VGG16, see
/// EXPERIMENTS.md §Perf; the non-zero value distribution is the
/// renormalized discrete Gaussian, identical in law to rejection
/// sampling).
pub struct WeightSampler {
    zero_frac: f64,
    /// Cumulative probabilities over the 254 non-zero values −127..=127
    /// (zero excluded), scaled to u64.
    cdf: Vec<u64>,
}

impl WeightSampler {
    pub fn new(zero_frac: f64, sigma_q: f64) -> Self {
        // Discrete Gaussian mass per non-zero value v: the probability
        // that N(0, σ) rounds to v, i.e. Φ((v+½)/σ) − Φ((v−½)/σ), with the
        // tails folded into ±127.
        let phi = |x: f64| 0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2));
        let mut mass = Vec::with_capacity(254);
        let mut total = 0.0;
        for v in (-127i32..=127).filter(|&v| v != 0) {
            let lo = (v as f64 - 0.5) / sigma_q;
            let hi = (v as f64 + 0.5) / sigma_q;
            let p = if v == -127 {
                phi(hi)
            } else if v == 127 {
                1.0 - phi(lo)
            } else {
                (phi(hi) - phi(lo)).max(0.0)
            };
            total += p;
            mass.push(p);
        }
        if total <= 0.0 {
            // Degenerate σ: fall back to ±1 uniformly.
            mass.fill(0.0);
            mass[126] = 0.5; // v = −1
            mass[127] = 0.5; // v = +1
            total = 1.0;
        }
        let mut cdf = Vec::with_capacity(254);
        let mut acc = 0.0;
        for p in &mass {
            acc += p / total;
            cdf.push((acc * u64::MAX as f64) as u64);
        }
        *cdf.last_mut().unwrap() = u64::MAX;
        WeightSampler { zero_frac, cdf }
    }

    /// Draw one quantized weight.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> i8 {
        if rng.chance(self.zero_frac) {
            return 0;
        }
        let r = rng.next_u64();
        let idx = self.cdf.partition_point(|&c| c < r);
        // Index → value: 0..=126 ↦ −127..=−1, 127..=253 ↦ 1..=127.
        let v = idx as i32 - 127;
        (if v >= 0 { v + 1 } else { v }) as i8
    }
}

/// Abramowitz–Stegun 7.1.26 rational approximation of erf
/// (|ε| < 1.5e−7 — far below the weight-statistic tolerances).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Synthesize the quantized 8-bit weights of one layer.
///
/// Zero-inflated discretized Gaussian: with probability `zero_frac` a
/// weight is 0; otherwise a non-zero value distributed as
/// `round(N(0, σ_q))` conditioned on being non-zero, tails clamped to ±127.
pub fn synthesize_weights(spec: &LayerSpec, rng: &mut Rng) -> Weights {
    let sampler = WeightSampler::new(spec.zero_frac, spec.sigma_q);
    let shape = [spec.m, spec.n_per_group(), spec.r_k, spec.r_k];
    Tensor::from_fn(&shape, |_| sampler.sample(rng))
}

/// Synthesize a layer's synthetic input activations (u8). Activation
/// values never affect any reported metric (features are stored raw in all
/// three designs) but are needed for functional verification.
pub fn synthesize_activations(spec: &LayerSpec, rng: &mut Rng) -> Tensor<u8> {
    Tensor::from_fn(&[spec.n, spec.r_i, spec.r_i], |_| rng.below(256) as u8)
}

/// A fully materialized evaluation workload: a model with synthesized
/// weights, after applying the paper's (U, D) sweep knobs.
pub struct Workload {
    pub model: Model,
    pub weights: Vec<Weights>,
    /// The knobs this workload was generated with.
    pub unique: Option<u32>,
    pub density: Option<f64>,
}

impl Workload {
    /// Build the workload for `model` at the given sweep point.
    ///
    /// Seeding: every layer forks an independent stream from
    /// `(seed, model, layer-name)` so sweep points differ only by the
    /// knobs, never by base weight draws.
    pub fn generate(model: &Model, unique: Option<u32>, density: Option<f64>, seed: u64) -> Self {
        let root = Rng::new(seed).fork(model.name);
        let mut weights = Vec::with_capacity(model.layers.len());
        for layer in &model.layers {
            let mut rng = root.fork(&layer.name);
            let mut w = synthesize_weights(layer, &mut rng);
            quant::apply_knobs(&mut w, unique, density, &mut rng);
            weights.push(w);
        }
        Workload {
            model: model.clone(),
            weights,
            unique,
            density,
        }
    }

    /// Convolutional (layer, weights) pairs — the unit of the evaluation.
    pub fn conv_layers(&self) -> impl Iterator<Item = (&LayerSpec, &Weights)> {
        self.model
            .layers
            .iter()
            .zip(&self.weights)
            .filter(|(l, _)| l.kind == LayerKind::Conv)
    }
}

/// The paper's sweep groups (x-axis groups of Figs 6–8): middle = original
/// model, right side = density degradation, left side = unique-weight
/// limitation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepGroup {
    /// Limit unique weights to U (left-side groups: 16, 64).
    Unique(u32),
    /// Original weights (middle group).
    Original,
    /// Degrade density to D% of original non-zeros (right groups: 75, 50, 25).
    Density(u32),
}

impl SweepGroup {
    /// The seven groups of the paper's figures, left to right.
    pub fn all() -> Vec<SweepGroup> {
        vec![
            SweepGroup::Unique(16),
            SweepGroup::Unique(64),
            SweepGroup::Original,
            SweepGroup::Density(75),
            SweepGroup::Density(50),
            SweepGroup::Density(25),
        ]
    }

    pub fn label(&self) -> String {
        match self {
            SweepGroup::Unique(u) => format!("U={u}"),
            SweepGroup::Original => "Orig".to_string(),
            SweepGroup::Density(d) => format!("D={d}%"),
        }
    }

    pub fn knobs(&self) -> (Option<u32>, Option<f64>) {
        match self {
            SweepGroup::Unique(u) => (Some(*u), None),
            SweepGroup::Original => (None, None),
            SweepGroup::Density(d) => (None, Some(*d as f64 / 100.0)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{density, unique_nonzero};

    #[test]
    fn alexnet_shapes() {
        let m = alexnet();
        let conv1 = &m.layers[0];
        assert_eq!(conv1.n, 3);
        assert_eq!(conv1.m, 96);
        assert_eq!(conv1.r_o(), 55);
        // Total conv weights ≈ 3.7 M (grouping ignored; with AlexNet's
        // original 2-way grouping in conv2/4/5 it would be ≈2.3 M).
        let w: usize = m.conv_layers().map(|l| l.num_weights()).sum();
        assert!((3_400_000..4_000_000).contains(&w), "alexnet conv weights {w}");
    }

    #[test]
    fn vgg16_shapes() {
        let m = vgg16();
        assert_eq!(m.conv_layers().count(), 13);
        let w: usize = m.conv_layers().map(|l| l.num_weights()).sum();
        // ≈14.7 M conv weights.
        assert!((14_000_000..15_500_000).contains(&w), "vgg16 conv weights {w}");
        for l in m.conv_layers() {
            assert_eq!(l.r_k, 3);
            assert_eq!(l.pad, 1);
            assert_eq!(l.r_o(), l.r_i);
        }
    }

    #[test]
    fn googlenet_shapes() {
        let m = googlenet();
        // 3 stem convs + 9 inception modules × 6 convs.
        assert_eq!(m.conv_layers().count(), 57);
        let w: usize = m.conv_layers().map(|l| l.num_weights()).sum();
        // ≈6 M conv weights.
        assert!((5_000_000..7_000_000).contains(&w), "googlenet conv weights {w}");
    }

    #[test]
    fn synthesized_density_matches_calibration() {
        let m = alexnet();
        let spec = &m.layers[2];
        let mut rng = Rng::new(42);
        let w = synthesize_weights(spec, &mut rng);
        let d = density(w.data());
        let expect = 1.0 - spec.zero_frac;
        assert!(
            (d - expect).abs() < 0.02,
            "density {d} vs calibrated {expect}"
        );
    }

    #[test]
    fn workload_generation_is_deterministic() {
        let m = alexnet();
        let a = Workload::generate(&m, None, None, 7);
        let b = Workload::generate(&m, None, None, 7);
        assert_eq!(a.weights[0].data(), b.weights[0].data());
        let c = Workload::generate(&m, None, None, 8);
        assert_ne!(a.weights[0].data(), c.weights[0].data());
    }

    #[test]
    fn knobs_only_change_knobbed_weights() {
        let m = alexnet();
        let orig = Workload::generate(&m, None, None, 7);
        let dens = Workload::generate(&m, None, Some(0.5), 7);
        // Density degradation only zeroes weights, never changes values.
        for (wo, wd) in orig.weights.iter().zip(&dens.weights) {
            assert!(wo
                .data()
                .iter()
                .zip(wd.data())
                .all(|(&a, &b)| b == a || b == 0));
        }
    }

    #[test]
    fn unique_knob_limits_uniques_per_layer() {
        let m = googlenet();
        let wl = Workload::generate(&m, Some(16), None, 3);
        for (_, w) in wl.conv_layers() {
            assert!(unique_nonzero(w.data()) <= 16);
        }
    }

    #[test]
    fn sweep_groups_order_and_knobs() {
        let gs = SweepGroup::all();
        assert_eq!(gs.len(), 6);
        assert_eq!(gs[2], SweepGroup::Original);
        assert_eq!(gs[0].knobs(), (Some(16), None));
        assert_eq!(gs[5].knobs(), (None, Some(0.25)));
    }

    #[test]
    fn model_lookup() {
        assert!(model_by_name("alexnet").is_some());
        assert!(model_by_name("vgg16").is_some());
        assert!(model_by_name("googlenet").is_some());
        assert!(model_by_name("resnet").is_none());
    }

    #[test]
    fn list_parsing() {
        let ms = parse_model_list("alexnet, tiny").unwrap();
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[1].name, "tiny");
        assert!(parse_model_list("alexnet,resnet").is_err());
        let gs = parse_group_list("U=16,Orig,D=50%").unwrap();
        assert_eq!(
            gs,
            vec![
                SweepGroup::Unique(16),
                SweepGroup::Original,
                SweepGroup::Density(50)
            ]
        );
        assert!(parse_group_list("X=9").is_err());
    }
}
