//! PJRT runtime: load the AOT-compiled JAX/Pallas golden model from
//! `artifacts/*.hlo.txt` and execute it on the XLA CPU client.
//!
//! This is the only place the `xla` crate is touched. Interchange is HLO
//! **text** (not serialized `HloModuleProto`): jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md). Python never runs at simulation time —
//! after `make artifacts` the binary is self-contained.

pub mod golden;
pub mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client plus the executables loaded from the artifact dir.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled artifact ready to execute.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModel {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl LoadedModel {
    /// Execute with f32 inputs of the given shapes; returns the flattened
    /// f32 outputs (artifacts are lowered with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let tuple = result.decompose_tuple().context("decomposing result tuple")?;
        tuple
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

/// Convert the integer simulation tensors to the f32 the golden model
/// consumes. Integer convs at these magnitudes (|acc| < 2^24) are exact
/// in f32, so golden comparisons are equality checks.
pub fn to_f32(data: &[impl Copy + Into<f64>]) -> Vec<f32> {
    data.iter().map(|&x| {
        let v: f64 = x.into();
        v as f32
    }).collect()
}

/// Convert u8 activations to f32.
pub fn activations_f32(t: &crate::tensor::Tensor<u8>) -> Vec<f32> {
    t.data().iter().map(|&x| x as f32).collect()
}

/// Convert i8 weights to f32.
pub fn weights_f32(t: &crate::tensor::Tensor<i8>) -> Vec<f32> {
    t.data().iter().map(|&x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn conversions_roundtrip_values() {
        let a = Tensor::from_vec(&[4], vec![0u8, 1, 128, 255]);
        assert_eq!(activations_f32(&a), vec![0.0, 1.0, 128.0, 255.0]);
        let w = Tensor::from_vec(&[3], vec![-128i8, 0, 127]);
        assert_eq!(weights_f32(&w), vec![-128.0, 0.0, 127.0]);
    }

    // PJRT-dependent tests live in rust/tests/golden.rs (they need the
    // artifacts built by `make artifacts`).
}
