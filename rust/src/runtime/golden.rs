//! Golden-model verification: run the CoDR compressed datapath and the
//! AOT-compiled JAX/Pallas artifacts on identical inputs and demand
//! bit-for-bit equality. Shared by the CLI (`codr golden`), the
//! integration tests, and the end-to-end example.

use super::{activations_f32, weights_f32, Manifest, Runtime};
use crate::codr::{functional, Codr};
use crate::models::{synthesize_activations, tiny_cnn, Workload};
use crate::tensor::{fc, maxpool2d, relu_i32, requantize, Accum, Tensor};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Requantization shifts of the tiny CNN — must mirror
/// `python/compile/model.py::TINY_SHIFTS`. Sized for the zoo's σ_q = 6
/// weights so the post-shift activations keep ~6 bits of signal (too
/// large a shift silently zeroes the network — caught by the
/// `golden_is_seed_sensitive` integration test).
pub const TINY_SHIFTS: (u32, u32) = (6, 6);

/// Deterministic bias used by every golden comparison.
pub fn golden_bias(m: usize) -> Vec<i32> {
    (0..m as i32).map(|i| i * 5 - 11).collect()
}

/// Outcome of one conv-artifact check.
#[derive(Clone, Debug)]
pub struct ConvCheck {
    pub name: String,
    pub outputs: usize,
    pub exact: bool,
}

/// Verify every conv artifact in `dir` against the simulator.
pub fn check_convs(dir: &Path, seed: u64) -> Result<Vec<ConvCheck>> {
    let manifest = Manifest::load(dir).context("loading manifest (run `make artifacts`)")?;
    let rt = Runtime::cpu()?;
    let design = Codr::default();
    let mut results = Vec::new();
    for entry in manifest.convs() {
        let spec = entry.to_layer_spec()?;
        let mut rng = Rng::new(seed).fork(&entry.name);
        let w = crate::models::synthesize_weights(&spec, &mut rng);
        let x = synthesize_activations(&spec, &mut rng);
        let bias = golden_bias(spec.m);

        let sim = functional::run_layer(&design, &spec, &w, &x, &bias);

        let model = rt.load_hlo(&entry.hlo_path(dir))?;
        let xf = activations_f32(&x);
        let wf = weights_f32(&w);
        let bf: Vec<f32> = bias.iter().map(|&b| b as f32).collect();
        let out = model.run_f32(&[
            (&xf, &[spec.n, spec.r_i, spec.r_i][..]),
            (&wf, &[spec.m, spec.n, spec.r_k, spec.r_k][..]),
            (&bf, &[spec.m][..]),
        ])?;
        let golden = &out[0];
        let exact = golden.len() == sim.len()
            && golden.iter().zip(sim.data()).all(|(&g, &s)| g == s as f32);
        results.push(ConvCheck {
            name: entry.name.clone(),
            outputs: sim.len(),
            exact,
        });
    }
    Ok(results)
}

/// Max-pool 2×2 stride 2 over u8 activations (post-requantization).
fn maxpool_u8(x: &Tensor<u8>, k: usize, stride: usize) -> Tensor<u8> {
    let as_i32: Accum = x.map(|v| v as i32);
    maxpool2d(&as_i32, k, stride).map(|v| v as u8)
}

/// End-to-end tiny-CNN comparison: simulator logits vs compiled model.
#[derive(Clone, Debug)]
pub struct TinyCnnE2e {
    pub logits_sim: Vec<i32>,
    pub logits_golden: Vec<f32>,
    pub exact: bool,
}

/// Run the tiny CNN through the CoDR compressed datapath layer by layer
/// (conv → ReLU → requantize → pool, then FC) and through the single
/// `cnn_fwd` artifact, on identical weights/activations.
pub fn run_tiny_cnn_e2e(dir: &Path, seed: u64) -> Result<TinyCnnE2e> {
    let model = tiny_cnn();
    let wl = Workload::generate(&model, None, None, seed);
    let conv1 = &model.layers[0];
    let conv2 = &model.layers[1];
    let fc_spec = &model.layers[2];
    let (w1, w2, wf) = (&wl.weights[0], &wl.weights[1], &wl.weights[2]);
    let b1 = golden_bias(conv1.m);
    let b2 = golden_bias(conv2.m);
    let bf = golden_bias(fc_spec.m);
    let mut rng = Rng::new(seed).fork("tiny/input");
    let x = synthesize_activations(conv1, &mut rng);

    // ---- simulator forward (every conv through the compressed datapath).
    let design = Codr::default();
    let h = functional::run_layer(&design, conv1, w1, &x, &b1);
    let h = maxpool_u8(&requantize(&relu_i32(&h), TINY_SHIFTS.0), 2, 2);
    let h = functional::run_layer(&design, conv2, w2, &h, &b2);
    let h = maxpool_u8(&requantize(&relu_i32(&h), TINY_SHIFTS.1), 2, 2);
    let wf2d = Tensor::from_vec(&[fc_spec.m, fc_spec.n], wf.data().to_vec());
    let logits_sim = fc(h.data(), &wf2d, &bf);

    // ---- golden forward (one compiled artifact, all layers fused).
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(dir)?;
    let entry = manifest
        .find("cnn_fwd")
        .context("cnn_fwd missing from manifest")?;
    let exe = rt.load_hlo(&entry.hlo_path(dir))?;
    let xf = activations_f32(&x);
    let w1f = weights_f32(w1);
    let b1f: Vec<f32> = b1.iter().map(|&v| v as f32).collect();
    let w2f = weights_f32(w2);
    let b2f: Vec<f32> = b2.iter().map(|&v| v as f32).collect();
    let wff = weights_f32(wf);
    let bff: Vec<f32> = bf.iter().map(|&v| v as f32).collect();
    let out = exe.run_f32(&[
        (&xf, &[conv1.n, conv1.r_i, conv1.r_i][..]),
        (&w1f, &[conv1.m, conv1.n, 3, 3][..]),
        (&b1f, &[conv1.m][..]),
        (&w2f, &[conv2.m, conv2.n, 3, 3][..]),
        (&b2f, &[conv2.m][..]),
        (&wff, &[fc_spec.m, fc_spec.n][..]),
        (&bff, &[fc_spec.m][..]),
    ])?;
    let logits_golden = out[0].clone();

    let exact = logits_golden.len() == logits_sim.len()
        && logits_golden
            .iter()
            .zip(&logits_sim)
            .all(|(&g, &s)| g == s as f32);
    Ok(TinyCnnE2e {
        logits_sim,
        logits_golden,
        exact,
    })
}

/// Render a full golden report (used by `codr golden`).
pub fn golden_report(dir: &Path, seed: u64) -> Result<String> {
    let rt_platform = Runtime::cpu()?.platform();
    let mut out = format!("golden check on PJRT platform `{rt_platform}`\n");
    let mut failures = 0;
    for c in check_convs(dir, seed)? {
        out.push_str(&format!(
            "  {:<28} {:>7} outputs ... {}\n",
            c.name,
            c.outputs,
            if c.exact { "OK (exact)" } else { "MISMATCH" }
        ));
        if !c.exact {
            failures += 1;
        }
    }
    let e2e = run_tiny_cnn_e2e(dir, seed)?;
    out.push_str(&format!(
        "  {:<28} {:>7} logits  ... {}\n",
        "cnn_fwd (end-to-end)",
        e2e.logits_sim.len(),
        if e2e.exact { "OK (exact)" } else { "MISMATCH" }
    ));
    if !e2e.exact {
        failures += 1;
    }
    if failures > 0 {
        bail!("{failures} golden mismatches\n{out}");
    }
    out.push_str("all golden checks passed: simulator == XLA, bit for bit\n");
    Ok(out)
}
