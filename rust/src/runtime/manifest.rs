//! Artifact manifest: `artifacts/manifest.txt` maps each compiled HLO
//! artifact to the layer geometry it was lowered at. Written by
//! `python/compile/aot.py`, parsed here. Format: one entry per line of
//! whitespace-separated `key=value` pairs, `#` comments allowed.
//!
//! ```text
//! name=conv_n4_m8_r16_k3_s1_p1 kind=conv n=4 m=8 ri=16 rk=3 stride=1 pad=1
//! name=cnn_fwd kind=cnn n=4 ri=16
//! ```

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact's geometry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    /// "conv" (single layer golden) or "cnn" (end-to-end forward).
    pub kind: String,
    pub fields: HashMap<String, usize>,
}

impl ArtifactEntry {
    pub fn get(&self, key: &str) -> Result<usize> {
        self.fields
            .get(key)
            .copied()
            .with_context(|| format!("manifest entry {} missing field {key}", self.name))
    }

    /// Reconstruct the layer spec a conv artifact was lowered for.
    pub fn to_layer_spec(&self) -> Result<crate::models::LayerSpec> {
        Ok(crate::models::LayerSpec {
            name: self.name.clone(),
            kind: crate::models::LayerKind::Conv,
            n: self.get("n")?,
            m: self.get("m")?,
            r_i: self.get("ri")?,
            r_k: self.get("rk")?,
            stride: self.get("stride")?,
            pad: self.get("pad")?,
            groups: 1,
            sigma_q: 20.0,
            zero_frac: 0.5,
        })
    }

    /// Path of the artifact's HLO text within `dir`.
    pub fn hlo_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.hlo.txt", self.name))
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut kind = None;
            let mut fields = HashMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad token {tok}", lineno + 1))?;
                match k {
                    "name" => name = Some(v.to_string()),
                    "kind" => kind = Some(v.to_string()),
                    _ => {
                        let n: usize = v.parse().with_context(|| {
                            format!("manifest line {}: non-numeric {k}={v}", lineno + 1)
                        })?;
                        fields.insert(k.to_string(), n);
                    }
                }
            }
            let (Some(name), Some(kind)) = (name, kind) else {
                bail!("manifest line {}: missing name/kind", lineno + 1);
            };
            entries.push(ArtifactEntry { name, kind, fields });
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn convs(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.iter().filter(|e| e.kind == "conv")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# golden conv cases
name=conv_a kind=conv n=4 m=8 ri=16 rk=3 stride=1 pad=1

name=cnn_fwd kind=cnn n=4 ri=16
";

    #[test]
    fn parses_entries_and_comments() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.convs().count(), 1);
        let e = m.find("conv_a").unwrap();
        assert_eq!(e.get("m").unwrap(), 8);
        let spec = e.to_layer_spec().unwrap();
        assert_eq!(spec.r_o(), 16);
    }

    #[test]
    fn hlo_path_layout() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let p = m.find("cnn_fwd").unwrap().hlo_path(Path::new("artifacts"));
        assert_eq!(p, PathBuf::from("artifacts/cnn_fwd.hlo.txt"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("name=x kind=conv badtoken").is_err());
        assert!(Manifest::parse("kind=conv n=1").is_err());
        assert!(Manifest::parse("name=x kind=conv n=abc").is_err());
    }
}
