//! The CoDR accelerator model (paper §IV).
//!
//! Architecture (Fig 5): `T_PU` processing units share an Input RF; each
//! PU holds `T_N` Multiplier PEs (MPE) and `T_M` Accumulator PEs (APE)
//! joined by an interconnect. An MPE decodes the compressed weight
//! structures, multiplies each unique-weight **Δ** by the VMEM-resident
//! input tile (differential scalar-matrix multiply, Fig 3b), and the
//! Selector routes `T_RO×T_CO` windows of the running product matrix to
//! the APE named by each decoded index.
//!
//! Dataflow loop ordering (Fig 5a, circled ④→①):
//!
//! ```text
//! ④ for each output spatial tile            (output stationary: outputs
//! ③   for each output-channel group          written exactly once)
//! ②     for each input-channel tile         (inputs fetched
//! ①       stream the compressed weights      M/(T_PU·T_M) times)
//! ```
//!
//! [`dataflow`] walks this loop nest counting every access, ALU op and
//! cycle *exactly* (from the real encoded streams) without executing
//! MACs; [`functional`] executes the same datapath — decode, differential
//! multiply, index routing, accumulation — and must reproduce
//! [`crate::tensor::conv2d`] bit-for-bit.

pub mod dataflow;
pub mod functional;

use crate::arch::TileConfig;
use crate::models::LayerSpec;
use crate::sim::{Accelerator, LayerResult};
use crate::tensor::Weights;

/// The CoDR design at its Table I configuration.
#[derive(Clone, Debug)]
pub struct Codr {
    pub cfg: TileConfig,
    pub cacti: crate::arch::CactiLite,
    pub mem: crate::arch::MemConfig,
}

impl Default for Codr {
    fn default() -> Self {
        Codr {
            cfg: TileConfig::codr(),
            cacti: crate::arch::CactiLite::default(),
            mem: crate::arch::MemConfig::default(),
        }
    }
}

impl Accelerator for Codr {
    fn name(&self) -> &'static str {
        "CoDR"
    }

    fn tile_config(&self) -> TileConfig {
        self.cfg
    }

    fn simulate_layer(&self, spec: &LayerSpec, weights: &Weights) -> LayerResult {
        dataflow::simulate_layer(self, spec, weights)
    }
}
