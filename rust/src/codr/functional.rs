//! CoDR functional simulation: execute the *actual* compressed datapath —
//! RLE decode → differential scalar-matrix multiply → index-routed window
//! accumulation — and produce real convolution outputs.
//!
//! This is the end-to-end correctness proof for the whole UCR + RLE +
//! dataflow stack: for any layer, the output must equal the dense integer
//! reference [`crate::tensor::conv2d`] (and hence the XLA golden model in
//! `artifacts/`) **bit for bit**, because every transformation along the
//! way (quantize → tile → sort → densify → unify → Δ → RLE) is lossless.

use super::Codr;
use crate::models::LayerSpec;
use crate::reuse::memo::{self, Fp128};
use crate::reuse::{tile_layer, UcrVector, WeightVector};
use crate::rle::{decode_layer, encode_layer, CoderSpec};
use crate::tensor::{Accum, Activations, Tensor, Weights};

/// Execute one conv layer through the CoDR compressed datapath.
///
/// Mirrors the hardware stage by stage:
/// 1. offline: UCR transform + customized RLE encode;
/// 2. Weight Decoder: decode the three streams back to (Δ, count, index);
/// 3. MLP array: running product matrix `P += Δ · input_tile`
///    (matrix-matrix accumulator — after entry *i*, `P = uᵢ · tile`);
/// 4. Selector + interconnect: for each index, route the `(k_r,k_c)`
///    window of `P` to APE `m_local`;
/// 5. APE: accumulate into the output tile (bias preloaded).
pub fn run_layer(
    design: &Codr,
    spec: &LayerSpec,
    weights: &Weights,
    input: &Activations,
    bias: &[i32],
) -> Accum {
    let cfg = &design.cfg;
    assert_eq!(input.shape(), &[spec.n, spec.r_i, spec.r_i]);
    assert_eq!(bias.len(), spec.m);

    // ---- offline compression ------------------------------------------
    // The UCR transform of each vector comes from the process-wide memo
    // (fingerprinted at extraction like the stats path) — the functional
    // simulator shares transforms with every other pipeline instead of
    // redoing them per call. The encode → decode round trip below still
    // runs on the REAL streams; only the pure sort/densify/unify step is
    // memoized, and the memo is pinned bit-identical to a fresh
    // transform.
    let tiles = tile_layer(spec, weights, cfg.t_n, cfg.t_m);
    let coder_spec = CoderSpec::new(cfg.t_m * spec.r_k * spec.r_k);
    let cache = memo::global();
    let owned: Vec<UcrVector> = tiles
        .iter()
        .flat_map(|t| t.vectors.iter())
        .map(|v| {
            let fp = Fp128::of_i8(&v.weights);
            cache.get_or_insert_keyed(fp, &v.weights).ucr.clone()
        })
        .collect();
    let enc = encode_layer(&owned, coder_spec);
    // The hardware re-decodes the stream every spatial pass; decoding once
    // is equivalent (stream decode determinism is tested separately).
    let lens: Vec<usize> = tiles
        .iter()
        .flat_map(|t| t.vectors.iter().map(|v| v.len()))
        .collect();
    let decoded = decode_layer(&enc, &lens);

    // ---- padded input (zero skirt) --------------------------------------
    let p = spec.pad;
    let r_pad = spec.r_i + 2 * p;
    let mut padded: Tensor<i32> = Tensor::zeros(&[spec.n, r_pad, r_pad]);
    for c in 0..spec.n {
        for r in 0..spec.r_i {
            for col in 0..spec.r_i {
                padded.set3(c, r + p, col + p, input.at3(c, r, col) as i32);
            }
        }
    }

    let r_o = spec.r_o();
    let mut out = Accum::zeros(&[spec.m, r_o, r_o]);
    for m in 0..spec.m {
        for r in 0..r_o {
            for c in 0..r_o {
                out.set3(m, r, c, bias[m]);
            }
        }
    }

    // ---- dataflow: ④ spatial tiles, ③/② channel tiles, ① weight stream --
    let t_ro_eff = cfg.t_ro_eff(spec.r_k, spec.stride);
    let t_co_eff = cfg.t_co_eff(spec.r_k, spec.stride);
    let mut flat = 0usize; // vector cursor into `decoded`, tile order
    let mut tile_vectors: Vec<(&crate::reuse::Tile, &[UcrVector])> = Vec::new();
    for tile in &tiles {
        tile_vectors.push((tile, &decoded[flat..flat + tile.vectors.len()]));
        flat += tile.vectors.len();
    }

    for ro0 in (0..r_o).step_by(t_ro_eff) {
        let ro_a = t_ro_eff.min(r_o - ro0);
        for co0 in (0..r_o).step_by(t_co_eff) {
            let co_a = t_co_eff.min(r_o - co0);
            // Input tile geometry for this output window.
            let t_ri_a = (ro_a - 1) * spec.stride + spec.r_k;
            let t_ci_a = (co_a - 1) * spec.stride + spec.r_k;

            for (tile, dvs) in &tile_vectors {
                for (dn, u) in dvs.iter().enumerate() {
                    let n = tile.n0 + dn;
                    let geom = &tile.vectors[dn];
                    process_vector(
                        u,
                        geom,
                        &padded,
                        n,
                        (ro0, co0, ro_a, co_a),
                        (t_ri_a, t_ci_a),
                        spec.stride,
                        tile.m0,
                        &mut out,
                    );
                }
            }
        }
    }
    out
}

/// MLP array + Selector + APE for one decoded vector on one spatial tile.
#[allow(clippy::too_many_arguments)]
fn process_vector(
    u: &UcrVector,
    geom: &WeightVector,
    padded: &Tensor<i32>,
    n: usize,
    (ro0, co0, ro_a, co_a): (usize, usize, usize, usize),
    (t_ri_a, t_ci_a): (usize, usize),
    stride: usize,
    m0: usize,
    out: &mut Accum,
) {
    if u.uniques.is_empty() {
        return;
    }
    // Input-tile origin in padded coordinates.
    let ir0 = ro0 * stride;
    let ic0 = co0 * stride;

    // Running product matrix P (the MLP array's matrix-matrix accumulator).
    let mut prod = vec![0i64; t_ri_a * t_ci_a];
    let mut prev: i64 = 0;
    for (&uw, group) in u.uniques.iter().zip(u.index_groups()) {
        let delta = uw as i64 - prev;
        prev = uw as i64;
        // Differential scalar-matrix multiply: P += Δ · tile.
        for r in 0..t_ri_a {
            for c in 0..t_ci_a {
                prod[r * t_ci_a + c] += delta * padded.at3(n, ir0 + r, ic0 + c) as i64;
            }
        }
        // Selector: each index picks the (k_r,k_c)-offset window of P and
        // the interconnect routes it to APE m_local.
        for &idx in group {
            let (m_local, kr, kc) = geom.coords_of(idx as usize);
            let m = m0 + m_local;
            for r in 0..ro_a {
                for c in 0..co_a {
                    let v = prod[(r * stride + kr) * t_ci_a + (c * stride + kc)];
                    let cur = out.at3(m, ro0 + r, co0 + c);
                    out.set3(m, ro0 + r, co0 + c, cur + v as i32);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{synthesize_activations, synthesize_weights, LayerKind};
    use crate::tensor::conv2d;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn spec(n: usize, m: usize, r_i: usize, r_k: usize, stride: usize, pad: usize) -> LayerSpec {
        LayerSpec {
            name: "f".into(),
            kind: LayerKind::Conv,
            n,
            m,
            r_i,
            r_k,
            stride,
            pad,
            groups: 1,
            sigma_q: 20.0,
            zero_frac: 0.5,
        }
    }

    fn check_layer(s: &LayerSpec, seed: u64) {
        let mut rng = Rng::new(seed);
        let w = synthesize_weights(s, &mut rng);
        let x = synthesize_activations(s, &mut rng);
        let bias: Vec<i32> = (0..s.m as i32).map(|i| i * 3 - 7).collect();
        let design = Codr::default();
        let got = run_layer(&design, s, &w, &x, &bias);
        let want = conv2d(&x, &w, &bias, s.stride, s.pad);
        assert_eq!(got, want, "layer {} seed {seed}", s.name);
    }

    #[test]
    fn matches_reference_3x3() {
        check_layer(&spec(4, 8, 12, 3, 1, 1), 1);
    }

    #[test]
    fn matches_reference_1x1() {
        check_layer(&spec(8, 8, 10, 1, 1, 0), 2);
    }

    #[test]
    fn matches_reference_5x5_pad2() {
        check_layer(&spec(3, 6, 14, 5, 1, 2), 3);
    }

    #[test]
    fn matches_reference_strided() {
        check_layer(&spec(3, 8, 23, 11, 4, 0), 4);
    }

    #[test]
    fn matches_reference_stride2_7x7() {
        check_layer(&spec(3, 8, 21, 7, 2, 3), 5);
    }

    #[test]
    fn matches_reference_edge_channel_tiles() {
        // N, M not multiples of T_N/T_M exercise clipped tiles.
        check_layer(&spec(5, 7, 9, 3, 1, 1), 6);
    }

    #[test]
    fn matches_reference_all_zero_weights() {
        let s = spec(2, 4, 8, 3, 1, 1);
        let w = Weights::zeros(&[4, 2, 3, 3]);
        let mut rng = Rng::new(7);
        let x = synthesize_activations(&s, &mut rng);
        let bias = vec![11; 4];
        let got = run_layer(&Codr::default(), &s, &w, &x, &bias);
        assert!(got.data().iter().all(|&v| v == 11));
    }

    #[test]
    fn matches_reference_dense_single_value() {
        // Maximum repetition: all weights identical — one unique weight,
        // enormous counts → exercises count-overflow dummies end to end.
        let s = spec(2, 8, 10, 3, 1, 1);
        let w = Weights::from_fn(&[8, 2, 3, 3], |_| 3);
        let mut rng = Rng::new(8);
        let x = synthesize_activations(&s, &mut rng);
        let bias = vec![0; 8];
        let got = run_layer(&Codr::default(), &s, &w, &x, &bias);
        let want = conv2d(&x, &w, &bias, 1, 1);
        assert_eq!(got, want);
    }

    /// The crown-jewel property: for random layer geometry, weights,
    /// activations, and sweep knobs, the full compressed datapath equals
    /// the dense reference exactly.
    #[test]
    fn prop_compressed_datapath_equals_reference() {
        check(
            25,
            |r, size| {
                let r_k = [1usize, 3, 5][r.index(3)];
                let stride = 1 + r.index(2);
                let pad = r.index(r_k.min(2) + 1);
                let r_i = (r_k + stride * 2 + r.index(6 + size / 10)).max(r_k);
                let n = 1 + r.index(6);
                let m = 1 + r.index(10);
                let zero_frac = r.f64() * 0.9;
                (n, m, r_i, r_k, stride, pad, zero_frac, r.next_u64())
            },
            |&(n, m, r_i, r_k, stride, pad, zero_frac, seed)| {
                let mut s = spec(n, m, r_i, r_k, stride, pad);
                s.zero_frac = zero_frac;
                let mut rng = Rng::new(seed);
                let w = synthesize_weights(&s, &mut rng);
                let x = synthesize_activations(&s, &mut rng);
                let bias: Vec<i32> = (0..m as i32).collect();
                let got = run_layer(&Codr::default(), &s, &w, &x, &bias);
                got == conv2d(&x, &w, &bias, stride, pad)
            },
        );
    }
}
